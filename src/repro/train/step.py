"""Train-step builder: grad accumulation (microbatching), remat, optional
cross-pod bf16 gradient compression, AdamW, metrics.

Microbatching splits the per-step batch along the batch axis and runs a
``lax.scan`` of forward+backward, accumulating gradients — the standard
compute/comm-overlap trick: XLA overlaps microbatch k's reduce-scatter
with microbatch k+1's compute.  ``grad_compress="bf16"`` accumulates
gradients in bf16, which halves the cross-pod all-reduce volume (the
fidelity loss is bounded by accumulating each microbatch's contribution in
f32 before the cast).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as model_lib
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig
from .optim import AdamWConfig, AdamWState, adamw_update, cosine_schedule

__all__ = ["make_loss", "make_train_step"]


def make_loss(cfg: ModelConfig, mesh=None, data_axes=("data",),
              shard=model_lib._id_shard) -> Callable:
    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, mesh=mesh,
                                 data_axes=data_axes, shard=shard)
    return loss


def _split_microbatches(batch: Dict, n: int) -> Dict:
    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n,))
        if x.shape[0] % n == 0 and x.shape[0] >= n:
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])
        # leading dim not divisible (e.g. [3, B, S] positions): try dim 1
        return jnp.moveaxis(
            x.reshape(x.shape[:1] + (n, x.shape[1] // n) + x.shape[2:]), 1, 0)
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, exec_cfg: ExecConfig,
                    opt_cfg: AdamWConfig, mesh=None,
                    data_axes: Tuple[str, ...] = ("data",),
                    shard=model_lib._id_shard,
                    lr_schedule: Optional[Callable] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss(cfg, mesh=mesh, data_axes=data_axes, shard=shard)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dtype = jnp.bfloat16 if exec_cfg.grad_compress == "bf16" else jnp.float32
    n_micro = max(exec_cfg.microbatch, 1)

    def compute_grads(params, batch):
        if n_micro == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads

        micro = _split_microbatches(batch, n_micro)

        def body(acc, mb):
            (loss, aux), grads = grad_fn(params, mb)
            g_acc, l_acc = acc
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype) / n_micro, g_acc, grads)
            return (g_acc, l_acc + loss / n_micro), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (grads, loss), auxs = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                           micro)
        aux = jax.tree.map(lambda a: a.mean(), auxs)
        return loss, aux, grads

    def train_step(params, opt_state: AdamWState, batch):
        loss, aux, grads = compute_grads(params, batch)
        lr = (lr_schedule(opt_state.count) if lr_schedule is not None
              else jnp.float32(opt_cfg.lr))
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg, lr=lr)
        metrics = {"loss": loss, "lr": lr, **aux, **om}
        return params, opt_state, metrics

    return train_step
