"""AdamW with global-norm clipping and cosine LR schedule (self-contained,
pytree-based — no optax dependency).

Moment dtype is configurable (``ExecConfig.optim_dtype``): bf16 moments
halve optimizer HBM — required to fit the 1T-param Kimi-K2 cell on 512
v5e chips — at the cost of stochastic-rounding-free moment updates
(accumulation still happens in f32 before the cast).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


@jax.named_scope("adamw")
def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c
    lr = cfg.lr if lr is None else lr
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_leaf(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd_leaf, grads, state.m, state.v, params)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return newp, AdamWState(count=count, m=newm, v=newv), {"grad_norm": gnorm}


def cosine_schedule(step: jax.Array, *, peak_lr: float, warmup: int,
                    total: int, floor: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)
