from .optim import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                    cosine_schedule, global_norm, clip_by_global_norm)
from .step import make_loss, make_train_step

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm",
           "make_loss", "make_train_step"]
