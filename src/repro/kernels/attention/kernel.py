"""Pallas TPU kernel: causal flash attention (GQA) forward.

One grid program per (batch*kv_head, q-block): q/k/v tiles live in VMEM,
the online-softmax state (m, l, acc) is carried through a ``fori_loop``
over kv blocks, and fully-masked kv blocks beyond the causal frontier are
skipped by bounding the loop at the q-block's last row — the causal-waste
saving that the jnp oracle path (`models.attention._blockwise_attention`)
cannot express with a static ``lax.scan``.

Block sizes default to (128, 128): MXU-aligned on both matmul dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel_call"]

_NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  seq_k: int, scale: float, causal: bool, groups: int):
    """q block: [G, bq, dh]; k/v: full [T, dh] for this kv head."""
    qi = pl.program_id(1)
    q = q_ref[0].swapaxes(0, 1).astype(jnp.float32) * scale   # [G, bq, dh]
    G, _, dh = q.shape
    dv = v_ref.shape[-1]

    nk = seq_k // bk
    q_start = qi * bq
    # causal frontier: kv blocks strictly above the diagonal are skipped
    last = jnp.minimum(nk, (q_start + bq + bk - 1) // bk) if causal else nk

    def body(ki, acc):
        m, l, o = acc
        k = k_ref[0, pl.dslice(ki * bk, bk)].astype(jnp.float32)   # [bk, dh]
        v = v_ref[0, pl.dslice(ki * bk, bk)].astype(jnp.float32)   # [bk, dv]
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())))    # [G,bq,bk]
        if causal:
            si = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ti = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where((ti <= si)[None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())))
        return m_new, l_new, o_new

    init = (jnp.full((G, bq), _NEG, jnp.float32),
            jnp.zeros((G, bq), jnp.float32),
            jnp.zeros((G, bq, dv), jnp.float32))
    m, l, o = jax.lax.fori_loop(0, last, body, init)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)
    o_ref[0] = out.swapaxes(0, 1)                     # [bq, G, dv]


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention_kernel_call(q, k, v, *, bq: int = 128, bk: int = 128,
                                causal: bool = True, interpret: bool = True):
    """q: [B, H, S, dh]; k/v: [B, KV, T, dh] -> o [B, H, S, dh].

    S and T must be multiples of bq/bk (pad upstream); H % KV == 0.
    """
    B, H, S, dh = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    scale = dh ** -0.5
    nq = S // bq

    qg = q.reshape(B, KV, G, S, dh).transpose(0, 1, 3, 2, 4) \
          .reshape(B * KV, S, G, dh)
    kf = k.reshape(B * KV, T, dh)
    vf = v.reshape(B * KV, T, dv)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_k=T,
                               scale=scale, causal=causal, groups=G)

    o = pl.pallas_call(
        kernel,
        grid=(B * KV, nq),
        in_specs=[
            pl.BlockSpec((1, bq, G, dh), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, T, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, dv), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, dv), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S, G, dv), q.dtype),
        interpret=interpret,
    )(qg, kf, vf)
    return o.reshape(B, KV, S, G, dv).transpose(0, 1, 3, 2, 4) \
            .reshape(B, H, S, dv)
