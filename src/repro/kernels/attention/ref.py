"""Numpy oracle: causal GQA softmax attention."""

from __future__ import annotations

import numpy as np

__all__ = ["attention_ref"]


def attention_ref(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                  causal: bool = True) -> np.ndarray:
    """q: [B,H,S,dh]; k/v: [B,KV,T,dh] -> [B,H,S,dv] (float64 math)."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    B, H, S, dh = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    out = np.empty((B, H, S, v.shape[-1]))
    for b in range(B):
        for h in range(H):
            kv = h // G
            s = (q[b, h] @ k[b, kv].T) * scale
            if causal:
                mask = np.tril(np.ones((S, T), bool), k=T - S)
                s = np.where(mask, s, -np.inf)
            s = s - s.max(axis=-1, keepdims=True)
            p = np.exp(s)
            p /= p.sum(axis=-1, keepdims=True)
            out[b, h] = p @ v[b, kv]
    return out
