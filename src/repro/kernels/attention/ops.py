"""Jitted public API for the flash-attention kernel."""

from __future__ import annotations

from typing import Optional

from ..common import default_interpret
from .kernel import flash_attention_kernel_call

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, bq: int = 128, bk: int = 128,
                    causal: bool = True, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return flash_attention_kernel_call(q, k, v, bq=bq, bk=bk, causal=causal,
                                       interpret=interpret)
