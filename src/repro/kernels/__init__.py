"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage ships the kernel (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jitted wrapper (ops.py) and a pure-numpy oracle (ref.py):

* ``dtw``       — the paper's DP, row-parallel min-plus wavefront
* ``iir``       — batched Chebyshev de-noise (direct-form II transposed)
* ``attention`` — causal GQA flash attention (online softmax)
* ``gla``       — chunked gated-linear-attention scan (Mamba2/mLSTM core)
"""

from . import dtw, iir, attention, gla
from .common import default_interpret

__all__ = ["dtw", "iir", "attention", "gla", "default_interpret"]
