"""Numpy oracle: direct-form II transposed IIR (matches scipy.lfilter)."""

from __future__ import annotations

import numpy as np

__all__ = ["lfilter_ref"]


def lfilter_ref(b: np.ndarray, a: np.ndarray, x: np.ndarray) -> np.ndarray:
    b = np.asarray(b, np.float64) / a[0]
    a = np.asarray(a, np.float64) / a[0]
    n = len(b)
    x = np.asarray(x, np.float64)
    y = np.zeros_like(x)
    z = np.zeros(x.shape[:-1] + (n - 1,))
    for t in range(x.shape[-1]):
        xt = x[..., t]
        yt = b[0] * xt + z[..., 0]
        y[..., t] = yt
        z = np.concatenate([
            (b[1:] * xt[..., None] - a[1:] * yt[..., None]
             + np.pad(z[..., 1:], [(0, 0)] * (z.ndim - 1) + [(0, 1)]))
        ], axis=-1) if False else (
            b[1:] * xt[..., None] - a[1:] * yt[..., None]
            + np.pad(z[..., 1:], [(0, 0)] * (z.ndim - 1) + [(0, 1)]))
    return y
