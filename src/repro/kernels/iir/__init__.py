from .ops import lfilter_batched
from .ref import lfilter_ref

__all__ = ["lfilter_batched", "lfilter_ref"]
