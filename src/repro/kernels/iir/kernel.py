"""Pallas TPU kernel: batched IIR filtering (direct-form II transposed).

The paper's 6th-order Chebyshev de-noise runs over every profiled series in
the reference DB.  The recurrence is sequential in time, so the TPU
adaptation batches series across VPU lanes: each grid program filters a
[BLOCK_B, T] tile, carrying the [BLOCK_B, order] filter state through a
``fori_loop`` over time steps — lanes do the parallel work, time is the
loop.  (An ``associative_scan`` state-space formulation is possible but
needs 2x2 matrix composition per biquad; the lane-batched loop is both
simpler and faster when the DB holds >= 128 series.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["iir_kernel_call", "BLOCK_B"]

BLOCK_B = 128   # series per grid program = one lane tile


def _iir_kernel(b_ref, a_ref, x_ref, y_ref, *, t_len: int, order: int):
    b = b_ref[...]                       # [order+1]
    a = a_ref[...]                       # [order+1]
    bb = x_ref.shape[0]

    def step(t, state):                  # state: [BLOCK_B, order]
        xt = x_ref[:, t]                 # [BLOCK_B]
        yt = b[0] * xt + state[:, 0]
        y_ref[:, t] = yt
        # z_i = b_{i+1} x - a_{i+1} y + z_{i+1}
        nxt = (b[1:][None, :] * xt[:, None]
               - a[1:][None, :] * yt[:, None]
               + jnp.pad(state[:, 1:], ((0, 0), (0, 1))))
        return nxt

    jax.lax.fori_loop(0, t_len, step, jnp.zeros((bb, order), jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def iir_kernel_call(b, a, x, interpret: bool = True):
    """b, a: [order+1] (a[0]=1); x: [B, T] -> y [B, T] (f32)."""
    B, T = x.shape
    order = b.shape[0] - 1
    nb = -(-B // BLOCK_B)
    pad = nb * BLOCK_B - B
    xp = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0)))
    kernel = functools.partial(_iir_kernel, t_len=T, order=order)
    y = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((order + 1,), lambda i: (0,)),
                  pl.BlockSpec((order + 1,), lambda i: (0,)),
                  pl.BlockSpec((BLOCK_B, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_B, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK_B, T), jnp.float32),
        interpret=interpret,
    )(b.astype(jnp.float32), a.astype(jnp.float32), xp)
    return y[:B]
