"""Jitted public API for the batched IIR kernel."""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..common import default_interpret
from .kernel import iir_kernel_call

__all__ = ["lfilter_batched"]


def lfilter_batched(b, a, x, interpret: Optional[bool] = None):
    """Filter a batch of series [B, T] along time (normalizes by a[0])."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64) / a[0]
    a = a / a[0]
    interpret = default_interpret() if interpret is None else interpret
    import jax.numpy as jnp
    return iir_kernel_call(jnp.asarray(b), jnp.asarray(a), x,
                           interpret=interpret)
