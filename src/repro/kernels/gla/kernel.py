"""Pallas TPU kernel: chunked gated linear attention (SSD / mLSTM core).

Per head the recurrence  S_t = a_t S_{t-1} + k_t^T v_t,  o_t = q_t S_t
is evaluated chunk-parallel: one grid program per (batch*head), a
``fori_loop`` over chunks carrying the [dk, dv] state in f32; each chunk
does three MXU matmuls (intra-chunk scores, inter-chunk read, state
update) plus VPU decay weighting — the same math as
``repro.models.ssm.gla_chunked`` and the ``ref.py`` step oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gla_kernel_call"]


def _gla_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, s_ref, *,
                chunk: int, seq: int):
    """q/k: [S, dk]; v: [S, dv]; g: [S] (within-chunk cumsum of log_a)."""
    dk = q_ref.shape[-1]
    dv = v_ref.shape[-1]
    nc = seq // chunk

    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj

    def body(ci, state):
        sl = pl.dslice(ci * chunk, chunk)
        qb = q_ref[0, sl].astype(jnp.float32)            # [L, dk]
        kb = k_ref[0, sl].astype(jnp.float32)
        vb = v_ref[0, sl].astype(jnp.float32)
        gb = g_ref[0, sl].astype(jnp.float32)            # [L]

        scores = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())))
        decay = jnp.exp(gb[:, None] - gb[None, :])
        scores = jnp.where(causal, scores * decay, 0.0)
        o = jax.lax.dot_general(scores, vb, (((1,), (0,)), ((), ())))
        o = o + jnp.exp(gb)[:, None] * jax.lax.dot_general(
            qb, state, (((1,), (0,)), ((), ())))
        o_ref[0, sl] = o.astype(o_ref.dtype)

        w = jnp.exp(gb[-1] - gb)                         # [L]
        state = (jnp.exp(gb[-1]) * state
                 + jax.lax.dot_general(kb * w[:, None], vb,
                                       (((0,), (0,)), ((), ()))))
        return state

    final = jax.lax.fori_loop(0, nc, body, jnp.zeros((dk, dv), jnp.float32))
    s_ref[0] = final


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_kernel_call(q, k, v, log_a, *, chunk: int = 128,
                    interpret: bool = True):
    """q,k: [B,H,S,dk]; v: [B,H,S,dv]; log_a: [B,H,S] (<=0).
    Returns (o [B,H,S,dv], final_state [B,H,dk,dv]).
    S must be a multiple of ``chunk`` (pad upstream)."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "pad S to a multiple of chunk"
    # within-chunk inclusive cumsum of log_a
    g = jnp.cumsum(log_a.reshape(B, H, nc, chunk).astype(jnp.float32),
                   axis=-1).reshape(B * H, S)
    qf = q.reshape(B * H, S, dk)
    kf = k.reshape(B * H, S, dk)
    vf = v.reshape(B * H, S, dv)

    kernel = functools.partial(_gla_kernel, chunk=chunk, seq=S)
    o, s = pl.pallas_call(
        kernel,
        grid=(B * H,),
        in_specs=[pl.BlockSpec((1, S, dk), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, S, dk), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, S, dv), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, S), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((1, S, dv), lambda b: (b, 0, 0)),
                   pl.BlockSpec((1, dk, dv), lambda b: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, dv), v.dtype),
                   jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, g)
    return o.reshape(B, H, S, dv), s.reshape(B, H, dk, dv)
