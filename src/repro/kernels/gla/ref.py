"""Numpy oracle: step-by-step gated linear attention recurrence."""

from __future__ import annotations

import numpy as np

__all__ = ["gla_ref"]


def gla_ref(q, k, v, log_a, initial_state=None):
    """q,k: [B,H,S,dk]; v: [B,H,S,dv]; log_a: [B,H,S].
    Returns (o, final_state) in float64."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    log_a = np.asarray(log_a, np.float64)
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    state = (np.zeros((B, H, dk, dv)) if initial_state is None
             else np.asarray(initial_state, np.float64).copy())
    o = np.empty((B, H, S, dv))
    for t in range(S):
        a = np.exp(log_a[..., t])[..., None, None]
        state = a * state + np.einsum("bhd,bhv->bhdv", k[..., t, :], v[..., t, :])
        o[..., t, :] = np.einsum("bhd,bhdv->bhv", q[..., t, :], state)
    return o, state
