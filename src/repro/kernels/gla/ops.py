"""Jitted public API for the GLA chunked-scan kernel."""

from __future__ import annotations

from typing import Optional

from ..common import default_interpret
from .kernel import gla_kernel_call

__all__ = ["gla_scan"]


def gla_scan(q, k, v, log_a, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return gla_kernel_call(q, k, v, log_a, chunk=chunk, interpret=interpret)
