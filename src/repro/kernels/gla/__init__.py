from .ops import gla_scan
from .ref import gla_ref

__all__ = ["gla_scan", "gla_ref"]
