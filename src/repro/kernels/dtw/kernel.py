"""Pallas TPU kernel: batched DTW accumulated-cost matrix.

TPU adaptation of the paper's CPU DP (DESIGN.md §2): the recurrence

    D[i, j] = d[i, j] + min(D[i-1, j], D[i, j-1], D[i-1, j-1])

is solved **row-parallel** — the in-row dependency is a min-plus (tropical
semiring) affine recurrence

    c_j = min(s_j, c_{j-1} + a_j),   s_j = min(D[i-1,j], D[i-1,j-1]) + d_j,
                                     a_j = d_j

whose maps compose associatively, so each row is a Hillis-Steele scan over
the VPU lanes (log2(M) shift+min steps) and rows advance sequentially.
One grid program per reference series (the matching phase compares one
query against the whole reference DB); the full D matrix stays in a VMEM
block and is written out for host-side backtracking (paper Eq. 3 needs the
warped series Y').

VMEM budget: the [N, M] f32 block must fit alongside the row scratch —
N, M <= 1024 keeps it under 4 MiB, the practical size after the wavelet
compression the paper proposes for cluster-scale series (its §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["dtw_matrix_kernel", "dtw_matrix_pairs_kernel"]

_INF = 3.0e38  # plain float: jnp scalars become captured consts in Pallas


def _minplus_scan(a: jax.Array, s: jax.Array, m_len: int) -> jax.Array:
    """Inclusive scan of min-plus affine maps f_j(c) = min(c + a_j, s_j)
    over the last axis; returns c_j = (f_j o ... o f_0)(+inf) = s-part."""
    n_steps = int(np.ceil(np.log2(max(m_len, 2))))
    # identity element: (a=0, s=+inf)
    for t in range(n_steps):
        off = 1 << t
        a_l = jnp.pad(a, (off, 0), constant_values=0.0)[:-off]
        s_l = jnp.pad(s, (off, 0), constant_values=_INF)[:-off]
        # compose: left map first, then right (current) map
        s = jnp.minimum(s_l + a, s)
        a = a_l + a
    return s


def _dtw_kernel(x_ref, y_ref, d_ref, *, n: int, m: int):
    """x: [N] query; y: [M] one reference; out D: [N, M]."""
    x = x_ref[...]
    y = y_ref[0]

    jj = jax.lax.iota(jnp.int32, m)

    def row(i, prev):
        d = jnp.abs(x[i] - y)                                  # [M]
        prev_shift = jnp.pad(prev, (1, 0), constant_values=_INF)[:-1]
        mrow = jnp.minimum(prev, prev_shift)
        s = jnp.where((i == 0) & (jj == 0), d, mrow + d)
        s = jnp.where((i == 0) & (jj > 0), _INF, s)            # row0: only c_{-1} path
        cur = _minplus_scan(d, s, m)
        d_ref[0, i, :] = cur
        return cur

    jax.lax.fori_loop(0, n, row, jnp.full((m,), _INF, jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dtw_call(x, ys, interpret: bool):
    n = x.shape[0]
    k, m = ys.shape
    kernel = functools.partial(_dtw_kernel, n=n, m=m)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((1, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n, m), jnp.float32),
        interpret=interpret,
    )(x, ys)


def dtw_matrix_kernel(x, ys, interpret: bool = True):
    """x: [N] f32; ys: [K, M] f32 -> D [K, N, M]."""
    x = jnp.asarray(x, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    return _dtw_call(x, ys, interpret)


# ---------------------------------------------------------------------------
# Pairs entry point: ragged query bank x ragged reference bank
# ---------------------------------------------------------------------------

def _dtw_pairs_kernel(x_ref, y_ref, d_ref, *, n: int, m: int):
    """x: [1, N] one query; y: [1, M] one reference; out D: [1, N, M].
    Same wavefront body as :func:`_dtw_kernel`, but the query is also
    blocked per grid program so each pair gets its own (query, reference)
    combination — the batched ``match_application`` layout."""
    x = x_ref[0]
    y = y_ref[0]

    jj = jax.lax.iota(jnp.int32, m)

    def row(i, prev):
        d = jnp.abs(x[i] - y)
        prev_shift = jnp.pad(prev, (1, 0), constant_values=_INF)[:-1]
        mrow = jnp.minimum(prev, prev_shift)
        s = jnp.where((i == 0) & (jj == 0), d, mrow + d)
        s = jnp.where((i == 0) & (jj > 0), _INF, s)
        cur = _minplus_scan(d, s, m)
        d_ref[0, i, :] = cur
        return cur

    jax.lax.fori_loop(0, n, row, jnp.full((m,), _INF, jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dtw_pairs_call(xs, ys, interpret: bool):
    k, n = xs.shape
    _, m = ys.shape
    kernel = functools.partial(_dtw_pairs_kernel, n=n, m=m)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n, m), jnp.float32),
        interpret=interpret,
    )(xs, ys)


def dtw_matrix_pairs_kernel(xs, ys, interpret: bool = True):
    """xs: [K, N] f32 queries; ys: [K, M] f32 references -> D [K, N, M],
    one grid program per (query, reference) pair.  Padded tails are
    harmless: D[i, j] only depends on cells (<=i, <=j), so callers read
    distances at (xlen-1, ylen-1) and slice before backtracking."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.shape[0] != ys.shape[0]:
        raise ValueError(f"pair count mismatch {xs.shape[0]} vs {ys.shape[0]}")
    return _dtw_pairs_call(xs, ys, interpret)
