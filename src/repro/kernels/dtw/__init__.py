from .ops import (dtw_batched, dtw_batched_pairs, dtw_distances,
                  dtw_distances_pairs)
from .ref import dtw_matrix_ref
from .score import (score_bank_offline, score_bank_offline_kernel,
                    score_bank_offline_var_approx_kernel,
                    score_bank_offline_var_kernel)
from .stream import (stream_bank_extend, stream_bank_extend_kernel,
                     stream_bank_extend_scored,
                     stream_bank_extend_scored_kernel)

__all__ = ["dtw_batched", "dtw_batched_pairs", "dtw_distances",
           "dtw_distances_pairs", "dtw_matrix_ref",
           "score_bank_offline", "score_bank_offline_kernel",
           "score_bank_offline_var_kernel",
           "score_bank_offline_var_approx_kernel",
           "stream_bank_extend", "stream_bank_extend_kernel",
           "stream_bank_extend_scored", "stream_bank_extend_scored_kernel"]
