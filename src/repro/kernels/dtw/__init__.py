from .ops import dtw_batched, dtw_distances
from .ref import dtw_matrix_ref

__all__ = ["dtw_batched", "dtw_distances", "dtw_matrix_ref"]
