"""Pallas TPU kernel: matrix-free offline bank scoring (the finish-path
whole-DB match).

One ``pallas_call`` renders the closed-end warp correlation of J complete
queries against the whole padded [K, M] reference bank — the offline
mirror of the fused streaming tick (``stream.py``).  The grid is
(query, reference-tile); each program runs its query through the full DP
with the [BK, M] row slice AND the three warp-path correlation-moment
slabs (sy, syy, sxy) pinned in VMEM, then reduces to the [BK] scores and
endpoint distances **in-kernel** — the only HBM writes are the [J, K]
score/distance tiles, never a row, a moment slab, or a [K, N, M] matrix.

Row updates and moment carries are the streaming scored kernel's
(``stream._stream_scored_kernel``): min-plus Hillis-Steele row scan,
backtrack-identical predecessor selection (diag, then vert, then horiz),
horizontal runs telescoped through one log2(M) anchored forward-fill.
The closed-end reduction reads row/moments at column ``lengths[k] - 1``
(the alignment endpoint D(N, M_k) of paper Eq. 1) instead of the
streaming open-end argmin, and evaluates ``core.dtw._corr_from_moments``
— the same score tail the jnp wavefront uses, so kernel == jnp is pinned
bit-identical on dyadic-grid data (tests/test_scored_matching.py) and
differs elsewhere only by warp-path tie flips.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from .stream import _INF, _MOM_SHIFT, _fill_from_anchor, _minplus_scan2

__all__ = ["score_bank_offline_kernel", "score_bank_offline",
           "score_bank_offline_var_kernel",
           "score_bank_offline_var_approx_kernel"]


def _score_kernel(xlen_ref, sx_ref, sxx_ref, x_ref, len_ref, bank_ref,
                  score_ref, dist_ref, *, n: int, m: int,
                  band: Optional[int]):
    """One (query, reference-tile) program: full-query DP + moments in
    VMEM, closed-end score reduction, [BK] outputs."""
    from ...core.dtw import _corr_from_moments

    xlen = xlen_ref[0]
    x = x_ref[0]                                   # [N]
    bank = bank_ref[...]                           # [BK, M]
    bk = bank.shape[0]
    lens = len_ref[...]                            # [BK]
    jj = jax.lax.iota(jnp.int32, m)
    yc = bank - _MOM_SHIFT
    yy = yc * yc

    def body(i, carry):
        row, moms = carry                          # [BK, M], [3, BK, M]
        d = jnp.abs(x[i] - bank)
        if band is not None:
            centers = (i * (lens - 1)) // jnp.maximum(xlen - 1, 1)
            d = jnp.where(jnp.abs(jj[None, :] - centers[:, None]) <= band,
                          d, _INF)
        corner = jnp.where(i == 0, 0.0, _INF)
        p_diag = jnp.concatenate(
            [jnp.broadcast_to(corner, (bk, 1)).astype(row.dtype),
             row[:, :-1]], axis=1)
        p_vert = row
        mn = jnp.minimum(p_vert, p_diag)
        new = _minplus_scan2(d, mn + d, m)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        new = jnp.minimum(new, _INF)
        p_horiz = jnp.concatenate(
            [jnp.full((bk, 1), _INF, new.dtype), new[:, :-1]], axis=1)
        sel_diag = p_diag <= jnp.minimum(p_vert, p_horiz)
        sel_vert = jnp.logical_and(~sel_diag, p_vert <= p_horiz)
        anch = jnp.logical_or(sel_diag, sel_vert)
        m_diag = jnp.concatenate(
            [jnp.zeros((3, bk, 1), moms.dtype), moms[:, :, :-1]], axis=2)
        base = jnp.where(sel_diag[None], m_diag,
                         jnp.where(sel_vert[None], moms, 0.0))
        base = _fill_from_anchor(base, anch, m)
        xm = x[i] - _MOM_SHIFT
        new_moms = base + jnp.stack([yc, yy, xm * yc])
        valid = i < xlen
        return (jnp.where(valid, new, row),
                jnp.where(valid, new_moms, moms))

    row0 = jnp.full((bk, m), _INF, jnp.float32)
    moms0 = jnp.zeros((3, bk, m), jnp.float32)
    row, moms = jax.lax.fori_loop(0, n, body, (row0, moms0))

    # closed-end reduction: endpoint column len_k - 1 per reference.
    onehot = jj[None, :] == (lens - 1)[:, None]              # [BK, M]
    dist = jnp.sum(jnp.where(onehot, row, 0.0), axis=1)
    msel = jnp.sum(jnp.where(onehot[None], moms, 0.0), axis=2)  # [3, BK]
    nn = jnp.maximum(xlen, 1).astype(jnp.float32)
    scores = _corr_from_moments(msel[0], msel[1], msel[2], sx_ref[0],
                                sxx_ref[0], nn)
    score_ref[0] = jnp.where(xlen > 0, scores, 0.0)
    dist_ref[0] = dist


@functools.partial(jax.jit,
                   static_argnames=("band", "block_k", "interpret"))
def _score_call(xs, xlens, bank, lengths, sx, sxx, band: Optional[int],
                block_k: int, interpret: bool):
    j, n = xs.shape
    k, m = bank.shape
    kernel = functools.partial(_score_kernel, n=n, m=m, band=band)
    scores, dists = pl.pallas_call(
        kernel,
        grid=(j, k // block_k),
        in_specs=[
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # xlen
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # sx
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # sxx
            pl.BlockSpec((1, n), lambda ji, ki: (ji, 0)),      # query
            pl.BlockSpec((block_k,), lambda ji, ki: (ki,)),    # lengths
            pl.BlockSpec((block_k, m), lambda ji, ki: (ki, 0)),  # bank
        ],
        out_specs=[
            pl.BlockSpec((1, block_k), lambda ji, ki: (ji, ki)),
            pl.BlockSpec((1, block_k), lambda ji, ki: (ji, ki)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, k), jnp.float32),
            jax.ShapeDtypeStruct((j, k), jnp.float32),
        ],
        interpret=interpret,
    )(xlens, sx, sxx, xs, lengths, bank)
    return scores, dists


def score_bank_offline_kernel(xs, xlens, bank, lengths, sx, sxx,
                              band: Optional[int] = None,
                              block_k: int = 128,
                              interpret: bool = True):
    """Closed-end scores + endpoint distances of J queries vs the whole
    bank — one pallas_call.

    xs [J, N] f32 (padded; ``xlens`` [J] i32 true lengths); bank [K, M]
    f32 with lengths [K] i32; sx/sxx [J] f32 centered query folds
    (``core.dtw.query_moments``) -> (scores [J, K], dists [J, K]).  K is
    padded up to a ``block_k`` multiple internally (padding rows never
    influence real rows; their outputs are sliced away).
    """
    xs = jnp.asarray(xs, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    xlens = jnp.asarray(xlens, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    sx = jnp.asarray(sx, jnp.float32)
    sxx = jnp.asarray(sxx, jnp.float32)
    k, m = bank.shape
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        bank = jnp.concatenate(
            [bank, jnp.zeros((pad, m), jnp.float32)], axis=0)
        lengths = jnp.concatenate(
            [lengths, jnp.ones((pad,), jnp.int32)], axis=0)
    scores, dists = _score_call(xs, xlens, bank, lengths, sx, sxx, band,
                                bk, interpret)
    return scores[:, :k], dists[:, :k]


def score_bank_offline(xs, xlens, bank, lengths, sx, sxx,
                       band: Optional[int] = None,
                       interpret: Optional[bool] = None):
    """Backend-defaulted entry: compiled on TPU, interpret elsewhere."""
    from ..common import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    return score_bank_offline_kernel(xs, xlens, bank, lengths, sx, sxx,
                                     band=band, interpret=interpret)


def _score_var_kernel(xlen_ref, sx_ref, sxx_ref, vstats_ref, x_ref, vx_ref,
                      len_ref, bank_ref, score_ref, prob_ref, dist_ref, *,
                      n: int, m: int, band: Optional[int],
                      threshold: float, approx: bool = False):
    """Variance-carrying twin of :func:`_score_kernel`: six moment slabs
    ([6, BK, M]: sy, syy, sxy, svy, svyy, svxy — each variance channel's
    delta is ``v_i *`` the matching base delta) plus an in-kernel
    probabilistic reduction (``core.dtw._prob_from_moments``, the single
    shared probability tail) beside the point score.

    ``approx=True`` is the calibration twin of the approx serving tick:
    FOUR slabs (only svy rides beside the base three) and the
    ``core.dtw._prob_from_moments_approx`` reduction — the offline
    oracle the approx tick's probabilities are pinned against."""
    from ...core.dtw import (_corr_from_moments, _prob_from_moments,
                             _prob_from_moments_approx)

    nch = 4 if approx else 6

    xlen = xlen_ref[0]
    x = x_ref[0]                                   # [N]
    xv = vx_ref[0]                                 # [N]
    bank = bank_ref[...]                           # [BK, M]
    bk = bank.shape[0]
    lens = len_ref[...]                            # [BK]
    jj = jax.lax.iota(jnp.int32, m)
    yc = bank - _MOM_SHIFT
    yy = yc * yc

    def body(i, carry):
        row, moms = carry                          # [BK, M], [6, BK, M]
        d = jnp.abs(x[i] - bank)
        if band is not None:
            centers = (i * (lens - 1)) // jnp.maximum(xlen - 1, 1)
            d = jnp.where(jnp.abs(jj[None, :] - centers[:, None]) <= band,
                          d, _INF)
        corner = jnp.where(i == 0, 0.0, _INF)
        p_diag = jnp.concatenate(
            [jnp.broadcast_to(corner, (bk, 1)).astype(row.dtype),
             row[:, :-1]], axis=1)
        p_vert = row
        mn = jnp.minimum(p_vert, p_diag)
        new = _minplus_scan2(d, mn + d, m)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        new = jnp.minimum(new, _INF)
        p_horiz = jnp.concatenate(
            [jnp.full((bk, 1), _INF, new.dtype), new[:, :-1]], axis=1)
        sel_diag = p_diag <= jnp.minimum(p_vert, p_horiz)
        sel_vert = jnp.logical_and(~sel_diag, p_vert <= p_horiz)
        anch = jnp.logical_or(sel_diag, sel_vert)
        m_diag = jnp.concatenate(
            [jnp.zeros((nch, bk, 1), moms.dtype), moms[:, :, :-1]], axis=2)
        base = jnp.where(sel_diag[None], m_diag,
                         jnp.where(sel_vert[None], moms, 0.0))
        base = _fill_from_anchor(base, anch, m)
        xm = x[i] - _MOM_SHIFT
        dm = jnp.stack([yc, yy, xm * yc])
        new_moms = base + jnp.concatenate([dm, xv[i] * dm[:nch - 3]],
                                          axis=0)
        valid = i < xlen
        return (jnp.where(valid, new, row),
                jnp.where(valid, new_moms, moms))

    row0 = jnp.full((bk, m), _INF, jnp.float32)
    moms0 = jnp.zeros((nch, bk, m), jnp.float32)
    row, moms = jax.lax.fori_loop(0, n, body, (row0, moms0))

    onehot = jj[None, :] == (lens - 1)[:, None]              # [BK, M]
    dist = jnp.sum(jnp.where(onehot, row, 0.0), axis=1)
    msel = jnp.sum(jnp.where(onehot[None], moms, 0.0), axis=2)  # [nch, BK]
    nn = jnp.maximum(xlen, 1).astype(jnp.float32)
    scores = _corr_from_moments(msel[0], msel[1], msel[2], sx_ref[0],
                                sxx_ref[0], nn)
    if approx:
        probs = _prob_from_moments_approx(
            msel[0], msel[1], msel[2], msel[3],
            sx_ref[0], sxx_ref[0], vstats_ref[0, 0], vstats_ref[0, 1],
            vstats_ref[0, 2], nn, jnp.float32(threshold))
    else:
        probs = _prob_from_moments(
            msel[0], msel[1], msel[2], msel[3], msel[4], msel[5],
            sx_ref[0], sxx_ref[0], vstats_ref[0, 0], vstats_ref[0, 1],
            vstats_ref[0, 2], nn, jnp.float32(threshold))
    score_ref[0] = jnp.where(xlen > 0, scores, 0.0)
    prob_ref[0] = jnp.where(xlen > 0, probs, 0.0)
    dist_ref[0] = dist


@functools.partial(jax.jit,
                   static_argnames=("band", "threshold", "block_k",
                                    "interpret", "approx"))
def _score_var_call(xs, xvars, xlens, bank, lengths, sx, sxx, vstats,
                    band: Optional[int], threshold: float, block_k: int,
                    interpret: bool, approx: bool = False):
    j, n = xs.shape
    k, m = bank.shape
    kernel = functools.partial(_score_var_kernel, n=n, m=m, band=band,
                               threshold=threshold, approx=approx)
    scores, probs, dists = pl.pallas_call(
        kernel,
        grid=(j, k // block_k),
        in_specs=[
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # xlen
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # sx
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # sxx
            pl.BlockSpec((1, 3), lambda ji, ki: (ji, 0)),      # vstats
            pl.BlockSpec((1, n), lambda ji, ki: (ji, 0)),      # query
            pl.BlockSpec((1, n), lambda ji, ki: (ji, 0)),      # variances
            pl.BlockSpec((block_k,), lambda ji, ki: (ki,)),    # lengths
            pl.BlockSpec((block_k, m), lambda ji, ki: (ki, 0)),  # bank
        ],
        out_specs=[
            pl.BlockSpec((1, block_k), lambda ji, ki: (ji, ki)),
            pl.BlockSpec((1, block_k), lambda ji, ki: (ji, ki)),
            pl.BlockSpec((1, block_k), lambda ji, ki: (ji, ki)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, k), jnp.float32),
            jax.ShapeDtypeStruct((j, k), jnp.float32),
            jax.ShapeDtypeStruct((j, k), jnp.float32),
        ],
        interpret=interpret,
    )(xlens, sx, sxx, vstats, xs, xvars, lengths, bank)
    return scores, probs, dists


def score_bank_offline_var_kernel(xs, xvars, xlens, bank, lengths, sx,
                                  sxx, vstats,
                                  band: Optional[int] = None,
                                  threshold: float = 0.9,
                                  block_k: int = 128,
                                  interpret: bool = True,
                                  approx: bool = False):
    """Closed-end scores + match probabilities + endpoint distances of J
    uncertain queries vs the whole bank — one pallas_call.

    As :func:`score_bank_offline_kernel` plus ``xvars`` [J, N] per-sample
    variances and ``vstats`` [J, 3] = (sv, svx, svxx) folds
    (``core.dtw.query_var_moments``) -> (scores, probs, dists) [J, K],
    with ``probs`` = P[true warp correlation >= ``threshold``].
    ``approx=True`` runs the four-slab single-proxy variant (see
    :func:`score_bank_offline_var_approx_kernel`).
    """
    xs = jnp.asarray(xs, jnp.float32)
    xvars = jnp.asarray(xvars, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    xlens = jnp.asarray(xlens, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    sx = jnp.asarray(sx, jnp.float32)
    sxx = jnp.asarray(sxx, jnp.float32)
    vstats = jnp.asarray(vstats, jnp.float32)
    k, m = bank.shape
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        bank = jnp.concatenate(
            [bank, jnp.zeros((pad, m), jnp.float32)], axis=0)
        lengths = jnp.concatenate(
            [lengths, jnp.ones((pad,), jnp.int32)], axis=0)
    scores, probs, dists = _score_var_call(
        xs, xvars, xlens, bank, lengths, sx, sxx, vstats, band,
        float(threshold), bk, interpret, approx=approx)
    return scores[:, :k], probs[:, :k], dists[:, :k]


def score_bank_offline_var_approx_kernel(xs, xvars, xlens, bank, lengths,
                                         sx, sxx, vstats,
                                         band: Optional[int] = None,
                                         threshold: float = 0.9,
                                         block_k: int = 128,
                                         interpret: bool = True):
    """Approx-tail offline scorer: FOUR moment slabs (sy, syy, sxy, svy)
    and the ``core.dtw._prob_from_moments_approx`` reduction — the
    calibration harness's offline oracle for the approx serving tick
    (the verdict path keeps :func:`score_bank_offline_var_kernel`).
    Same signature and returns as the exact variant."""
    return score_bank_offline_var_kernel(
        xs, xvars, xlens, bank, lengths, sx, sxx, vstats, band=band,
        threshold=threshold, block_k=block_k, interpret=interpret,
        approx=True)
