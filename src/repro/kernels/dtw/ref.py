"""Pure-numpy O(N*M) oracle for the DTW kernel (paper Eq. 1-2)."""

from __future__ import annotations

import numpy as np

__all__ = ["dtw_matrix_ref"]


def dtw_matrix_ref(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n, m = len(x), len(y)
    D = np.empty((n, m), np.float64)
    for i in range(n):
        for j in range(m):
            d = abs(x[i] - y[j])
            if i == 0 and j == 0:
                D[i, j] = d
            elif i == 0:
                D[i, j] = D[i, j - 1] + d
            elif j == 0:
                D[i, j] = D[i - 1, j] + d
            else:
                D[i, j] = d + min(D[i - 1, j], D[i, j - 1], D[i - 1, j - 1])
    return D
