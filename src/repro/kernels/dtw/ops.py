"""Jitted public API for the batched DTW kernel."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..common import default_interpret
from .kernel import dtw_matrix_kernel

__all__ = ["dtw_batched", "dtw_distances"]


def dtw_batched(x, ys, interpret: Optional[bool] = None):
    """Query x [N] against references ys [K, M] -> D matrices [K, N, M]."""
    interpret = default_interpret() if interpret is None else interpret
    return dtw_matrix_kernel(x, ys, interpret=interpret)


def dtw_distances(x, ys, interpret: Optional[bool] = None):
    """-> similarity distances D(N, M) per reference, shape [K]."""
    D = dtw_batched(x, ys, interpret=interpret)
    return D[:, -1, -1]
