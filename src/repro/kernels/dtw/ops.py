"""Jitted public API for the batched DTW kernel.

All entry points are whole-bank batched: one ``pallas_call`` (one grid of
wavefront programs) covers every reference — or every (query, reference)
pair — so matching the entire reference DB is a single device dispatch.
``lengths`` vectors carry the true (pre-padding) series lengths; distances
are read at the dynamic column ``lengths[k] - 1``, which padding can never
influence (D[i, j] depends only on cells (<=i, <=j)).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..common import default_interpret
from .kernel import dtw_matrix_kernel, dtw_matrix_pairs_kernel

__all__ = ["dtw_batched", "dtw_batched_pairs", "dtw_distances",
           "dtw_distances_pairs"]


def dtw_batched(x, ys, interpret: Optional[bool] = None):
    """Query x [N] against references ys [K, M] -> D matrices [K, N, M]."""
    interpret = default_interpret() if interpret is None else interpret
    return dtw_matrix_kernel(x, ys, interpret=interpret)


def dtw_batched_pairs(xs, ys, interpret: Optional[bool] = None):
    """Pairwise queries xs [K, N] vs references ys [K, M] -> [K, N, M]."""
    interpret = default_interpret() if interpret is None else interpret
    return dtw_matrix_pairs_kernel(xs, ys, interpret=interpret)


def _lengths_or_full(lengths, k: int, m: int):
    """int32 [K] true-length vector; defaults to the full padded width."""
    return jnp.full((k,), m, jnp.int32) if lengths is None \
        else jnp.asarray(lengths, jnp.int32)


def _last_valid(D, row_idx, col_idx):
    """D [K, N, M] -> D[k, row_idx[k], col_idx[k]] per pair."""
    k = D.shape[0]
    Dk = jnp.take_along_axis(
        D, row_idx.reshape(k, 1, 1).astype(jnp.int32), axis=1)[:, 0, :]
    return jnp.take_along_axis(
        Dk, col_idx.reshape(k, 1).astype(jnp.int32), axis=1)[:, 0]


def dtw_distances(x, ys, interpret: Optional[bool] = None, *, lengths=None):
    """-> similarity distances D(N, len_k) per reference, shape [K].

    ``lengths`` (keyword-only int [K], so pre-existing positional
    ``interpret`` callers keep working) gives each padded reference row's
    true length; omitted means every row uses the full width M."""
    D = dtw_batched(x, ys, interpret=interpret)
    if lengths is None:
        return D[:, -1, -1]
    ls = jnp.asarray(lengths, jnp.int32)
    rows = jnp.full((D.shape[0],), D.shape[1] - 1, jnp.int32)
    return _last_valid(D, rows, ls - 1)


def dtw_distances_pairs(xs, ys, xlens=None, ylens=None,
                        interpret: Optional[bool] = None):
    """-> distances D(xlen_k, ylen_k) per (query, reference) pair, [K]."""
    D = dtw_batched_pairs(xs, ys, interpret=interpret)
    k = D.shape[0]
    ql = _lengths_or_full(xlens, k, D.shape[1])
    rl = _lengths_or_full(ylens, k, D.shape[2])
    return _last_valid(D, ql - 1, rl - 1)
