"""Pallas TPU kernel: streaming bank-extend (the online-matching tick).

One service tick advances J in-flight streaming DPs (one per slot) by one
chunk of C samples against the whole padded [K, M] reference bank.  The
jnp reference (``core.dtw._bank_extend_many``) re-materializes a [J, K, M]
cost slab in HBM for every sample; here the grid is (job, reference-tile)
and each program keeps its [BK, M] DP row slice in VMEM across the entire
chunk — C row updates run back-to-back on-chip, with exactly one HBM read
and one HBM write of the row slice per tick.

Each row update is the same min-plus (tropical semiring) Hillis-Steele
scan as the offline wavefront kernel (``kernel.py``): the in-row
dependence D[i, j] = d[i, j] + min(m_j, D[i, j-1]) is an affine map in
(min, +), so a row solves in log2(M) shift+min steps on the VPU lanes.

Semantics mirror ``_bank_extend_many`` cell-for-cell (the tests pin this
on ragged banks, Sakoe-Chiba bands, and arbitrary chunkings):

* the virtual corner D[-1, -1] = 0 enters as the shifted-in value of a
  job's very first sample only (``ns == 0``);
* samples at or beyond ``nvalid[j]`` are padding and leave the row
  untouched (ragged per-job chunks);
* the banded variant re-derives each reference's Sakoe-Chiba center from
  its true length and the job's expected query length every row.

Two kernels share the row-update machinery:

* :func:`stream_bank_extend` — the distance-only tick (the large-K
  throughput mode): one [BK, M] DP row slice per program.
* :func:`stream_bank_extend_scored` — the FUSED scoring tick: the same
  program additionally pins the three warp-path correlation-moment slabs
  (sy, syy, sxy) of the DP row in VMEM and carries them through the DP
  with backtrack-identical predecessor selection (argmin over diag /
  vert / horiz with ``core.dtw.backtrack``'s tie order — diag first,
  then vert).  The horizontal moment recurrence m(i, j) = m(i, j-1) -
  pair(j-1) + pair(j) telescopes along a horizontal run to m(i, j) =
  base(j0) + pair(j), where j0 is the run's anchor (the nearest
  non-horiz cell at or left of j), so a row's moments solve in one
  log2(M) anchored forward-fill instead of a sequential column walk —
  the same depth as the min-plus distance scan.  Cell values and moments
  match ``core.dtw._bank_extend_diag_impl`` cell-for-cell (pinned by
  tests/test_kernels.py); the open-end score reduction stays outside the
  kernel (``core.dtw.bank_extend_tick_scored_dispatch`` fuses it into
  the same jit).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental import pallas as pl

__all__ = ["stream_bank_extend_kernel", "stream_bank_extend",
           "stream_bank_extend_scored_kernel", "stream_bank_extend_scored"]

_INF = 3.0e38  # plain float: jnp scalars become captured consts in Pallas

#: Center for the correlation moments — MUST match ``core.dtw._MOM_SHIFT``
#: (the jnp twin) so the two scored paths accumulate identical slabs.
_MOM_SHIFT = 0.5


def _minplus_scan2(a: jax.Array, s: jax.Array, m_len: int) -> jax.Array:
    """Row-batched twin of ``kernel._minplus_scan``: inclusive Hillis-
    Steele scan of the min-plus affine maps f_j(c) = min(c + a_j, s_j)
    along the last axis of [BK, M] blocks."""
    n_steps = int(np.ceil(np.log2(max(m_len, 2))))
    for t in range(n_steps):
        off = 1 << t
        a_l = jnp.pad(a, ((0, 0), (off, 0)), constant_values=0.0)[:, :-off]
        s_l = jnp.pad(s, ((0, 0), (off, 0)), constant_values=_INF)[:, :-off]
        s = jnp.minimum(s_l + a, s)
        a = a_l + a
    return s


def _stream_kernel(ns_ref, nv_ref, ql_ref, x_ref, len_ref, rows_ref,
                   bank_ref, out_ref, *, c: int, m: int,
                   band: Optional[int]):
    """One (job, reference-tile) program: advance the [BK, M] DP row slice
    by up to ``c`` samples, entirely in VMEM."""
    n0 = ns_ref[0]
    nv = nv_ref[0]
    ql = ql_ref[0]
    x = x_ref[0]                                   # [C]
    bank = bank_ref[...]                           # [BK, M]
    bk = bank.shape[0]
    jj = jax.lax.iota(jnp.int32, m)

    def body(i, row):
        d = jnp.abs(x[i] - bank)                   # [BK, M]
        if band is not None:
            lens = len_ref[...]
            centers = ((n0 + i) * (lens - 1)) \
                // jnp.maximum(ql - 1, 1)          # [BK]
            d = jnp.where(jnp.abs(jj[None, :] - centers[:, None]) <= band,
                          d, _INF)
        # virtual corner D[-1, -1] = 0 for the job's first sample only
        corner = jnp.where((n0 == 0) & (i == 0), 0.0, _INF)
        shifted = jnp.concatenate(
            [jnp.broadcast_to(corner, (bk, 1)).astype(row.dtype),
             row[:, :-1]], axis=1)
        mn = jnp.minimum(row, shifted)
        new = _minplus_scan2(d, mn + d, m)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        # samples past nvalid are chunk padding: row passes through
        return jnp.where(i < nv, new, row)

    out_ref[0] = jax.lax.fori_loop(0, c, body, rows_ref[0])


def _fill_from_anchor(vals, anch, m_len: int):
    """Forward-fill each row of ``vals`` [..., M] from the nearest column
    at or left of it where ``anch`` is True (log2(M) Hillis-Steele steps).
    Column 0 is always anchored in our use (a DP row's first cell can
    never pick the horizontal predecessor), so every column resolves."""
    n_steps = int(np.ceil(np.log2(max(m_len, 2))))
    for t in range(n_steps):
        off = 1 << t
        v_l = jnp.pad(vals, [(0, 0)] * (vals.ndim - 1) + [(off, 0)],
                      constant_values=0.0)[..., :-off]
        a_l = jnp.pad(anch, ((0, 0), (off, 0)),
                      constant_values=False)[:, :-off]
        vals = jnp.where(anch[None] if vals.ndim == 3 else anch,
                         vals, v_l)
        anch = jnp.logical_or(anch, a_l)
    return vals


def _stream_scored_kernel(ns_ref, nv_ref, ql_ref, x_ref, *refs, c: int,
                          m: int, band: Optional[int],
                          variance: bool = False):
    """One (job, reference-tile) program of the FUSED tick: advance the
    [BK, M] DP row slice AND its [3, BK, M] warp-path moment slabs by up
    to ``c`` samples, entirely in VMEM.

    ``variance=True`` grows the slab and takes an extra per-sample
    variance ref right after the chunk ref: each variance channel's
    delta is ``v_i *`` the matching base channel's delta, so the
    identical anchored forward-fill carries them all (channels 0..2
    arithmetic is untouched — bit-identity with the three-channel
    kernel and the jnp wavefront is preserved).  Exact mode twins all
    three base channels ([6, BK, M]: sy, syy, sxy, svy, svyy, svxy);
    approx mode twins only sy ([4, BK, M]: ..., svy — the serving
    tick's single σ²-proxy, see ``core.dtw._prob_from_moments_approx``).
    The channel count is read off the slab shape, so ONE kernel serves
    both.

    Rows are clamped at ``_INF`` each update (like the wavefront jnp twin)
    so predecessor selection ties resolve identically in saturated
    regions; the moments of saturated cells are don't-care (no finite
    path can descend from them) but stay finite."""
    if variance:
        (vx_ref, len_ref, rows_ref, moms_ref, bank_ref,
         out_ref, mout_ref) = refs
        vx = vx_ref[0]                             # [C]
    else:
        len_ref, rows_ref, moms_ref, bank_ref, out_ref, mout_ref = refs
    n0 = ns_ref[0]
    nv = nv_ref[0]
    ql = ql_ref[0]
    x = x_ref[0]                                   # [C]
    bank = bank_ref[...]                           # [BK, M]
    bk = bank.shape[0]
    jj = jax.lax.iota(jnp.int32, m)
    yc = bank - _MOM_SHIFT                         # centered reference
    yy = yc * yc

    def body(i, carry):
        row, moms = carry                          # [BK, M], [nch, BK, M]
        d = jnp.abs(x[i] - bank)
        if band is not None:
            lens = len_ref[...]
            centers = ((n0 + i) * (lens - 1)) \
                // jnp.maximum(ql - 1, 1)
            d = jnp.where(jnp.abs(jj[None, :] - centers[:, None]) <= band,
                          d, _INF)
        corner = jnp.where((n0 == 0) & (i == 0), 0.0, _INF)
        p_diag = jnp.concatenate(
            [jnp.broadcast_to(corner, (bk, 1)).astype(row.dtype),
             row[:, :-1]], axis=1)
        p_vert = row
        mn = jnp.minimum(p_vert, p_diag)
        new = _minplus_scan2(d, mn + d, m)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        new = jnp.minimum(new, _INF)
        # predecessor selection on the finished row: the horizontal
        # predecessor D[i, j-1] is the new row shifted right one column.
        p_horiz = jnp.concatenate(
            [jnp.full((bk, 1), _INF, new.dtype), new[:, :-1]], axis=1)
        sel_diag = p_diag <= jnp.minimum(p_vert, p_horiz)
        sel_vert = jnp.logical_and(~sel_diag, p_vert <= p_horiz)
        anch = jnp.logical_or(sel_diag, sel_vert)
        # anchor cells read their predecessor's moments directly (the
        # virtual corner / first-sample boundary shifts in zeros)...
        m_diag = jnp.concatenate(
            [jnp.zeros((moms.shape[0], bk, 1), moms.dtype),
             moms[:, :, :-1]], axis=2)
        base = jnp.where(sel_diag[None], m_diag,
                         jnp.where(sel_vert[None], moms, 0.0))
        # ...horizontal runs telescope to base(anchor) + pair(j): fill
        # each run from its anchor, then add this cell's aligned pair.
        base = _fill_from_anchor(base, anch, m)
        xm = x[i] - _MOM_SHIFT
        dm = jnp.stack([yc, yy, xm * yc])
        if variance:
            # exact mode twins all three base deltas (6 channels);
            # approx mode only sy (4 channels) — shape-driven.
            dm = jnp.concatenate(
                [dm, vx[i] * dm[:moms.shape[0] - 3]], axis=0)
        new_moms = base + dm
        valid = i < nv
        return (jnp.where(valid, new, row),
                jnp.where(valid, new_moms, moms))

    row0, moms0 = jax.lax.fori_loop(0, c, body,
                                    (rows_ref[0], moms_ref[0]))
    out_ref[0] = row0
    mout_ref[0] = moms0


@functools.partial(jax.jit,
                   static_argnames=("band", "block_k", "interpret"))
def _stream_scored_call(rows, moms, ns, bank, lengths, chunks, nvalid,
                        qlens, band: Optional[int], block_k: int,
                        interpret: bool, vchunks=None):
    j, k, m = rows.shape
    c = chunks.shape[1]
    nch = moms.shape[1]                   # 3, or 6 in variance mode
    variance = vchunks is not None
    kernel = functools.partial(_stream_scored_kernel, c=c, m=m, band=band,
                               variance=variance)
    in_specs = [
        pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # ns
        pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # nvalid
        pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # qlens
        pl.BlockSpec((1, c), lambda ji, ki: (ji, 0)),      # chunk
    ]
    operands = [ns, nvalid, qlens, chunks]
    if variance:
        in_specs.append(pl.BlockSpec((1, c), lambda ji, ki: (ji, 0)))
        operands.append(vchunks)                           # variances
    in_specs += [
        pl.BlockSpec((block_k,), lambda ji, ki: (ki,)),    # lengths
        pl.BlockSpec((1, block_k, m),
                     lambda ji, ki: (ji, ki, 0)),          # rows
        pl.BlockSpec((1, nch, block_k, m),
                     lambda ji, ki: (ji, 0, ki, 0)),       # moments
        pl.BlockSpec((block_k, m), lambda ji, ki: (ki, 0)),  # bank
    ]
    operands += [lengths, rows, moms, bank]
    new_rows, new_moms = pl.pallas_call(
        kernel,
        grid=(j, k // block_k),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_k, m), lambda ji, ki: (ji, ki, 0)),
            pl.BlockSpec((1, nch, block_k, m),
                         lambda ji, ki: (ji, 0, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, k, m), jnp.float32),
            jax.ShapeDtypeStruct((j, nch, k, m), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return new_rows, new_moms, ns + nvalid


@functools.partial(jax.jit,
                   static_argnames=("band", "block_k", "interpret"))
def _stream_call(rows, ns, bank, lengths, chunks, nvalid, qlens,
                 band: Optional[int], block_k: int, interpret: bool):
    j, k, m = rows.shape
    c = chunks.shape[1]
    kernel = functools.partial(_stream_kernel, c=c, m=m, band=band)
    new_rows = pl.pallas_call(
        kernel,
        grid=(j, k // block_k),
        in_specs=[
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # ns
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # nvalid
            pl.BlockSpec((1,), lambda ji, ki: (ji,)),          # qlens
            pl.BlockSpec((1, c), lambda ji, ki: (ji, 0)),      # chunk
            pl.BlockSpec((block_k,), lambda ji, ki: (ki,)),    # lengths
            pl.BlockSpec((1, block_k, m),
                         lambda ji, ki: (ji, ki, 0)),          # rows
            pl.BlockSpec((block_k, m), lambda ji, ki: (ki, 0)),  # bank
        ],
        out_specs=pl.BlockSpec((1, block_k, m),
                               lambda ji, ki: (ji, ki, 0)),
        out_shape=jax.ShapeDtypeStruct((j, k, m), jnp.float32),
        interpret=interpret,
    )(ns, nvalid, qlens, chunks, lengths, rows, bank)
    return new_rows, ns + nvalid


def stream_bank_extend_kernel(rows, ns, bank, lengths, chunks, nvalid,
                              qlens, band: Optional[int] = None,
                              block_k: int = 128, interpret: bool = True):
    """Advance J streaming DPs by one padded chunk — one pallas_call.

    rows [J, K, M] f32; ns/nvalid/qlens [J] i32; bank [K, M] f32;
    lengths [K] i32; chunks [J, C] f32 -> (rows [J, K, M], ns [J]).
    The reference bank is tiled ``block_k`` rows per grid program; K is
    padded up internally when it does not divide evenly (padding rows can
    never influence real rows — every cell update is per-reference).
    """
    rows = jnp.asarray(rows, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    chunks = jnp.asarray(chunks, jnp.float32)
    ns = jnp.asarray(ns, jnp.int32)
    nvalid = jnp.asarray(nvalid, jnp.int32)
    qlens = jnp.asarray(qlens, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    j, k, m = rows.shape
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((j, pad, m), _INF, jnp.float32)], axis=1)
        bank = jnp.concatenate(
            [bank, jnp.zeros((pad, m), jnp.float32)], axis=0)
        lengths = jnp.concatenate(
            [lengths, jnp.ones((pad,), jnp.int32)], axis=0)
    new_rows, ns2 = _stream_call(rows, ns, bank, lengths, chunks, nvalid,
                                 qlens, band, bk, interpret)
    return new_rows[:, :k], ns2


def stream_bank_extend(rows, ns, bank, lengths, chunks, nvalid, qlens,
                       band: Optional[int] = None,
                       interpret: Optional[bool] = None):
    """Backend-defaulted entry: compiled on TPU, interpret elsewhere."""
    from ..common import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    return stream_bank_extend_kernel(rows, ns, bank, lengths, chunks,
                                     nvalid, qlens, band=band,
                                     interpret=interpret)


def stream_bank_extend_scored_kernel(rows, moms, ns, bank, lengths, chunks,
                                     nvalid, qlens,
                                     band: Optional[int] = None,
                                     block_k: int = 128,
                                     interpret: bool = True,
                                     vchunks=None):
    """Advance J streaming DPs AND their warp-path correlation moments by
    one padded chunk — one pallas_call.

    rows [J, K, M] f32; moms [3, J, K, M] f32 (sy, syy, sxy slabs of the
    current DP row's cells); other args as
    :func:`stream_bank_extend_kernel`.  Returns ``(rows, moms, ns)`` with
    the same layouts.  Variance mode: pass ``vchunks`` [J, C] per-sample
    variances with a SIX-channel ``moms`` [6, J, K, M] (sy, syy, sxy,
    svy, svyy, svxy) for the exact tail, or a FOUR-channel [4, J, K, M]
    (sy, syy, sxy, svy) for the approx serving tick — the extra slabs
    ride the same VMEM row-scan.  The
    open-end score reduction over the returned slabs lives in
    ``core.dtw`` (``bank_extend_tick_scored[_var]_dispatch``) so the
    moment semantics stay defined in exactly one place.
    """
    rows = jnp.asarray(rows, jnp.float32)
    moms = jnp.asarray(moms, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    chunks = jnp.asarray(chunks, jnp.float32)
    ns = jnp.asarray(ns, jnp.int32)
    nvalid = jnp.asarray(nvalid, jnp.int32)
    qlens = jnp.asarray(qlens, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if vchunks is not None:
        vchunks = jnp.asarray(vchunks, jnp.float32)
        if moms.shape[0] not in (4, 6):
            raise ValueError("variance mode needs a six-channel (exact) "
                             "or four-channel (approx) moment slab, got "
                             f"{moms.shape[0]} channels")
    j, k, m = rows.shape
    nch = moms.shape[0]
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((j, pad, m), _INF, jnp.float32)], axis=1)
        moms = jnp.concatenate(
            [moms, jnp.zeros((nch, j, pad, m), jnp.float32)], axis=2)
        bank = jnp.concatenate(
            [bank, jnp.zeros((pad, m), jnp.float32)], axis=0)
        lengths = jnp.concatenate(
            [lengths, jnp.ones((pad,), jnp.int32)], axis=0)
    new_rows, new_moms, ns2 = _stream_scored_call(
        rows, moms.transpose(1, 0, 2, 3), ns, bank, lengths, chunks,
        nvalid, qlens, band, bk, interpret, vchunks=vchunks)
    return (new_rows[:, :k], new_moms.transpose(1, 0, 2, 3)[:, :, :k],
            ns2)


def stream_bank_extend_scored(rows, moms, ns, bank, lengths, chunks,
                              nvalid, qlens, band: Optional[int] = None,
                              interpret: Optional[bool] = None):
    """Backend-defaulted entry for the fused scoring tick."""
    from ..common import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    return stream_bank_extend_scored_kernel(rows, moms, ns, bank, lengths,
                                            chunks, nvalid, qlens,
                                            band=band, interpret=interpret)
