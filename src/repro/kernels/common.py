"""Shared kernel utilities."""

from __future__ import annotations

import jax

__all__ = ["default_interpret"]


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU hosts (this container) they run in
    interpret mode, which executes the kernel body in Python for
    correctness validation against the ref.py oracles."""
    return jax.default_backend() != "tpu"
