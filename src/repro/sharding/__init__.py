from .rules import (ExecConfig, param_specs, cache_specs, batch_specs,
                    opt_state_specs, make_shard_fn, logical_batch_axes)

__all__ = ["ExecConfig", "param_specs", "cache_specs", "batch_specs",
           "opt_state_specs", "make_shard_fn", "logical_batch_axes"]
