from .compat import shard_map
from .rules import (ExecConfig, param_specs, cache_specs, batch_specs,
                    opt_state_specs, make_shard_fn, logical_batch_axes)

__all__ = ["shard_map", "ExecConfig", "param_specs", "cache_specs",
           "batch_specs", "opt_state_specs", "make_shard_fn",
           "logical_batch_axes"]
