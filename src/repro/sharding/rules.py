"""Sharding rules: map parameter/cache/batch pytrees to PartitionSpecs.

Layout (Megatron-TP x DP, optional FSDP and sequence-parallel residuals):

* column-parallel projections  [d_in, d_out] -> (fsdp, "model")
* row-parallel projections     [d_in, d_out] -> ("model", fsdp)
* embedding table [V, D] -> ("model", fsdp);  unembed [D, V] -> (fsdp, "model")
* expert weights [E, a, b] -> ("model", fsdp, None)   (EP over "model")
* KV caches: batch over data axes when divisible, else sequence over data
  (long-context decode with batch=1); kv-heads/latent dim over "model".

Every axis assignment is guarded by divisibility — a dimension that does
not divide the mesh axis stays replicated, so every (arch x shape x mesh)
cell lowers without manual per-arch spec tables.  ``ExecConfig`` carries
the execution parameters the paper's AutoTuner transfers between matched
workloads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ..models.config import ModelConfig

__all__ = ["ExecConfig", "param_specs", "cache_specs", "batch_specs",
           "opt_state_specs", "make_shard_fn", "logical_batch_axes"]


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Tunable execution parameters (the framework analogue of the paper's
    {M, R, FS, I} — what the AutoTuner profiles over and transfers)."""
    fsdp: bool = False                 # shard params over data axes too
    zero1: bool = True                 # shard optimizer state over data axes
    remat: str = "none"                # "none" | "dots" | "full"
    seq_shard_activations: bool = False  # Megatron sequence parallelism
    microbatch: int = 1                # gradient-accumulation steps
    optim_dtype: str = "float32"       # AdamW moment dtype
    grad_compress: str = "none"        # "none" | "bf16" (cross-pod)
    logits_fp32: bool = False          # keep logits bf16 unless set
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    blockwise_threshold: int = 4096    # online-softmax attn when S >= this
    moe_expert_tp: bool = False        # serving: shard expert FFN dim over
                                       # data axes, replicate tokens (small
                                       # decode batches), no weight gathers

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExecConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def logical_batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes: ("pod", "data") on multi-pod, ("data",)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0 and dim >= size


def _guard(spec_axes, shape, mesh: Mesh):
    """Drop axis assignments that don't divide; pad to rank with None."""
    out = []
    for i, dim in enumerate(shape):
        ax = spec_axes[i] if i < len(spec_axes) else None
        out.append(ax if _div(dim, mesh, ax) else None)
    return P(*out)


_COL = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "w_gate",
        "w_up", "w_in", "in_proj", "up_proj", "w_gates", "router"}
_ROW = {"wo", "w_down", "out_proj", "down_proj"}


def _param_rule(path: Tuple[str, ...], shape, mesh: Mesh, fsdp_axes,
                expert_tp_axes=None):
    names = [p for p in path]
    leaf_ctx = names[-2] if len(names) >= 2 else ""
    container = set(names)

    base: Tuple = ()
    if "experts" in container:                   # [E, a, b]
        if expert_tp_axes is not None:
            # serving expert-TP: FFN dim over data axes (w_gate/w_up:
            # [E, D, F] dim 2; w_down: [E, F, D] dim 1)
            if names[-1] == "w_down":
                base = ("model", expert_tp_axes, None)
            else:
                base = ("model", None, expert_tp_axes)
        else:
            base = ("model", fsdp_axes, None)
    elif leaf_ctx == "router":
        base = (None, None)
    elif "table" in names[-1:]:                   # embedding [V, D]
        base = ("model", fsdp_axes)
    elif "unembed" == leaf_ctx:                   # [D, V]
        base = (fsdp_axes, "model")
    elif leaf_ctx in _COL:
        base = (fsdp_axes, "model")
    elif leaf_ctx in _ROW:
        base = ("model", fsdp_axes)
    elif names[-1] == "conv_w":                   # [K, C]
        base = (None, "model")
    elif len(shape) == 1:
        base = ("model",) if _div(shape[0], mesh, "model") and shape[0] >= 1024 \
            else (None,)
    return base


def _path_names(keypath) -> Tuple[str, ...]:
    names = []
    for k in keypath:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params_shape, cfg: ModelConfig, mesh: Mesh,
                exec_cfg: ExecConfig):
    """PartitionSpec pytree mirroring ``params_shape`` (eval_shape output)."""
    fsdp_axes = logical_batch_axes(mesh) if exec_cfg.fsdp else None
    expert_tp_axes = (logical_batch_axes(mesh)
                      if getattr(exec_cfg, "moe_expert_tp", False) else None)

    def rule(keypath, leaf):
        names = _path_names(keypath)
        shape = leaf.shape
        stacked = "segments" in names           # leading scan-layer dim
        inner_shape = shape[1:] if stacked else shape
        base = _param_rule(names, inner_shape, mesh, fsdp_axes,
                           expert_tp_axes)
        spec = _guard(base, inner_shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_state_specs(params_shape, param_spec_tree, mesh: Mesh,
                    exec_cfg: ExecConfig):
    """Optimizer-moment specs: parameter specs + ZeRO-1 sharding of the
    first still-replicated divisible dim over the data axes."""
    if not exec_cfg.zero1:
        return param_spec_tree
    daxes = logical_batch_axes(mesh)

    def rule(leaf_shape, spec):
        parts = list(spec) + [None] * (len(leaf_shape.shape) - len(spec))
        if exec_cfg.fsdp:
            return P(*parts)
        for i, (dim, ax) in enumerate(zip(leaf_shape.shape, parts)):
            if ax is None and _div(dim, mesh, daxes):
                parts[i] = daxes
                break
        return P(*parts)

    return jax.tree.map(rule, params_shape, param_spec_tree)


def cache_specs(cache_shape, cfg: ModelConfig, mesh: Mesh, batch: int):
    """Decode/prefill cache specs.  Seq-shard when batch can't shard."""
    daxes = logical_batch_axes(mesh)
    batch_ok = _div(batch, mesh, daxes)

    def rule(keypath, leaf):
        names = _path_names(keypath)
        shape = leaf.shape  # [L, ...block shape...]
        inner = shape[1:]
        leafname = names[-1]
        spec: list = [None] * len(inner)
        # batch is dim 0 of the inner shape for every cache kind
        if batch_ok and len(inner) >= 1:
            spec[0] = daxes
        if leafname in ("k", "v"):                # [B, S, KV, dh]
            if not batch_ok and _div(inner[1], mesh, daxes):
                spec[1] = daxes                   # sequence-sharded cache
            if _div(inner[2], mesh, "model"):
                spec[2] = "model"                 # kv heads over model
            elif _div(inner[1], mesh, "model") and spec[1] is None:
                spec[1] = "model"                 # else sequence over model
                                                  # (never dh: contraction)
        elif leafname in ("c_kv", "k_rope"):      # [B, S, r]
            if not batch_ok and _div(inner[1], mesh, daxes):
                spec[1] = daxes
            if _div(inner[1], mesh, "model") and spec[1] is None:
                spec[1] = "model"                 # MLA latent cache: seq/TP
        elif leafname == "ssm":                   # [B, H, dk, dv]
            if _div(inner[1], mesh, "model"):
                spec[1] = "model"
        elif leafname == "conv":                  # [B, K-1, C]
            if _div(inner[2], mesh, "model"):
                spec[2] = "model"
        return P(None, *spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def batch_specs(batch_shape, mesh: Mesh):
    """Input batch: leading batch dim over data axes when divisible."""
    daxes = logical_batch_axes(mesh)

    def rule(keypath, leaf):
        names = _path_names(keypath)
        if leaf.ndim == 0:
            return P()
        if names and names[-1] == "positions" and leaf.ndim == 3:
            # m-rope positions [3, B, S]
            ok = _div(leaf.shape[1], mesh, daxes)
            return P(None, daxes if ok else None, None)
        ok = _div(leaf.shape[0], mesh, daxes)
        return P(daxes if ok else None, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def make_shard_fn(mesh: Mesh, exec_cfg: ExecConfig, batch: int):
    """Activation sharding-constraint callback for model.apply."""
    daxes = logical_batch_axes(mesh)
    bsz = 1
    for a in daxes:
        bsz *= mesh.shape[a]
    batch_ok = batch % bsz == 0 and batch >= bsz
    baxis = daxes if batch_ok else None
    seq_axis = "model" if exec_cfg.seq_shard_activations else None

    from jax.sharding import NamedSharding

    def shard(x, kind: str):
        if kind == "heads" and x.ndim == 4:
            # [B, S, H, dh]: heads over "model" when divisible; NEVER the
            # head_dim — it is the q.k contraction dim and sharding it
            # turns every attention tile into an all-reduce (measured:
            # +4e11 B/chip on minitron train_4k, EXPERIMENTS.md §Perf).
            m = mesh.shape["model"]
            ha = "model" if x.shape[2] % m == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, None, ha, None)))
        if kind == "heads_bhs" and x.ndim == 4:
            # [B, H, S, d] (SSM/GLA layout): H over "model" when divisible,
            # else the channel dim — unlike softmax attention, the GLA
            # chunk contraction produces only a small per-chunk
            # [B,H,L,L] partial (psum'd), while the state/value tensors
            # shard, so channel sharding is a net win here.
            m = mesh.shape["model"]
            ha = "model" if x.shape[1] % m == 0 else None
            da = "model" if ha is None and x.shape[3] % m == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, ha, None, da)))
        if kind == "ffn" and x.ndim == 3:
            m = mesh.shape["model"]
            fa = "model" if x.shape[-1] % m == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, None, fa)))
        if kind == "full_seq" and x.ndim == 3:
            # gather point for sequence parallelism: force the all-gather
            # to happen on this (bf16) tensor, not on a downstream f32
            # upcast (measured 2x collective volume otherwise)
            if seq_axis is None:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, None, None)))
        if kind == "resid" and x.ndim == 3:
            sa = seq_axis if seq_axis and x.shape[1] % mesh.shape["model"] == 0 \
                else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, sa, None)))
        if kind == "logits" and x.ndim == 3:
            va = "model" if x.shape[-1] % mesh.shape["model"] == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxis, None, va)))
        return x

    return shard
