"""Version-tolerant wrappers over jax APIs that moved between releases.

One copy of the workaround: ``shard_map`` graduated from
``jax.experimental.shard_map`` to ``jax.shard_map`` (and its ``check_rep``
flag was renamed ``check_vma``) across jax releases.  Both the MoE
expert-parallel path (``models.moe``) and the sharded streaming-matcher
tick (``serve.tuning``) go through this shim so a jax upgrade is a
one-file fix.

Replication checking is disabled in every branch: the expert-parallel
psum pattern and the replicated-scalar outputs of the tick fan-out are
not representable to the checker.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.[experimental.]shard_map`` with whatever signature this jax
    ships; replication checking off."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
