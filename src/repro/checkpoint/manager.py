"""Fault-tolerant checkpointing with elastic re-mesh restore.

Layout (one directory per step)::

    <root>/step_000120/
        manifest.json        # treedef paths, shapes, dtypes, metadata, hash
        arrays.npz           # one entry per leaf
    <root>/LATEST            # atomic pointer file

Writes are two-phase (tmp dir + ``os.replace``) so a preempted writer can
never corrupt the latest checkpoint — the restart path always finds either
the previous step or the completed new one.  Restore takes an *optional
mesh + PartitionSpec tree*: leaves are ``jax.device_put`` onto the new
sharding, so restoring onto a different pod count / mesh shape (elastic
rescale after node failure) is the same code path as same-mesh restore.

On a real multi-host cluster the arrays.npz entry per leaf becomes one
object per (leaf, shard) written by the shard's host — the manifest format
already carries everything needed; the single-host container collapses
shards into whole arrays.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "load_checkpoint_tree",
           "CheckpointManager"]

_STEP_RE = re.compile(r"step_(\d+)$")


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


def _leaf_paths(tree: Any) -> Optional[List[str]]:
    """Flattened "a/b/c" key paths when every container in ``tree`` is a
    dict (the self-describing case a target-free restore can rebuild);
    None for any other pytree."""
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, _ in paths:
        parts = []
        for k in kp:
            if not isinstance(k, jax.tree_util.DictKey):
                return None
            parts.append(str(k.key))
        out.append("/".join(parts))
    return out


def save_checkpoint(root: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None) -> str:
    """Two-phase atomic write.  Returns the checkpoint directory."""
    os.makedirs(root, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {_leaf_key(i): np.asarray(leaf) for i, leaf in enumerate(leaves)}

    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        digest = hashlib.sha256()
        for k in sorted(arrays):
            digest.update(k.encode())
            digest.update(np.ascontiguousarray(arrays[k]).tobytes()[:4096])
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(a.shape) for a in arrays.values()],
            "dtypes": [str(a.dtype) for a in arrays.values()],
            # present iff the tree is dict-nested: lets a reader rebuild
            # the tree WITHOUT a matching target (the recovery path,
            # where leaf shapes depend on crashed-service state the
            # restorer cannot know a priori).
            "leaf_paths": _leaf_paths(tree),
            "metadata": metadata or {},
            "content_hash": digest.hexdigest(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(root, f"step_{step:06d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    fd, ptr_tmp = tempfile.mkstemp(dir=root)
    with os.fdopen(fd, "w") as f:
        f.write(f"step_{step:06d}")
    os.replace(ptr_tmp, os.path.join(root, "LATEST"))
    return final


def _verify(manifest: Dict, arrays) -> None:
    digest = hashlib.sha256()
    for k in sorted(arrays.files):
        digest.update(k.encode())
        digest.update(np.ascontiguousarray(arrays[k]).tobytes()[:4096])
    if digest.hexdigest() != manifest["content_hash"]:
        raise IOError("checkpoint content hash mismatch (corrupt write?)")


def _complete_steps(root: str) -> List[int]:
    """Step numbers whose directory holds a manifest — i.e. checkpoints
    whose two-phase write COMPLETED.  A step dir without a manifest is a
    torn artifact (an interrupted writer, a partial copy) and must never
    be selected for restore."""
    out = []
    for d in os.listdir(root):
        m = _STEP_RE.search(d)
        if m and os.path.isfile(os.path.join(root, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def _resolve_step_dir(root: str, step: Optional[int]) -> str:
    """Checkpoint dir for ``step`` (latest when None).  The LATEST
    pointer is a hint, not an authority: if it is missing or names a dir
    without a manifest (torn write, pointer from a crashed writer), fall
    back to the newest COMPLETE step dir."""
    if step is not None:
        return os.path.join(root, f"step_{step:06d}")
    try:
        with open(os.path.join(root, "LATEST")) as f:
            d = f.read().strip()
        if os.path.isfile(os.path.join(root, d, "manifest.json")):
            return os.path.join(root, d)
    except FileNotFoundError:
        pass
    steps = _complete_steps(root)
    if not steps:
        raise FileNotFoundError(f"no complete checkpoint under {root}")
    return os.path.join(root, f"step_{steps[-1]:06d}")


def restore_checkpoint(root: str, target: Any, step: Optional[int] = None,
                       mesh=None, specs: Any = None,
                       verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  With ``mesh``+``specs``, leaves are placed onto
    NamedSharding(mesh, spec) — elastic re-mesh restore."""
    path = _resolve_step_dir(root, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    if verify:
        _verify(manifest, arrays)

    leaves, treedef = jax.tree.flatten(target)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(f"leaf count mismatch: target {len(leaves)} vs "
                         f"checkpoint {manifest['n_leaves']}")
    spec_leaves = (jax.tree.flatten(specs)[0] if specs is not None
                   else [None] * len(leaves))

    out = []
    for i, (tgt, spec) in enumerate(zip(leaves, spec_leaves)):
        a = arrays[_leaf_key(i)]
        if tuple(a.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch at leaf {i}: {a.shape} vs "
                             f"{tgt.shape}")
        if mesh is not None and spec is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec)
            out.append(jax.device_put(a.astype(tgt.dtype), sharding))
        else:
            out.append(jax.numpy.asarray(a.astype(tgt.dtype)))
    return jax.tree.unflatten(treedef, out), manifest


def load_checkpoint_tree(root: str, step: Optional[int] = None,
                         verify: bool = True) -> Tuple[Dict, Dict]:
    """Target-free restore of a dict-nested checkpoint: rebuild the
    nested dict from the manifest's ``leaf_paths`` with host numpy
    leaves.  This is the recovery-from-crash entry point — the restorer
    cannot supply a shape-matching target because the leaf shapes (slot
    capacity, packed bank width, per-job buffers) are precisely the
    crashed state being recovered.  Returns ``(tree, manifest)``."""
    path = _resolve_step_dir(root, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("leaf_paths") is None:
        raise ValueError(
            "checkpoint was not saved from a dict-nested tree; use "
            "restore_checkpoint with a target instead")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    if verify:
        _verify(manifest, arrays)
    tree: Dict = {}
    for i, p in enumerate(manifest["leaf_paths"]):
        node = tree
        parts = p.split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = np.array(arrays[_leaf_key(i)])
    return tree, manifest


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, exposes resume."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def steps(self) -> List[int]:
        """COMPLETE checkpoint steps only: a step dir without its
        manifest (interrupted writer) is invisible here, so
        ``latest_step()`` can never select a torn checkpoint."""
        return _complete_steps(self.root)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> str:
        path = save_checkpoint(self.root, step, tree, metadata)
        self._gc()
        return path

    def restore(self, target: Any, step: Optional[int] = None, mesh=None,
                specs: Any = None) -> Tuple[Any, Dict]:
        return restore_checkpoint(self.root, target, step=step, mesh=mesh,
                                  specs=specs)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:06d}"),
                          ignore_errors=True)
        for d in os.listdir(self.root):
            full = os.path.join(self.root, d)
            # torn artifacts from interrupted writers: orphaned two-phase
            # tmp dirs (no live save holds one here — _gc runs between
            # saves) and manifest-less step dirs steps() refuses to list.
            if d.startswith(".tmp_ckpt_") and os.path.isdir(full):
                shutil.rmtree(full, ignore_errors=True)
            m = _STEP_RE.search(d)
            if m and os.path.isdir(full) and \
                    not os.path.isfile(os.path.join(full, "manifest.json")):
                shutil.rmtree(full, ignore_errors=True)
