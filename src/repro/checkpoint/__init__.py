from .manager import (CheckpointManager, load_checkpoint_tree,
                      restore_checkpoint, save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "load_checkpoint_tree"]
