"""Similarity measurement (paper §3.1.3, Eq. 3) and the matching phase
(paper Fig. 4-b).

After DTW aligns reference series Y into Y' (same length as query X), the
similarity is the correlation coefficient CORR(X, Y'); ``CORR >= 0.9`` is
an acceptable match (threshold set empirically in the paper).  The matching
phase compares the new application's series, per configuration-parameter
set, with every database application's series for the *same* parameter set,
and declares the application with the highest number of >=0.9 wins the most
similar.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from . import dtw as _dtw
from . import filters as _filters

__all__ = ["correlation", "similarity", "MatchResult", "match_series", "match_application"]

#: Paper §3.1.3: acceptable-match threshold.
MATCH_THRESHOLD = 0.9


def correlation(x: np.ndarray, y: np.ndarray) -> float:
    """Pearson correlation coefficient between equal-length series."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    xc = x - x.mean()
    yc = y - y.mean()
    denom = np.sqrt((xc * xc).sum() * (yc * yc).sum())
    if denom < 1e-12:
        return 1.0 if np.allclose(x, y) else 0.0
    return float((xc * yc).sum() / denom)


def similarity(x: np.ndarray, y: np.ndarray, *, preprocess: bool = False,
               band: Optional[int] = None) -> float:
    """SIM(X, Y) in [0, 1]: DTW-align Y to X, then CORR(X, Y').

    ``preprocess=True`` runs the paper's Chebyshev de-noise + [0,1]
    normalization on both series first.
    """
    if preprocess:
        x = np.asarray(_filters.preprocess(np.asarray(x, np.float32)))
        y = np.asarray(_filters.preprocess(np.asarray(y, np.float32)))
    yp, _ = _dtw.dtw_warp(x, y, band=band)
    return float(np.clip(correlation(x, yp), 0.0, 1.0))


@dataclasses.dataclass
class MatchResult:
    """Outcome of the matching phase for one query application."""
    best: Optional[str]                 # app with most >=threshold wins
    wins: Mapping[str, int]             # per-app count of matched param sets
    scores: Mapping[str, Sequence[float]]  # per-app CORR per param set
    threshold: float = MATCH_THRESHOLD


def match_series(query: np.ndarray, references: Mapping[str, np.ndarray],
                 *, preprocess: bool = True, band: Optional[int] = None
                 ) -> Mapping[str, float]:
    """Similarity of one query series against named reference series."""
    return {name: similarity(query, ref, preprocess=preprocess, band=band)
            for name, ref in references.items()}


def match_application(query_series: Sequence[np.ndarray],
                      reference_series: Mapping[str, Sequence[np.ndarray]],
                      *, threshold: float = MATCH_THRESHOLD,
                      preprocess: bool = True,
                      band: Optional[int] = None) -> MatchResult:
    """Paper Fig. 4-b: per parameter set j, score the query's series j
    against every reference app's series j; an app scores a *win* when its
    CORR is the highest of all apps AND >= threshold.  The app with the
    most wins is the match."""
    napps = {name: len(s) for name, s in reference_series.items()}
    nsets = len(query_series)
    for name, k in napps.items():
        if k != nsets:
            raise ValueError(f"{name} has {k} series, query has {nsets}")

    scores = {name: [] for name in reference_series}
    wins = {name: 0 for name in reference_series}
    for j in range(nsets):
        best_name, best_corr = None, -1.0
        for name, series in reference_series.items():
            c = similarity(query_series[j], series[j],
                           preprocess=preprocess, band=band)
            scores[name].append(c)
            if c > best_corr:
                best_name, best_corr = name, c
        if best_name is not None and best_corr >= threshold:
            wins[best_name] += 1

    best = max(wins, key=lambda k: wins[k]) if wins else None
    if best is not None and wins[best] == 0:
        best = None
    return MatchResult(best=best, wins=wins, scores=scores, threshold=threshold)
