"""Similarity measurement (paper §3.1.3, Eq. 3) and the matching phase
(paper Fig. 4-b).

After DTW aligns reference series Y into Y' (same length as query X), the
similarity is the correlation coefficient CORR(X, Y'); ``CORR >= 0.9`` is
an acceptable match (threshold set empirically in the paper).  The matching
phase compares the new application's series, per configuration-parameter
set, with every database application's series for the *same* parameter set,
and declares the application with the highest number of >=0.9 wins the most
similar.

Similarity scores are the **raw** Pearson correlation in [-1, 1]:
anti-correlated references score negative instead of being clipped to 0, so
callers can see *how* wrong a candidate is; the 0.9 threshold comparison is
the only place a clamp semantically happens.

Batched bank layout (the hot path)
----------------------------------
Scoring one query against K references used to dispatch one jitted DTW per
pair from a Python loop — O(K) device round-trips.  The batched path packs
all references into a padded ``[K, M]`` bank with an ``int32 [K]`` vector
of true lengths (``database.SeriesBank`` / ``pack_series``; padding repeats
each series' edge value and never reaches a DTW distance) and scores the
whole bank **matrix-free and device-resident**: the warp-path correlation
moments (sy, syy, sxy) are carried *through* the DP with
backtrack-identical predecessor selection and read at the closed alignment
endpoint ``(N-1, len_k-1)`` (``dtw.dtw_score_bank`` / ``dtw_score_pairs``;
the Pallas offline kernel ``kernels.dtw.score`` on TPU backends), so one
dispatch returns the final [K] correlations directly — no ``[K, N, M]``
matrix is ever materialized and nothing per-cell crosses the device
boundary:

* :func:`similarity_bank` — one matrix-free scorer dispatch for all K
  references.  ``matrix_path=True`` keeps the previous engine (batched
  ``dtw_matrix_bank`` + O(N+M) host-side backtracking per reference) as
  the debugging/reference path; it is also what ``dtw.dtw_warp``
  consumers should reach for when they need the D matrix itself.
* :func:`match_series` — dict-of-references convenience wrapper over
  :func:`similarity_bank`.
* :func:`match_application` — batches every (parameter set, application)
  pair of Fig. 4-b into a single ``dtw.dtw_score_pairs`` dispatch, ragged
  on both the query and reference sides.
* :func:`prefix_similarity_bank` — scores a *partial* (in-flight) query
  from streamed DP rows: open-ended alignment + running-moment correlation
  while the job runs.  Its closed-end branch (``open_end=False`` with
  ``band=`` passed) routes to the matrix-free scorer too — exactly what
  ``tuner.OnlineMatcher.final_scores`` does on completion.

Device scores and the host backtrack agree bitwise-path on tie-free
(dyadic-grid) data and to warp-path-tie tolerance elsewhere (float noise
can flip near-tie argmin choices, moving individual warp paths but not
match decisions; ``tests/test_scored_matching.py`` pins both regimes).
The matrix path chunks very large banks so the ``[K, N, M]`` stack stays
under ``MAX_MATRIX_ELEMS`` elements per dispatch; the matrix-free path
needs no such cap.  The scalar :func:`similarity` remains the reference
implementation and the right tool for one-off pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from . import dtw as _dtw
from . import filters as _filters
from .database import SeriesBank, pack_series

__all__ = ["correlation", "similarity", "similarity_bank", "MatchResult",
           "match_series", "match_application", "MATCH_THRESHOLD",
           "RunningMoments", "prefix_similarity_bank"]

#: Paper §3.1.3: acceptable-match threshold.
MATCH_THRESHOLD = 0.9

#: Chunk bound for the [K, N, M] accumulated-cost stack of one dispatch
#: (2**27 f32 elements = 512 MiB).  Typical DB banks fit in one chunk.
MAX_MATRIX_ELEMS = 1 << 27


def correlation(x: np.ndarray, y: np.ndarray) -> float:
    """Pearson correlation coefficient between equal-length series."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    xc = x - x.mean()
    yc = y - y.mean()
    vx = (xc * xc).sum()
    vy = (yc * yc).sum()
    # Relative degeneracy guard: catastrophic cancellation on a constant
    # series leaves a residue proportional to the uncentered energy, not an
    # absolute epsilon, so an absolute cutoff misses it at larger
    # magnitudes and the 0/0 would poison downstream ranking.
    degx = vx <= 1e-10 * (x * x).sum() + 1e-12
    degy = vy <= 1e-10 * (y * y).sum() + 1e-12
    if degx or degy:
        return 1.0 if np.allclose(x, y) else 0.0
    return float((xc * yc).sum() / np.sqrt(vx * vy))


def similarity(x: np.ndarray, y: np.ndarray, *, preprocess: bool = False,
               band: Optional[int] = None) -> float:
    """SIM(X, Y) in [-1, 1]: DTW-align Y to X, then CORR(X, Y').

    ``preprocess=True`` runs the paper's Chebyshev de-noise + [0,1]
    normalization on both series first.  The raw correlation is returned
    (anti-correlation is information, not noise); compare against
    :data:`MATCH_THRESHOLD` to decide acceptability.
    """
    if preprocess:
        x = np.asarray(_filters.preprocess(np.asarray(x, np.float32)))
        y = np.asarray(_filters.preprocess(np.asarray(y, np.float32)))
    yp, _ = _dtw.dtw_warp(x, y, band=band)
    return float(np.clip(correlation(x, yp), -1.0, 1.0))


# ---------------------------------------------------------------------------
# Batched bank scoring
# ---------------------------------------------------------------------------

def _as_bank(references: Union[SeriesBank, np.ndarray, Sequence[np.ndarray]],
             lengths: Optional[np.ndarray]) -> SeriesBank:
    if isinstance(references, SeriesBank):
        if lengths is not None:
            raise ValueError("lengths is implied by the SeriesBank")
        return references
    if isinstance(references, np.ndarray):
        if references.ndim != 2:
            # iterating a 1-D array here would silently pack K one-sample
            # series; make the porting mistake loud instead.
            raise ValueError(
                f"references array must be [K, M], got shape "
                f"{references.shape}; wrap a single series in a list")
        if lengths is None:
            lengths = np.full((references.shape[0],), references.shape[1],
                              np.int32)
        return SeriesBank(np.asarray(references, np.float32),
                          np.asarray(lengths, np.int32))
    # ragged sequence of 1-D series: each element's own length is
    # authoritative — a lengths vector here would be silently wrong.
    if lengths is not None:
        raise ValueError("lengths only applies to a padded 2-D bank; pass "
                         "a [K, M] array (or a SeriesBank) with it")
    return pack_series(list(references))


def _warp_corr(x: np.ndarray, y: np.ndarray, D: np.ndarray) -> float:
    """Host-side Eq. 3 tail: backtrack D, warp Y to Y', correlate."""
    path = _dtw.backtrack(D)
    yp = _dtw.warp_to(y, path, len(x))
    return float(np.clip(correlation(np.asarray(x, np.float64), yp),
                         -1.0, 1.0))


def similarity_bank(x: np.ndarray,
                    references: Union[SeriesBank, np.ndarray,
                                      Sequence[np.ndarray]],
                    lengths: Optional[np.ndarray] = None, *,
                    preprocess: bool = False,
                    band: Optional[int] = None,
                    matrix_path: bool = False) -> np.ndarray:
    """SIM(X, Y_k) for every reference in a bank -> float64 [K].

    Default engine: the matrix-free closed-end moment scorer
    (``dtw.dtw_score_bank``) — one device dispatch returns all K warp
    correlations with no ``[K, N, M]`` materialization and no host
    backtracking; the bank's tiled device upload is memoized on the
    :class:`SeriesBank` (``score_plan``), so repeated verdicts against
    the same bank move no bank bytes.

    ``matrix_path=True`` selects the previous engine — one batched
    ``dtw.dtw_matrix_bank`` dispatch, then O(N+M) host-side backtracking
    + correlation per reference — kept as the reference/debug path; the
    two agree bitwise-path on tie-free data and to warp-path-tie
    tolerance (~1e-3) elsewhere.

    ``preprocess=True`` applies the paper pipeline to the query (scalar)
    and the whole bank (``filters.preprocess_bank``: one dispatch per
    distinct series length, row-identical to the scalar pipeline).
    """
    bank = _as_bank(references, lengths)
    x = np.asarray(x, np.float32).reshape(-1)
    if len(bank) == 0:
        return np.zeros((0,), np.float64)
    if preprocess:
        x = np.asarray(_filters.preprocess(x))
        # memoized on the source bank: repeated preprocess=True calls
        # reuse one filtered pack and one score-plan device upload.
        bank = bank.preprocessed()

    if not matrix_path:
        return np.asarray(_dtw.dtw_score_bank(
            x, bank.series, bank.lengths, band=band,
            plan=bank.score_plan()), np.float64)

    k, m = bank.series.shape
    n = x.shape[0]
    chunk = max(1, int(MAX_MATRIX_ELEMS // max(n * m, 1)))
    out = np.empty((k,), np.float64)
    for lo in range(0, k, chunk):
        hi = min(lo + chunk, k)
        D = np.asarray(_dtw.dtw_matrix_bank(
            x, bank.series[lo:hi], bank.lengths[lo:hi], band=band))
        for r in range(lo, hi):
            l = int(bank.lengths[r])
            out[r] = _warp_corr(x, bank.series[r, :l], D[r - lo, :, :l])
    return out


# ---------------------------------------------------------------------------
# Prefix (streaming) scoring
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunningMoments:
    """Single-pass correlation accumulator over aligned sample pairs.

    The streaming scorer re-derives the warp path every tick (it can change
    as the prefix grows) but correlates along it in one pass with these
    running moments instead of the offline two-pass :func:`correlation`;
    float64 accumulators keep the two within ~1e-7 on [0, 1] utilization
    series.  Degenerate (constant) series follow :func:`correlation`'s
    convention: 1.0 when the pair is (all-close) identical, else 0.0.
    """
    n: int = 0
    sx: float = 0.0
    sy: float = 0.0
    sxx: float = 0.0
    syy: float = 0.0
    sxy: float = 0.0

    def update(self, x: np.ndarray, y: np.ndarray) -> "RunningMoments":
        x = np.asarray(x, np.float64).reshape(-1)
        y = np.asarray(y, np.float64).reshape(-1)
        self.n += x.shape[0]
        self.sx += float(x.sum())
        self.sy += float(y.sum())
        self.sxx += float((x * x).sum())
        self.syy += float((y * y).sum())
        self.sxy += float((x * y).sum())
        return self

    @property
    def corr(self) -> float:
        if self.n == 0:
            return 0.0
        vx = max(self.sxx - self.sx * self.sx / self.n, 0.0)
        vy = max(self.syy - self.sy * self.sy / self.n, 0.0)
        # Relative degeneracy guard (see :func:`correlation`): cancellation
        # residue on constant series scales with the uncentered moments.
        degx = vx <= 1e-10 * (self.sxx + self.sx * self.sx / self.n) + 1e-12
        degy = vy <= 1e-10 * (self.syy + self.sy * self.sy / self.n) + 1e-12
        if degx or degy:
            mean_close = abs(self.sx - self.sy) / self.n < 1e-6
            return 1.0 if degx and degy and mean_close else 0.0
        cov = self.sxy - self.sx * self.sy / self.n
        return float(np.clip(cov / np.sqrt(vx * vy), -1.0, 1.0))


#: "No band argument given" sentinel for prefix_similarity_bank — the
#: caller's streamed rows already embed whatever banding the stream used,
#: so only an EXPLICIT band (None included) licenses the rows-free
#: matrix-free closed-end path.
_BAND_UNSET = object()


def prefix_similarity_bank(x_prefix: np.ndarray, bank: SeriesBank,
                           rows: Optional[np.ndarray] = None, *,
                           open_end: bool = True,
                           band=_BAND_UNSET) -> np.ndarray:
    """SIM of a *partial* query against every reference -> float64 [K].

    ``rows`` is the [n, K, M] stack of streamed DP rows (what
    ``dtw.dtw_bank_extend(..., collect_rows=True)`` hands back, accumulated
    across chunks) — the accumulated-cost matrix of the consumed prefix.
    With ``open_end=True`` each reference is scored against its best
    matching *prefix* (backtrack from ``argmin`` of the last DP row — the
    open-ended alignment of online DTW); with ``open_end=False`` the full
    reference endpoint ``len_k - 1`` is used, which on a completed query
    reproduces the offline :func:`similarity_bank` score (same DP, same
    predecessor selection, single-pass accumulation).

    The closed-end branch is **matrix-free** when ``band`` is passed
    explicitly (``None`` meaning "unbanded"): the query is re-scored by
    the device-resident moment scorer (``dtw.dtw_score_bank``) with the
    Sakoe-Chiba corridor re-derived from the true query length, and
    ``rows`` may be omitted entirely — this is the
    ``OnlineMatcher.final_scores`` path.  Without an explicit band the
    streamed ``rows`` (which already embed the stream's banding) are
    backtracked on the host as before.
    """
    x = np.asarray(x_prefix, np.float64).reshape(-1)
    if not open_end and band is not _BAND_UNSET:
        return np.asarray(_dtw.dtw_score_bank(
            x, bank.series, bank.lengths, band=band,
            plan=bank.score_plan()), np.float64)
    if rows is None:
        raise ValueError("rows are required unless scoring closed-end "
                         "with an explicit band= (the matrix-free path)")
    rows = np.asarray(rows)
    n, k, _ = rows.shape
    if n != x.shape[0]:
        raise ValueError(f"{x.shape[0]} query samples but {n} DP rows")
    out = np.empty((k,), np.float64)
    for r in range(k):
        l = int(bank.lengths[r])
        D = rows[:, r, :l]
        j_end = int(np.argmin(D[-1])) if open_end else l - 1
        path = _dtw.backtrack(D[:, : j_end + 1])
        yp = _dtw.warp_to(bank.series[r, : j_end + 1], path, n)
        out[r] = RunningMoments().update(x, yp).corr
    return out


@dataclasses.dataclass
class MatchResult:
    """Outcome of the matching phase for one query application."""
    best: Optional[str]                 # app with most >=threshold wins
    wins: Mapping[str, int]             # per-app count of matched param sets
    scores: Mapping[str, Sequence[float]]  # per-app raw CORR per param set
    threshold: float = MATCH_THRESHOLD


def match_series(query: np.ndarray, references: Mapping[str, np.ndarray],
                 *, preprocess: bool = True, band: Optional[int] = None
                 ) -> Mapping[str, float]:
    """Similarity of one query series against named reference series.

    Batched: the whole reference set is scored with one DTW dispatch."""
    names = list(references)
    bank = pack_series([references[nm] for nm in names], labels=names)
    sims = similarity_bank(query, bank, preprocess=preprocess, band=band)
    return {nm: float(s) for nm, s in zip(names, sims)}


def match_application(query_series: Sequence[np.ndarray],
                      reference_series: Mapping[str, Sequence[np.ndarray]],
                      *, threshold: float = MATCH_THRESHOLD,
                      preprocess: bool = True,
                      band: Optional[int] = None) -> MatchResult:
    """Paper Fig. 4-b: per parameter set j, score the query's series j
    against every reference app's series j; an app scores a *win* when its
    CORR is the highest of all apps AND >= threshold.  The app with the
    most wins is the match.

    Every (parameter set, app) pair is solved in one batched
    ``dtw.dtw_matrix_pairs`` dispatch — ragged series on both sides ride in
    padded banks with true-length vectors."""
    names = list(reference_series)
    napps = {name: len(s) for name, s in reference_series.items()}
    nsets = len(query_series)
    for name, kk in napps.items():
        if kk != nsets:
            raise ValueError(f"{name} has {kk} series, query has {nsets}")
    if nsets == 0 or not names:
        wins = {name: 0 for name in names}
        return MatchResult(best=None, wins=wins,
                           scores={name: [] for name in names},
                           threshold=threshold)

    qbank = pack_series(list(query_series))
    rbank = pack_series([reference_series[name][j]
                         for name in names for j in range(nsets)])
    if preprocess:
        qbank = dataclasses.replace(qbank, series=np.asarray(
            _filters.preprocess_bank(qbank.series, qbank.lengths)))
        rbank = dataclasses.replace(rbank, series=np.asarray(
            _filters.preprocess_bank(rbank.series, rbank.lengths)))

    # pair p = (app a, set j) -> query row j, reference row a * nsets + j
    qidx = np.tile(np.arange(nsets), len(names))
    xs, xl = qbank.series[qidx], qbank.lengths[qidx]
    # matrix-free: every pair's closed-end warp correlation from ONE
    # moment-carrying dispatch — no [P, N, M] stack, no host backtracks.
    corr = np.asarray(_dtw.dtw_score_pairs(
        xs, rbank.series, xl, rbank.lengths, band=band), np.float64)

    scores = {name: [float(corr[a * nsets + j]) for j in range(nsets)]
              for a, name in enumerate(names)}
    wins = {name: 0 for name in names}
    for j in range(nsets):
        best_name = max(names, key=lambda nm: scores[nm][j])
        if scores[best_name][j] >= threshold:
            wins[best_name] += 1

    best = max(wins, key=lambda kk: wins[kk]) if wins else None
    if best is not None and wins[best] == 0:
        best = None
    return MatchResult(best=best, wins=wins, scores=scores,
                       threshold=threshold)
