"""Workload utilization signatures.

The paper samples CPU utilization with SysStat at 1 Hz while a job runs.
In this framework the equivalent observable is the *compute-utilization
trace of one compiled step*: we walk the jaxpr of the step function in
program order, assign every equation an estimated execution time on the
target chip::

    t_op = max(flops / peak_flops, bytes / hbm_bw)

and a utilization value ``u_op = (flops/peak) / t_op`` (1.0 = perfectly
compute-bound, ->0 = memory-bound), then sample the resulting
piecewise-constant utilization function at a fixed number of points.  The
series is then fed through the exact paper pipeline (Chebyshev de-noise,
[0,1] normalization, DTW + correlation matching).

``lax.scan`` bodies are expanded ``length`` times so the layer structure of
a model shows up as the periodic pattern the paper's SysStat traces show
for map/reduce waves.  The signature source is pluggable: on real hardware
the same pipeline ingests per-step SysStat/xprof samples instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax
import numpy as np

__all__ = ["ChipSpec", "TPU_V5E", "OpCost", "jaxpr_costs", "utilization_series",
           "signature_of"]


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float        # bf16 FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link


#: Target hardware for the whole repo (see system brief / EXPERIMENTS.md).
TPU_V5E = ChipSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


@dataclasses.dataclass
class OpCost:
    name: str
    flops: float
    bytes: float
    depth: int = 0


_TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "sin", "cos",
                   "rsqrt", "sqrt", "pow", "cbrt", "log1p", "expm1", "erf_inv"}
_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                       "branches", "fun_jaxpr")


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _aval_size(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (v.aval for v in eqn.invars[:2])
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    k = float(np.prod([lhs.shape[d] for d in lc], dtype=np.float64)) if lc else 1.0
    out = _aval_size(eqn.outvars[0].aval)
    return 2.0 * out * k


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval
    out = _aval_size(eqn.outvars[0].aval)
    # per output element: 2 * (kernel spatial x in-channels)
    k = float(np.prod(rhs.shape, dtype=np.float64)) / max(rhs.shape[-1], 1)
    return 2.0 * out * k


def _eqn_cost(eqn) -> Tuple[float, float]:
    """(flops, bytes) for one non-container equation."""
    name = eqn.primitive.name
    in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
    out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
    out_size = sum(_aval_size(v.aval) for v in eqn.outvars)
    if name == "dot_general":
        flops = _dot_flops(eqn)
    elif name == "conv_general_dilated":
        flops = _conv_flops(eqn)
    elif name in _TRANSCENDENTAL:
        flops = 4.0 * out_size
    elif name.startswith("reduce_") or name in ("argmax", "argmin"):
        flops = sum(_aval_size(v.aval) for v in eqn.invars if hasattr(v, "aval"))
    elif name in ("broadcast_in_dim", "reshape", "transpose", "convert_element_type",
                  "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
                  "gather", "scatter", "squeeze", "rev", "pad", "iota", "copy"):
        flops = 0.0
    else:
        flops = out_size
    return flops, in_bytes + out_bytes


def jaxpr_costs(jaxpr, depth: int = 0, _out: List[OpCost] = None) -> List[OpCost]:
    """Program-order per-op costs, expanding scan bodies ``length`` times."""
    out = [] if _out is None else _out
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            length = int(eqn.params["length"])
            body_costs = jaxpr_costs(body, depth + 1)
            # expand: the body repeats `length` times in program order
            reps = min(length, 64)  # cap expansion; scale cost to keep totals exact
            scale = length / reps
            for _ in range(reps):
                out.extend(OpCost(c.name, c.flops * scale, c.bytes * scale, c.depth)
                           for c in body_costs)
        elif name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            jaxpr_costs(body, depth + 1, out)
        elif name == "cond":
            branches = eqn.params["branches"]
            if branches:
                jaxpr_costs(branches[0].jaxpr, depth + 1, out)
        elif name in ("pjit", "custom_vjp_call", "custom_jvp_call", "remat",
                      "checkpoint", "custom_vjp_call_jaxpr", "closed_call"):
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
                or eqn.params.get("fun_jaxpr")
            if inner is not None:
                jaxpr_costs(getattr(inner, "jaxpr", inner), depth, out)
            else:
                flops, nbytes = _eqn_cost(eqn)
                out.append(OpCost(name, flops, nbytes, depth))
        else:
            flops, nbytes = _eqn_cost(eqn)
            out.append(OpCost(name, flops, nbytes, depth))
    return out


def utilization_series(costs: Sequence[OpCost], samples: int = 512,
                       chip: ChipSpec = TPU_V5E) -> np.ndarray:
    """Piecewise-constant utilization trace sampled at ``samples`` points.

    This is the framework analogue of the paper's 1 Hz SysStat CPU series.
    """
    if not costs:
        return np.zeros(samples, np.float32)
    t = np.array([max(c.flops / chip.peak_flops, c.bytes / chip.hbm_bw, 1e-12)
                  for c in costs])
    u = np.array([(c.flops / chip.peak_flops) / ti
                  for c, ti in zip(costs, t)])
    edges = np.concatenate([[0.0], np.cumsum(t)])
    total = edges[-1]
    sample_t = (np.arange(samples) + 0.5) * (total / samples)
    idx = np.clip(np.searchsorted(edges, sample_t, side="right") - 1, 0, len(u) - 1)
    return u[idx].astype(np.float32)


def signature_of(fn: Callable, *args: Any, samples: int = 512,
                 chip: ChipSpec = TPU_V5E, **kwargs: Any) -> np.ndarray:
    """Trace ``fn(*args)`` abstractly (no execution, ShapeDtypeStructs are
    fine) and return its utilization signature series."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    costs = jaxpr_costs(closed.jaxpr)
    return utilization_series(costs, samples=samples, chip=chip)
