"""Full-module HLO cost model with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` on the CPU backend reports each
``while`` body **once**, so a scan-over-layers model under-reports FLOPs by
~num_layers x.  The dry-run needs trustworthy roofline terms, so this
module parses the post-optimization (partitioned, per-device) HLO text and
computes:

* flops   — dots (2*prod(out)*K from ``lhs_contracting_dims``),
            convolutions, transcendentals, reductions, elementwise;
* bytes   — HBM traffic at fusion granularity: a fusion node costs its
            operands + outputs (fusion internals stay in registers/VMEM);
* collective bytes/counts — per opcode, largest shape on the line;

with every ``while`` body multiplied by its trip count (recovered from the
loop condition's ``compare(iv, constant)``), fusions attributed to their
call sites, and ``conditional`` branches averaged.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ModuleCost", "parse_module"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1,
    "f8e8m0fnu": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")

_TRANSCENDENTAL = {"exponential", "log", "tanh", "logistic", "rsqrt", "sqrt",
                   "power", "sine", "cosine", "erf", "atan2", "cbrt",
                   "log-plus-one", "exponential-minus-one"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier", "custom-call"}


def _shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dtype, shape))
    return out


def _shape_bytes(dtype: str, shape: Tuple[int, ...]) -> float:
    return float(np.prod(shape, dtype=np.float64)) * _DTYPE_BYTES[dtype] \
        if shape else _DTYPE_BYTES[dtype]


def _size(shape: Tuple[int, ...]) -> float:
    return float(np.prod(shape, dtype=np.float64)) if shape else 1.0


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    line: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    callees: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]]


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes: float
    transcendentals: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    tag_flops: Dict[str, float] = dataclasses.field(default_factory=dict)
    tag_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR_RE.match(line.strip())
        if m and ("->" in line):
            cur = Computation(name=m.group(1), instrs=[], symbols={})
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, result_txt, opcode, rest = mi.groups()
        result_shapes = _shapes(result_txt)
        # operands: %refs before any attribute like calls=/to_apply=
        arg_txt = rest.split("), ")[0] if "), " in rest else rest
        operands = _OPERAND_RE.findall(arg_txt)
        callees = _CALLS_RE.findall(rest)
        mb = _BRANCHES_RE.search(rest)
        if mb:
            callees += _OPERAND_RE.findall(mb.group(1))
        instr = Instr(name=name, opcode=opcode, line=line,
                      result_shapes=result_shapes, operands=operands,
                      callees=callees)
        cur.instrs.append(instr)
        cur.symbols[name] = result_shapes
    return comps, entry


def _result_bytes(shapes) -> float:
    return sum(_shape_bytes(d, s) for d, s in shapes)


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


class _Analyzer:
    def __init__(self, comps: Dict[str, Computation], tags: Tuple[str, ...] = ()):
        self.comps = comps
        self.tags = tags
        self._memo: Dict[Tuple[str, str], ModuleCost] = {}
        # computations called as fusion bodies / reductions: bytes don't count
        self.fusion_bodies = set()
        for c in comps.values():
            for ins in c.instrs:
                if ins.opcode in ("fusion", "reduce", "reduce-window", "sort",
                                  "all-reduce", "reduce-scatter", "scatter",
                                  "select-and-scatter", "map"):
                    self.fusion_bodies.update(ins.callees)

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        total = 0.0
        for op in ins.operands:
            shapes = comp.symbols.get(op)
            if shapes:
                total += _result_bytes(shapes)
        return total

    def _fusion_operand_bytes(self, comp: Computation, ins: Instr) -> float:
        """HBM reads of a fusion: a parameter that is only consumed by
        (dynamic-)slice / gather inside the body costs the slice result,
        not the full array (scan weight slices, KV-cache reads)."""
        body = self.comps.get(ins.callees[0]) if ins.callees else None
        if body is None:
            return self._operand_bytes(comp, ins)
        # map parameter index -> effective read bytes
        param_names = {}
        for bins in body.instrs:
            if bins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", bins.line)
                if m:
                    param_names[bins.name] = int(m.group(1))
        eff: Dict[int, float] = {}
        full: Dict[int, bool] = {}
        for bins in body.instrs:
            for oi, opname in enumerate(bins.operands):
                if opname not in param_names:
                    continue
                idx = param_names[opname]
                if bins.opcode in ("slice", "dynamic-slice", "gather") and oi == 0:
                    eff[idx] = eff.get(idx, 0.0) + _result_bytes(bins.result_shapes)
                elif bins.opcode == "dynamic-update-slice" and oi == 0:
                    upd = body.symbols.get(bins.operands[1]) if len(bins.operands) > 1 else None
                    eff[idx] = eff.get(idx, 0.0) + (_result_bytes(upd) if upd else 0.0)
                elif bins.opcode in ("get-tuple-element", "bitcast"):
                    full[idx] = True   # conservatively full if aliased onward
                else:
                    full[idx] = True
        total = 0.0
        for oi, op in enumerate(ins.operands):
            shapes = comp.symbols.get(op)
            if not shapes:
                continue
            sz = _result_bytes(shapes)
            if oi in eff and not full.get(oi, False):
                sz = min(sz, eff[oi])
            total += sz
        return total

    def _fusion_result_bytes(self, comp: Computation, ins: Instr) -> float:
        """HBM writes of a fusion: a root that is a dynamic-update-slice
        writes the update, not the whole buffer (in-place DUS)."""
        body = self.comps.get(ins.callees[0]) if ins.callees else None
        base = _result_bytes(ins.result_shapes)
        if body is None:
            return base
        for bins in body.instrs:
            if bins.opcode == "dynamic-update-slice" and "ROOT" in bins.line:
                upd = body.symbols.get(bins.operands[1]) if len(bins.operands) > 1 else None
                if upd:
                    return _result_bytes(upd)
        return base

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        consts = []
        for ins in comp.instrs:
            consts += [int(v) for v in _CONST_RE.findall(ins.line)]
        return float(max(consts)) if consts else 1.0

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out = _size(ins.result_shapes[0][1]) if ins.result_shapes else 0.0
        k = 1.0
        m = _LHS_CONTRACT_RE.search(ins.line)
        if m and ins.operands:
            lhs_shapes = comp.symbols.get(ins.operands[0])
            if lhs_shapes:
                lhs = lhs_shapes[0][1]
                dims = [int(d) for d in m.group(1).split(",") if d]
                for d in dims:
                    if d < len(lhs):
                        k *= lhs[d]
        return 2.0 * out * k

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        out = _size(ins.result_shapes[0][1]) if ins.result_shapes else 0.0
        if len(ins.operands) >= 2:
            rhs_shapes = comp.symbols.get(ins.operands[1])
            if rhs_shapes:
                rhs = rhs_shapes[0][1]
                # kernel: spatial... x in_ch x out_ch (out features last)
                k = _size(rhs) / max(rhs[-1], 1) if rhs else 1.0
                return 2.0 * out * k
        return 2.0 * out

    def _instr_cost(self, comp: Computation, ins: Instr,
                    inside_fusion: bool) -> ModuleCost:
        op = ins.opcode
        zero: Dict[str, float] = {}
        if op in _FREE_OPS:
            return ModuleCost(0.0, 0.0, 0.0, dict(zero), dict(zero))

        out_size = sum(_size(s) for _, s in ins.result_shapes)

        # containers -----------------------------------------------------
        if op == "while":
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            trips = self._trip_count(cond) if cond else 1.0
            inner = self.comp_cost(body) if body else ModuleCost(0, 0, 0, {}, {})
            return _scale(inner, trips)
        if op == "fusion":
            inner = ModuleCost(0, 0, 0, {}, {})
            for c in ins.callees:
                ic = self.comp_cost(c, inside_fusion=True)
                inner = _add(inner, ic)
            nbytes = (self._fusion_operand_bytes(comp, ins)
                      + self._fusion_result_bytes(comp, ins))
            return ModuleCost(inner.flops, 0.0 if inside_fusion else nbytes,
                              inner.transcendentals, inner.collective_bytes,
                              inner.collective_counts,
                              dict(inner.tag_flops), dict(inner.tag_bytes))
        if op in ("call", "conditional"):
            inner = ModuleCost(0, 0, 0, {}, {})
            if ins.callees:
                if op == "conditional":
                    branch = [self.comp_cost(c) for c in ins.callees]
                    n = max(len(branch), 1)
                    for b in branch:
                        inner = _add(inner, _scale(b, 1.0 / n))
                else:
                    for c in ins.callees:
                        inner = _add(inner, self.comp_cost(c))
            return inner

        # collectives ------------------------------------------------------
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                return ModuleCost(0, 0, 0, {}, {})
            sizes = [_shape_bytes(d, s) for d, s in _shapes(ins.line)]
            cb = max(sizes) if sizes else 0.0
            return ModuleCost(0.0, 0.0 if inside_fusion else cb, 0.0,
                              {base: cb}, {base: 1.0})

        # leaf compute -----------------------------------------------------
        if op == "dot":
            flops = self._dot_flops(comp, ins)
        elif op == "convolution":
            flops = self._conv_flops(comp, ins)
        elif op in _TRANSCENDENTAL:
            return ModuleCost(out_size, 0.0 if inside_fusion else
                              self._operand_bytes(comp, ins)
                              + _result_bytes(ins.result_shapes),
                              out_size, {}, {})
        elif op in ("reduce", "reduce-window"):
            in_shapes = comp.symbols.get(ins.operands[0]) if ins.operands else None
            flops = _size(in_shapes[0][1]) if in_shapes else out_size
        elif op in ("transpose", "reshape", "broadcast", "slice", "concatenate",
                    "pad", "reverse", "iota", "dynamic-slice",
                    "dynamic-update-slice", "gather", "scatter", "convert",
                    "select", "compare"):
            flops = 0.0
        else:
            flops = out_size
        if inside_fusion:
            nbytes = 0.0
        elif op in ("slice", "dynamic-slice", "gather"):
            nbytes = 2.0 * _result_bytes(ins.result_shapes)
        elif op == "dynamic-update-slice":
            upd = comp.symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
            nbytes = 2.0 * (_result_bytes(upd) if upd else 0.0)
        else:
            nbytes = (self._operand_bytes(comp, ins)
                      + _result_bytes(ins.result_shapes))
        return ModuleCost(flops, nbytes, 0.0, {}, {})

    def _tag_of(self, ins: Instr) -> Optional[str]:
        m = _OPNAME_RE.search(ins.line)
        if m:
            op_name = m.group(1)
            for tag in self.tags:
                if tag in op_name:
                    return tag
        # fusions: look for tagged ops inside the body (the fusion line's
        # metadata references a single representative op and often loses
        # the scope)
        if ins.opcode == "fusion" and ins.callees:
            body = self.comps.get(ins.callees[0])
            if body is not None:
                for bins in body.instrs:
                    mb = _OPNAME_RE.search(bins.line)
                    if mb:
                        for tag in self.tags:
                            if tag in mb.group(1):
                                return tag
        return None

    def _tagged(self, cost: ModuleCost, ins: Instr) -> ModuleCost:
        if not self.tags or (cost.flops == 0 and cost.bytes == 0):
            return cost
        tag = self._tag_of(ins)
        if tag is not None:
            # copy-on-write: the cost may alias a memoized computation
            cost = dataclasses.replace(
                cost, tag_flops=dict(cost.tag_flops),
                tag_bytes=dict(cost.tag_bytes))
            cost.tag_flops[tag] = cost.tag_flops.get(tag, 0.0) + cost.flops
            cost.tag_bytes[tag] = cost.tag_bytes.get(tag, 0.0) + cost.bytes
        return cost

    def comp_cost(self, name: str, inside_fusion: bool = False) -> ModuleCost:
        key = (name, "f" if inside_fusion else "t")
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        if comp is None:
            return ModuleCost(0, 0, 0, {}, {})
        total = ModuleCost(0, 0, 0, {}, {})
        self._memo[key] = total  # break cycles defensively
        for ins in comp.instrs:
            c = self._instr_cost(comp, ins, inside_fusion)
            if ins.opcode not in ("while", "call", "conditional"):
                c = self._tagged(c, ins)
            total = _add(total, c)
        self._memo[key] = total
        return total


def _merge(a: Dict[str, float], b: Dict[str, float]) -> Dict[str, float]:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


def _add(a: ModuleCost, b: ModuleCost) -> ModuleCost:
    return ModuleCost(a.flops + b.flops, a.bytes + b.bytes,
                      a.transcendentals + b.transcendentals,
                      _merge(a.collective_bytes, b.collective_bytes),
                      _merge(a.collective_counts, b.collective_counts),
                      _merge(a.tag_flops, b.tag_flops),
                      _merge(a.tag_bytes, b.tag_bytes))


def _scale(a: ModuleCost, s: float) -> ModuleCost:
    sc = lambda d: {k: v * s for k, v in d.items()}
    return ModuleCost(a.flops * s, a.bytes * s, a.transcendentals * s,
                      sc(a.collective_bytes), sc(a.collective_counts),
                      sc(a.tag_flops), sc(a.tag_bytes))


DEFAULT_TAGS = ("flash_tile", "moe_local", "gla_chunk", "attn", "mlp",
                "unembed", "adamw", "embed")


def parse_module(hlo_text: str, tags: Tuple[str, ...] = DEFAULT_TAGS
                 ) -> ModuleCost:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return ModuleCost(0, 0, 0, {}, {})
    an = _Analyzer(comps, tags=tags)
    # fusion bodies are only counted via their call sites: comp_cost(entry)
    # reaches them through fusion instrs, so just start at the entry.
    return an.comp_cost(entry)
