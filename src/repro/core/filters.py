"""Chebyshev type-I low-pass filtering of utilization time series.

The paper de-noises every captured CPU-utilization series with a 6th-order
low-pass Chebyshev filter before storing/matching (§3.1.1, §4).  We design
the filter ourselves (analog Chebyshev-I prototype -> frequency pre-warp ->
bilinear transform) so the hot path has no scipy dependency, and apply it
either with a lax.scan (direct-form-II-transposed, batched over series) or
with the Pallas IIR kernel in ``repro.kernels.iir``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cheby1_design",
    "lfilter",
    "filtfilt",
    "denoise",
    "normalize01",
    "preprocess",
    "preprocess_bank",
    "StreamingFilter",
]


# ---------------------------------------------------------------------------
# Filter design (numpy, runs once at trace time)
# ---------------------------------------------------------------------------

def _cheby1_analog_prototype(order: int, ripple_db: float):
    """Poles/gain of the analog Chebyshev-I prototype (cutoff 1 rad/s)."""
    if order < 1:
        raise ValueError("order must be >= 1")
    eps = np.sqrt(10.0 ** (0.1 * ripple_db) - 1.0)
    mu = np.arcsinh(1.0 / eps) / order
    k = np.arange(1, order + 1)
    theta = np.pi * (2.0 * k - 1.0) / (2.0 * order)
    poles = -np.sinh(mu) * np.sin(theta) + 1j * np.cosh(mu) * np.cos(theta)
    gain = np.real(np.prod(-poles))
    if order % 2 == 0:  # even order: passband sits at -ripple dB at DC
        gain /= np.sqrt(1.0 + eps * eps)
    return poles, gain


def cheby1_design(order: int, ripple_db: float, cutoff: float) -> Tuple[np.ndarray, np.ndarray]:
    """Digital Chebyshev-I low-pass ``(b, a)``.

    ``cutoff`` is the normalized cutoff in (0, 1), as a fraction of the
    Nyquist frequency (scipy convention).  Returns float64 coefficient
    arrays of length ``order + 1``.
    """
    if not 0.0 < cutoff < 1.0:
        raise ValueError(f"cutoff must be in (0,1), got {cutoff}")
    poles, gain = _cheby1_analog_prototype(order, ripple_db)

    # Pre-warp and scale the prototype (lp2lp), then bilinear transform.
    fs = 2.0
    warped = 2.0 * fs * np.tan(np.pi * cutoff / fs)
    poles = poles * warped
    gain = gain * warped ** order

    fs2 = 2.0 * fs
    z_digital = np.full(order, -1.0 + 0j)          # zeros map to z = -1
    p_digital = (fs2 + poles) / (fs2 - poles)
    gain = gain * np.real(np.prod(1.0 / (fs2 - poles)))

    b = gain * np.real(np.poly(z_digital))
    a = np.real(np.poly(p_digital))
    return b.astype(np.float64), a.astype(np.float64)


# ---------------------------------------------------------------------------
# Filter application (jax)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _lfilter_scan(b: jax.Array, a: jax.Array, x: jax.Array) -> jax.Array:
    """Direct-form-II-transposed IIR over the last axis. x: [..., T]."""
    n = b.shape[0]
    batch_shape = x.shape[:-1]
    in_dtype = x.dtype
    x = x.astype(jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    xf = x.reshape((-1, x.shape[-1]))            # [B, T]
    B = xf.shape[0]
    state0 = jnp.zeros((B, n - 1), dtype=xf.dtype)

    b_ = b.astype(xf.dtype)
    a_ = a.astype(xf.dtype)

    def step(state, xt):                          # xt: [B]
        yt = b_[0] * xt + state[:, 0]
        # z_i <- b_{i+1} x - a_{i+1} y + z_{i+1}
        nxt = (b_[1:][None, :] * xt[:, None]
               - a_[1:][None, :] * yt[:, None]
               + jnp.pad(state[:, 1:], ((0, 0), (0, 1))))
        return nxt, yt

    _, y = jax.lax.scan(step, state0, jnp.moveaxis(xf, -1, 0))
    y = jnp.moveaxis(y, 0, -1).reshape(batch_shape + (x.shape[-1],))
    return y.astype(in_dtype)


def lfilter(b: np.ndarray, a: np.ndarray, x: jax.Array) -> jax.Array:
    """Apply IIR filter along the last axis (normalizes by a[0])."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64) / a[0]
    a = a / a[0]
    return _lfilter_scan(jnp.asarray(b), jnp.asarray(a), x)


def filtfilt(b: np.ndarray, a: np.ndarray, x: jax.Array) -> jax.Array:
    """Zero-phase filtering: forward pass, reverse, forward, reverse.

    Simple odd-reflection padding at both ends to suppress edge transients.
    """
    T = x.shape[-1]
    pad = min(3 * (max(len(a), len(b)) - 1), T - 1)
    if pad > 0:
        left = 2 * x[..., :1] - x[..., 1:pad + 1][..., ::-1]
        right = 2 * x[..., -1:] - x[..., -pad - 1:-1][..., ::-1]
        xp = jnp.concatenate([left, x, right], axis=-1)
    else:
        xp = x
    y = lfilter(b, a, xp)
    y = lfilter(b, a, y[..., ::-1])[..., ::-1]
    if pad > 0:
        y = y[..., pad:pad + T]
    return y


# ---------------------------------------------------------------------------
# Streaming (stateful causal) filtering
# ---------------------------------------------------------------------------

@jax.jit
def _lfilter_scan_carry(b: jax.Array, a: jax.Array, x: jax.Array,
                        zi: jax.Array, nvalid: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """One DF2T pass over a (padded) chunk with explicit state in/out.

    x: [T] chunk, zi: [n-1] filter state, nvalid: samples of x that are
    real — the state freezes after them, so padded tails never leak into
    the carried state (y's tail is garbage; callers slice).  Returns
    (y [T], zf [n-1]).  DF2T is causal, so filtering chunk-by-chunk with
    the carried state is *exactly* the one-shot :func:`lfilter` of the
    concatenated signal — the invariant the streaming service leans on.
    """
    def step(state, inp):
        xt, s = inp
        yt = b[0] * xt + state[0]
        nxt = b[1:] * xt - a[1:] * yt + jnp.pad(state[1:], (0, 1))
        return jnp.where(s < nvalid, nxt, state), yt

    zf, y = jax.lax.scan(
        step, zi, (x, jnp.arange(x.shape[0], dtype=jnp.int32)))
    return y, zf


class StreamingFilter:
    """Causal Chebyshev de-noise for in-flight series, chunk by chunk.

    The paper pipeline's :func:`filtfilt` is zero-phase and therefore
    anti-causal — it needs the whole series.  A job being matched *while it
    executes* only ever has a prefix, so the online path uses the causal
    forward filter with its direct-form-II-transposed state carried across
    chunks: any chunking of the input produces the same output as one
    one-shot :func:`lfilter` call (DTW downstream absorbs the filter's
    group delay).  Utilization series are already on the [0, 1] scale, so
    no running normalization is applied.

    Chunks are padded to power-of-two buckets before the jitted scan (the
    state freezes after the valid samples), so arbitrary tick sizes reuse
    a handful of compiled shapes instead of tracing per length.
    """

    def __init__(self, order: int = None, ripple_db: float = None,
                 cutoff: float = None) -> None:
        b, a = _default_ba(order if order is not None else DEFAULT_ORDER,
                           ripple_db if ripple_db is not None
                           else DEFAULT_RIPPLE_DB,
                           cutoff if cutoff is not None else DEFAULT_CUTOFF)
        a = np.asarray(a, np.float64)
        self._b = jnp.asarray(np.asarray(b, np.float64) / a[0],
                              jnp.float32)
        self._a = jnp.asarray(a / a[0], jnp.float32)
        self.reset()

    def reset(self) -> None:
        self._z = jnp.zeros((self._b.shape[0] - 1,), jnp.float32)

    def __call__(self, chunk: np.ndarray) -> np.ndarray:
        from .dtw import _chunk_bucket      # shared jit-shape bucketing

        x = np.asarray(chunk, np.float32).reshape(-1)
        c = x.shape[0]
        if c == 0:
            return np.zeros((0,), np.float32)
        cp = _chunk_bucket(c)
        xp = np.zeros((cp,), np.float32)
        xp[:c] = x
        y, self._z = _lfilter_scan_carry(self._b, self._a, jnp.asarray(xp),
                                         self._z, jnp.int32(c))
        return np.asarray(y[:c])


# ---------------------------------------------------------------------------
# The paper's pre-processing pipeline
# ---------------------------------------------------------------------------

#: Paper §3.1.1/§4: six-order low-pass Chebyshev filter.  Ripple/cutoff are
#: not stated in the paper; 1 dB ripple with cutoff at 0.125 Nyquist keeps
#: the multi-second phase structure of 1 Hz utilization traces while killing
#: sampling jitter.
DEFAULT_ORDER = 6
DEFAULT_RIPPLE_DB = 1.0
DEFAULT_CUTOFF = 0.125


@functools.lru_cache(maxsize=None)
def _default_ba(order: int, ripple_db: float, cutoff: float):
    return cheby1_design(order, ripple_db, cutoff)


def denoise(x: jax.Array, *, order: int = DEFAULT_ORDER,
            ripple_db: float = DEFAULT_RIPPLE_DB,
            cutoff: float = DEFAULT_CUTOFF, zero_phase: bool = True) -> jax.Array:
    """De-noise series (last axis) with the paper's Chebyshev low-pass."""
    b, a = _default_ba(order, ripple_db, cutoff)
    x = jnp.asarray(x, dtype=jnp.float32)
    return filtfilt(b, a, x) if zero_phase else lfilter(b, a, x)


def normalize01(x: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Magnitude normalization to [0, 1] (paper §3.1.1), per series."""
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    return (x - lo) / jnp.maximum(hi - lo, eps)


def preprocess(x: jax.Array, **kw) -> jax.Array:
    """Full paper pre-processing: Chebyshev de-noise then [0,1] normalize."""
    return normalize01(denoise(x, **kw))


# ---------------------------------------------------------------------------
# Batched (padded-bank) pre-processing
# ---------------------------------------------------------------------------

def preprocess_bank(x, lengths, **kw) -> np.ndarray:
    """Paper pre-processing over a padded ``[K, M]`` bank, row-for-row
    **identical** to the scalar :func:`preprocess` of each unpadded series.

    ``filtfilt``'s backward pass is anti-causal, so filtering the padded
    rows directly would bleed the padding's edge transient back into the
    valid prefix — enough to flip 0.9-threshold match decisions on short
    series.  Instead rows are grouped by true length and each group is
    processed as one batch at its native length (reflection padding and
    normalization statistics see exactly the unpadded series), then
    re-packed with edge padding.  Dispatch count = number of distinct
    lengths — the parameter-set buckets real captures quantize into — not
    K.  Returns a float32 numpy array [K, M].
    """
    x = np.asarray(x, np.float32)
    lengths = np.asarray(lengths, np.int64).reshape(-1)
    out = np.empty_like(x)
    for l in np.unique(lengths):
        idx = np.nonzero(lengths == l)[0]
        block = np.asarray(preprocess(jnp.asarray(x[idx, :l]), **kw))
        out[idx, :l] = block
        out[idx, l:] = block[:, -1:]
    return out
