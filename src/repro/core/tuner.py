"""AutoTuner — the paper's end goal as a framework feature.

Given a *new* workload, the tuner (1) captures its utilization signature
cheaply (abstract jaxpr trace; on hardware, a short profiled run on a small
input — exactly the paper's "small set of data"), (2) matches it against
the reference database with the paper's DTW + correlation pipeline, and
(3) if the best match clears the 0.9 threshold, transfers that workload's
best-known execution configuration (mesh layout, microbatch, remat policy,
attention block size, ...) instead of running a parameter search.

Hillclimbed configs discovered in EXPERIMENTS.md §Perf are recorded back
into the database with :meth:`AutoTuner.record`, so tuning knowledge
accumulates across workloads — e.g. kimi-k2 (MLA + MoE) matches
deepseek-v2's signature and inherits its tuned sharding without search.

Batched matching: :meth:`AutoTuner.match` scores the query against *every*
candidate entry in the database with one batched DTW dispatch — the DB
hands back a cached padded ``[K, M]`` bank (+ true-length vector) over the
candidate entries (``ReferenceDB.bank``), ``similarity_bank`` scores all K
references matrix-free in one dispatch (closed-end moment-carrying DP —
no ``[K, N, M]`` stack, no host backtracking; the bank's tiled device
upload is memoized on the SeriesBank), and per-workload bests are reduced
on the host from the bank's row labels.  The wavelet prefilter ranks candidates with the equally
batched ``wavelet_similarity_bank`` before the (narrowed) DTW dispatch.
Entries are stored pre-processed (``profile`` runs the scalar paper
pipeline at capture time), so matching never re-filters the bank.  Scores
are raw correlations in [-1, 1]; the 0.9 threshold is applied only when
deciding whether to transfer a config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from . import dtw as _dtw
from . import filters as _filters
from . import wavelet as _wavelet
from .similarity import (MATCH_THRESHOLD, prefix_similarity_bank,
                         similarity_bank as _sim_bank)
from .database import ReferenceDB, SeriesBank

__all__ = ["TuneDecision", "AutoTuner", "OnlineMatcher"]


@dataclasses.dataclass
class TuneDecision:
    workload: str
    matched: Optional[str]            # workload id of the best DB match
    corr: float                       # best raw correlation in [-1, 1]
    # (-1.0 when there were no candidates at all)
    config: Optional[Dict[str, Any]]  # transferred exec config (None -> search)
    scores: Dict[str, float]          # all candidate raw correlations
    used_wavelet_prefilter: bool = False
    # streaming decisions (serve.tuning.TuningService): how much of the job
    # had been observed, and whether this is the early (prefix) or the
    # final (complete-series, offline-exact) verdict.
    fraction_seen: Optional[float] = None
    final: bool = True
    # fraction of the job observed when the streaming service first
    # committed to a match (== fraction_seen for early decisions; carried
    # onto the final verdict; 1.0 when no early decision fired).  This is
    # the datum ReferenceDB's decision history accumulates so the
    # margin / stable_ticks / min_fraction rule can be calibrated per
    # workload family instead of fixed constants (ROADMAP).
    decided_at_fraction: Optional[float] = None
    # Calibrated match probability P[true warp correlation >= threshold]
    # under the query's per-sample measurement variance (the uncertain-
    # series matcher, arXiv:1112.5505).  None when the decision came from
    # the exact (point-correlation) rule; at zero input variance the
    # probability is exactly 0.0/1.0 and the two rules coincide bitwise.
    probability: Optional[float] = None

    def to_record(self) -> Dict[str, Any]:
        """JSON-serializable form for ``ReferenceDB`` decision history
        (drops the transferred config — history is for calibration, and
        configs live on the matched entry already)."""
        return {"workload": self.workload, "matched": self.matched,
                "corr": float(self.corr),
                "scores": {k: float(v) for k, v in self.scores.items()},
                "fraction_seen": self.fraction_seen,
                "decided_at_fraction": self.decided_at_fraction,
                "final": bool(self.final),
                "probability": (None if self.probability is None
                                else float(self.probability))}

    @classmethod
    def from_record(cls, rec: Dict[str, Any]) -> "TuneDecision":
        return cls(workload=rec["workload"], matched=rec.get("matched"),
                   corr=float(rec.get("corr", -1.0)), config=None,
                   scores=dict(rec.get("scores", {})),
                   fraction_seen=rec.get("fraction_seen"),
                   final=bool(rec.get("final", True)),
                   decided_at_fraction=rec.get("decided_at_fraction"),
                   probability=rec.get("probability"))


class AutoTuner:
    def __init__(self, db: ReferenceDB, *, threshold: float = MATCH_THRESHOLD,
                 band: Optional[int] = None,
                 wavelet_prefilter: int = 0,
                 wavelet_coeffs: int = 64) -> None:
        """``wavelet_prefilter``: if >0, rank candidates by the fast
        wavelet-domain similarity first and run full DTW only on the top-k
        (the paper's future-work scaling fix; beyond-paper feature)."""
        self.db = db
        self.threshold = threshold
        self.band = band
        self.wavelet_prefilter = wavelet_prefilter
        self.wavelet_coeffs = wavelet_coeffs

    # -- profiling -------------------------------------------------------------
    @staticmethod
    def preprocess(series: np.ndarray) -> np.ndarray:
        """Paper pipeline: Chebyshev de-noise + [0,1] normalization."""
        return np.asarray(_filters.preprocess(np.asarray(series, np.float32)))

    def profile(self, workload: str, params: Mapping[str, Any],
                series: np.ndarray, **meta: Any) -> None:
        """Store a (de-noised) profiled series in the reference DB."""
        self.db.add(workload, params, self.preprocess(series), **meta)

    # -- matching ----------------------------------------------------------------
    def match(self, workload: str, series: np.ndarray,
              exclude: Sequence[str] = ()) -> TuneDecision:
        """Score the query against every candidate DB entry in one batched
        DTW dispatch and transfer the best match's config if its raw
        correlation clears the threshold."""
        q = self.preprocess(series)
        candidates = [w for w in self.db.workloads()
                      if w != workload and w not in exclude]

        used_prefilter = False
        if self.wavelet_prefilter and len(candidates) > self.wavelet_prefilter:
            used_prefilter = True
            bank = self.db.bank(workloads=candidates)
            wsims = _wavelet.wavelet_similarity_bank(
                q, bank.series, bank.lengths, m=self.wavelet_coeffs)
            wbest: Dict[str, float] = {}
            for lbl, s in zip(bank.labels, wsims):
                wbest[lbl] = max(wbest.get(lbl, -1.0), float(s))
            ranked = sorted(candidates, key=lambda w: wbest[w], reverse=True)
            candidates = ranked[:self.wavelet_prefilter]

        scores: Dict[str, float] = {}
        if candidates:
            bank = self.db.bank(workloads=candidates)
            corrs = _sim_bank(q, bank, preprocess=False, band=self.band)
            for lbl, c in zip(bank.labels, corrs):
                scores[lbl] = max(scores.get(lbl, -1.0), float(c))

        matched, corr = None, -1.0
        for w in candidates:          # insertion order, ties -> first
            c = scores[w]
            if c > corr:
                matched, corr = w, c

        config = None
        if matched is not None and corr >= self.threshold:
            config = self.db.best_config(matched)
        else:
            matched = None if corr < self.threshold else matched
        return TuneDecision(workload=workload, matched=matched, corr=corr,
                            config=config, scores=scores,
                            used_wavelet_prefilter=used_prefilter)

    # -- feedback ------------------------------------------------------------------
    def record(self, workload: str, config: Mapping[str, Any], score: float,
               series: Optional[np.ndarray] = None,
               params: Optional[Mapping[str, Any]] = None) -> None:
        """Record a tuned config (e.g. from a §Perf hillclimb) so future
        workloads can inherit it via matching."""
        if series is not None:
            self.profile(workload, params or {}, series)
        if not self.db.series_for(workload):
            raise ValueError(f"no series stored for {workload}; pass series=")
        self.db.set_best_config(workload, config, score)

    def tune(self, workload: str, series: np.ndarray,
             fallback: Optional[Callable[[], Mapping[str, Any]]] = None,
             **profile_meta: Any) -> TuneDecision:
        """Match; on success transfer config, else invoke the fallback
        search (and record its outcome)."""
        decision = self.match(workload, series)
        if decision.config is None and fallback is not None:
            cfg = dict(fallback())
            self.profile(workload, profile_meta.pop("params", {}), series,
                         **profile_meta)
            self.db.set_best_config(workload, cfg, score=0.0)
            decision = dataclasses.replace(decision, config=cfg)
        return decision


class _RowBuffer:
    """Append-only growable [n, ...] numpy buffer (geometric doubling).

    The scoring layer reads the whole history every tick, so a
    list-of-chunks + concatenate would cost O(n^2) copy traffic over a
    job's lifetime; this keeps appends amortized O(1) and reads zero-copy
    views.
    """

    def __init__(self) -> None:
        self._buf: Optional[np.ndarray] = None
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def append(self, block: np.ndarray) -> None:
        block = np.asarray(block)
        if block.shape[0] == 0:
            return
        if self._buf is None:
            self._buf = np.empty((max(block.shape[0], 64),)
                                 + block.shape[1:], block.dtype)
        while self._n + block.shape[0] > self._buf.shape[0]:
            grown = np.empty((2 * self._buf.shape[0],)
                             + self._buf.shape[1:], self._buf.dtype)
            grown[: self._n] = self._buf[: self._n]
            self._buf = grown
        self._buf[self._n: self._n + block.shape[0]] = block
        self._n += block.shape[0]

    def view(self) -> np.ndarray:
        """Zero-copy [n, ...] view of everything appended so far."""
        if self._buf is None:
            return np.zeros((0,), np.float32)
        return self._buf[: self._n]


class OnlineMatcher:
    """Streaming (prefix) matcher for ONE in-flight job.

    Arriving CPU-sample chunks feed the incremental bank DP
    (``dtw.dtw_bank_extend`` — the DP state is carried across chunks, so
    any chunking reproduces the one-shot batch solve exactly), and the
    consumed prefix is scored against every reference with the open-ended
    warp correlation (``similarity.prefix_similarity_bank``).  Once the
    series completes, :meth:`final_scores` equals the offline
    ``similarity_bank`` of the full query.

    One jitted dispatch per :meth:`extend` call.  A *service* multiplexing
    many concurrent jobs should use ``repro.serve.tuning.TuningService``
    instead, which folds every in-flight job's tick into a single
    dispatch.

    ``denoise=True`` routes chunks through the causal streaming Chebyshev
    filter (``filters.StreamingFilter``) first — the online stand-in for
    the anti-causal offline ``filtfilt`` pipeline; scores are then exact
    w.r.t. the *causally filtered* query.
    """

    def __init__(self, bank: SeriesBank, *, band: Optional[int] = None,
                 query_len: Optional[int] = None, collect_rows: bool = True,
                 denoise: bool = False) -> None:
        self.bank = bank
        self._state = _dtw.dtw_bank_init(bank.series, bank.lengths,
                                         band=band, query_len=query_len)
        self._collect = collect_rows
        self._rows = _RowBuffer()
        self._x = _RowBuffer()
        self._filter = _filters.StreamingFilter() if denoise else None

    @property
    def n(self) -> int:
        """Query samples consumed so far."""
        return self._state.n

    def extend(self, chunk: np.ndarray) -> "OnlineMatcher":
        """Consume one chunk of samples (one jitted dispatch)."""
        chunk = np.asarray(chunk, np.float32).reshape(-1)
        if chunk.shape[0] == 0:
            return self
        if self._filter is not None:
            chunk = self._filter(chunk)
        self._x.append(chunk)
        self._state, rows = _dtw.dtw_bank_extend(self._state, chunk,
                                                 collect_rows=self._collect)
        if self._collect:
            self._rows.append(np.asarray(rows))
        return self

    def query(self) -> np.ndarray:
        """The consumed (possibly causally filtered) query prefix."""
        return self._x.view()

    def distances(self) -> np.ndarray:
        """Prefix-vs-complete-reference DTW distances -> [K]."""
        return np.asarray(self._state.distances())

    def prefix_distances(self) -> np.ndarray:
        """Open-end distances (best reference *prefix*) -> [K]; monotone
        non-decreasing in the number of consumed samples."""
        return np.asarray(self._state.prefix_distances())

    def prefix_scores(self, open_end: bool = True) -> np.ndarray:
        """Warp correlation of the consumed prefix per reference -> [K]."""
        if not self._collect:
            raise ValueError("prefix scoring needs collect_rows=True")
        if self.n < 2:
            return np.zeros((len(self.bank),), np.float64)
        return prefix_similarity_bank(self.query(), self.bank,
                                      self._rows.view(),
                                      open_end=open_end)

    def final_scores(self) -> np.ndarray:
        """Complete-series scores; equals the offline ``similarity_bank``
        of the full (filtered) query against the bank.

        With ``collect_rows=True`` the streamed DP rows already hold the
        full accumulated-cost matrix of the consumed query, so the final
        verdict is a pure host backtrack of those rows — no second device
        dispatch re-running the whole DP (that re-solve was the PR-5
        ``stream_offline_equiv`` regression).  This also preserves the
        stream's corridor placement exactly as scored in flight when a
        banded ``query_len`` prediction did not come true.  With
        ``collect_rows=False`` there are no rows to backtrack, so the
        matrix-free closed-end moment scorer re-solves in one device
        dispatch, with the banded corridor re-derived from the true
        consumed length — which IS the offline ``similarity_bank``
        verdict.
        """
        if self.n < 2:
            return np.zeros((len(self.bank),), np.float64)
        if self._collect:
            return self.prefix_scores(open_end=False)
        return prefix_similarity_bank(self.query(), self.bank, None,
                                      open_end=False, band=self._state.band)
