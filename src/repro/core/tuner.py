"""AutoTuner — the paper's end goal as a framework feature.

Given a *new* workload, the tuner (1) captures its utilization signature
cheaply (abstract jaxpr trace; on hardware, a short profiled run on a small
input — exactly the paper's "small set of data"), (2) matches it against
the reference database with the paper's DTW + correlation pipeline, and
(3) if the best match clears the 0.9 threshold, transfers that workload's
best-known execution configuration (mesh layout, microbatch, remat policy,
attention block size, ...) instead of running a parameter search.

Hillclimbed configs discovered in EXPERIMENTS.md §Perf are recorded back
into the database with :meth:`AutoTuner.record`, so tuning knowledge
accumulates across workloads — e.g. kimi-k2 (MLA + MoE) matches
deepseek-v2's signature and inherits its tuned sharding without search.

Batched matching: :meth:`AutoTuner.match` scores the query against *every*
candidate entry in the database with one batched DTW dispatch — the DB
hands back a cached padded ``[K, M]`` bank (+ true-length vector) over the
candidate entries (``ReferenceDB.bank``), ``similarity_bank`` solves all K
DPs at once, and per-workload bests are reduced on the host from the bank's
row labels.  The wavelet prefilter ranks candidates with the equally
batched ``wavelet_similarity_bank`` before the (narrowed) DTW dispatch.
Entries are stored pre-processed (``profile`` runs the scalar paper
pipeline at capture time), so matching never re-filters the bank.  Scores
are raw correlations in [-1, 1]; the 0.9 threshold is applied only when
deciding whether to transfer a config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from . import filters as _filters
from . import wavelet as _wavelet
from .similarity import MATCH_THRESHOLD, similarity_bank as _sim_bank
from .database import ReferenceDB

__all__ = ["TuneDecision", "AutoTuner"]


@dataclasses.dataclass
class TuneDecision:
    workload: str
    matched: Optional[str]            # workload id of the best DB match
    corr: float                       # best raw correlation in [-1, 1]
    # (-1.0 when there were no candidates at all)
    config: Optional[Dict[str, Any]]  # transferred exec config (None -> search)
    scores: Dict[str, float]          # all candidate raw correlations
    used_wavelet_prefilter: bool = False


class AutoTuner:
    def __init__(self, db: ReferenceDB, *, threshold: float = MATCH_THRESHOLD,
                 band: Optional[int] = None,
                 wavelet_prefilter: int = 0,
                 wavelet_coeffs: int = 64) -> None:
        """``wavelet_prefilter``: if >0, rank candidates by the fast
        wavelet-domain similarity first and run full DTW only on the top-k
        (the paper's future-work scaling fix; beyond-paper feature)."""
        self.db = db
        self.threshold = threshold
        self.band = band
        self.wavelet_prefilter = wavelet_prefilter
        self.wavelet_coeffs = wavelet_coeffs

    # -- profiling -------------------------------------------------------------
    @staticmethod
    def preprocess(series: np.ndarray) -> np.ndarray:
        """Paper pipeline: Chebyshev de-noise + [0,1] normalization."""
        return np.asarray(_filters.preprocess(np.asarray(series, np.float32)))

    def profile(self, workload: str, params: Mapping[str, Any],
                series: np.ndarray, **meta: Any) -> None:
        """Store a (de-noised) profiled series in the reference DB."""
        self.db.add(workload, params, self.preprocess(series), **meta)

    # -- matching ----------------------------------------------------------------
    def match(self, workload: str, series: np.ndarray,
              exclude: Sequence[str] = ()) -> TuneDecision:
        """Score the query against every candidate DB entry in one batched
        DTW dispatch and transfer the best match's config if its raw
        correlation clears the threshold."""
        q = self.preprocess(series)
        candidates = [w for w in self.db.workloads()
                      if w != workload and w not in exclude]

        used_prefilter = False
        if self.wavelet_prefilter and len(candidates) > self.wavelet_prefilter:
            used_prefilter = True
            bank = self.db.bank(workloads=candidates)
            wsims = _wavelet.wavelet_similarity_bank(
                q, bank.series, bank.lengths, m=self.wavelet_coeffs)
            wbest: Dict[str, float] = {}
            for lbl, s in zip(bank.labels, wsims):
                wbest[lbl] = max(wbest.get(lbl, -1.0), float(s))
            ranked = sorted(candidates, key=lambda w: wbest[w], reverse=True)
            candidates = ranked[:self.wavelet_prefilter]

        scores: Dict[str, float] = {}
        if candidates:
            bank = self.db.bank(workloads=candidates)
            corrs = _sim_bank(q, bank, preprocess=False, band=self.band)
            for lbl, c in zip(bank.labels, corrs):
                scores[lbl] = max(scores.get(lbl, -1.0), float(c))

        matched, corr = None, -1.0
        for w in candidates:          # insertion order, ties -> first
            c = scores[w]
            if c > corr:
                matched, corr = w, c

        config = None
        if matched is not None and corr >= self.threshold:
            config = self.db.best_config(matched)
        else:
            matched = None if corr < self.threshold else matched
        return TuneDecision(workload=workload, matched=matched, corr=corr,
                            config=config, scores=scores,
                            used_wavelet_prefilter=used_prefilter)

    # -- feedback ------------------------------------------------------------------
    def record(self, workload: str, config: Mapping[str, Any], score: float,
               series: Optional[np.ndarray] = None,
               params: Optional[Mapping[str, Any]] = None) -> None:
        """Record a tuned config (e.g. from a §Perf hillclimb) so future
        workloads can inherit it via matching."""
        if series is not None:
            self.profile(workload, params or {}, series)
        if not self.db.series_for(workload):
            raise ValueError(f"no series stored for {workload}; pass series=")
        self.db.set_best_config(workload, config, score)

    def tune(self, workload: str, series: np.ndarray,
             fallback: Optional[Callable[[], Mapping[str, Any]]] = None,
             **profile_meta: Any) -> TuneDecision:
        """Match; on success transfer config, else invoke the fallback
        search (and record its outcome)."""
        decision = self.match(workload, series)
        if decision.config is None and fallback is not None:
            cfg = dict(fallback())
            self.profile(workload, profile_meta.pop("params", {}), series,
                         **profile_meta)
            self.db.set_best_config(workload, cfg, score=0.0)
            decision = dataclasses.replace(decision, config=cfg)
        return decision
