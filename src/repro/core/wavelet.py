"""Haar wavelet compression of utilization series (the paper's §5 future
plan, implemented here as a first-class beyond-paper feature).

The paper notes DTW's quadratic cost makes cluster-scale matching (3N
series per N-node cluster) expensive, and proposes representing each series
by M wavelet coefficients so equal-length series can be compared with a
plain distance instead of DTW.  We implement a Haar DWT, top-|coefficient|
truncation, and the fast matcher; ``benchmarks/bench_wavelet.py`` measures
the speed/fidelity trade-off against full DTW matching.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

__all__ = ["haar_dwt", "haar_idwt", "compress", "reconstruct",
           "wavelet_distance", "wavelet_similarity", "match_series_wavelet",
           "haar_dwt_bank", "compress_bank", "wavelet_similarity_bank"]

_SQRT2 = np.sqrt(2.0)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def haar_dwt(x: np.ndarray) -> np.ndarray:
    """Full Haar decomposition.  Pads (edge) to a power of two.

    Layout: [approx | level_k detail | ... | level_1 detail] — i.e. the
    coarsest coefficients first.
    """
    x = np.asarray(x, np.float64)
    n = _next_pow2(len(x))
    if n != len(x):
        x = np.pad(x, (0, n - len(x)), mode="edge")
    out = []
    cur = x
    while len(cur) > 1:
        even, odd = cur[0::2], cur[1::2]
        out.append((even - odd) / _SQRT2)     # detail
        cur = (even + odd) / _SQRT2           # approximation
    out.append(cur)                            # final approx, length 1
    return np.concatenate(out[::-1])


def haar_idwt(c: np.ndarray) -> np.ndarray:
    """Inverse of :func:`haar_dwt` (returns the padded power-of-two length)."""
    c = np.asarray(c, np.float64)
    n = len(c)
    cur = c[:1]
    pos = 1
    while pos < n:
        detail = c[pos:pos + len(cur)]
        even = (cur + detail) / _SQRT2
        odd = (cur - detail) / _SQRT2
        nxt = np.empty(2 * len(cur))
        nxt[0::2], nxt[1::2] = even, odd
        pos += len(cur)
        cur = nxt
    return cur


def compress(x: np.ndarray, m: int) -> np.ndarray:
    """Keep the M highest-energy coefficients (others zeroed), as the paper
    proposes; returns the full-length sparse coefficient vector so distance
    computation stays a plain vector op."""
    c = haar_dwt(x)
    if m >= len(c):
        return c
    keep = np.argsort(np.abs(c))[::-1][:m]
    out = np.zeros_like(c)
    out[keep] = c[keep]
    return out


def reconstruct(c: np.ndarray, length: int) -> np.ndarray:
    return haar_idwt(c)[:length]


def wavelet_distance(cx: np.ndarray, cy: np.ndarray) -> float:
    """Plain Euclidean distance between (equal-length) coefficient vectors —
    the paper's replacement for DTW on compressed series."""
    n = max(len(cx), len(cy))
    cx = np.pad(cx, (0, n - len(cx)))
    cy = np.pad(cy, (0, n - len(cy)))
    return float(np.linalg.norm(cx - cy))


def wavelet_similarity(x: np.ndarray, y: np.ndarray, m: int = 64) -> float:
    """Similarity in [0, 1] from compressed-domain correlation."""
    n = max(_next_pow2(len(x)), _next_pow2(len(y)))
    xp = np.pad(np.asarray(x, np.float64), (0, n - len(x)), mode="edge")
    yp = np.pad(np.asarray(y, np.float64), (0, n - len(y)), mode="edge")
    cx, cy = compress(xp, m), compress(yp, m)
    num = float((cx * cy).sum())
    den = float(np.linalg.norm(cx) * np.linalg.norm(cy))
    if den < 1e-12:
        return 1.0 if np.allclose(cx, cy) else 0.0
    return float(np.clip(num / den, 0.0, 1.0))


def match_series_wavelet(query: np.ndarray,
                         references: Mapping[str, np.ndarray],
                         m: int = 64) -> Mapping[str, float]:
    return {name: wavelet_similarity(query, ref, m=m)
            for name, ref in references.items()}


# ---------------------------------------------------------------------------
# Batched (bank) variants — vectorized over K series at once
# ---------------------------------------------------------------------------

def haar_dwt_bank(x: np.ndarray) -> np.ndarray:
    """Row-wise Haar decomposition of ``[K, T]`` (edge-pads T to a power of
    two); same coefficient layout as :func:`haar_dwt` per row."""
    x = np.asarray(x, np.float64)
    n = _next_pow2(x.shape[1])
    if n != x.shape[1]:
        x = np.pad(x, ((0, 0), (0, n - x.shape[1])), mode="edge")
    out = []
    cur = x
    while cur.shape[1] > 1:
        even, odd = cur[:, 0::2], cur[:, 1::2]
        out.append((even - odd) / _SQRT2)
        cur = (even + odd) / _SQRT2
    out.append(cur)
    return np.concatenate(out[::-1], axis=1)


def compress_bank(c: np.ndarray, m: int) -> np.ndarray:
    """Per-row top-|coefficient| truncation of a ``[K, P]`` coefficient
    bank (row-wise :func:`compress` tail)."""
    c = np.asarray(c, np.float64)
    if m >= c.shape[1]:
        return c
    keep = np.argpartition(np.abs(c), -m, axis=1)[:, -m:]
    out = np.zeros_like(c)
    np.put_along_axis(out, keep, np.take_along_axis(c, keep, axis=1), axis=1)
    return out


def wavelet_similarity_bank(x: np.ndarray, bank: np.ndarray,
                            lengths: np.ndarray, m: int = 64) -> np.ndarray:
    """Compressed-domain similarity of one query against a padded bank ->
    [K] in [0, 1] — the whole-DB form of :func:`wavelet_similarity`, used
    as the AutoTuner's fast prefilter ranking.

    All series are edge-extended to one common power-of-two length (the
    scalar function picks it per pair), so values can differ slightly from
    per-pair calls when lengths are very unequal; the *ranking* is what the
    prefilter consumes.
    """
    bank = np.asarray(bank, np.float64)
    lengths = np.asarray(lengths)
    x = np.asarray(x, np.float64).reshape(-1)
    k, width = bank.shape
    if k == 0:
        return np.zeros((0,), np.float64)
    n = max(_next_pow2(len(x)),
            _next_pow2(int(lengths.max()) if k else 1))
    xp = np.pad(x, (0, n - len(x)), mode="edge")
    if n >= width:
        # bank rows already repeat their edge value past lengths[k]
        bp = np.pad(bank, ((0, 0), (0, n - width)), mode="edge")
    else:
        bp = bank[:, :n]
    cx = compress(xp, m)
    cb = compress_bank(haar_dwt_bank(bp), m)
    num = cb @ cx
    den = np.linalg.norm(cx) * np.linalg.norm(cb, axis=1)
    sims = np.where(den < 1e-12,
                    np.all(np.isclose(cb, cx[None, :]), axis=1).astype(float),
                    num / np.maximum(den, 1e-300))
    return np.clip(sims, 0.0, 1.0)
