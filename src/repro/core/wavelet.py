"""Haar wavelet compression of utilization series (the paper's §5 future
plan, implemented here as a first-class beyond-paper feature).

The paper notes DTW's quadratic cost makes cluster-scale matching (3N
series per N-node cluster) expensive, and proposes representing each series
by M wavelet coefficients so equal-length series can be compared with a
plain distance instead of DTW.  We implement a Haar DWT, top-|coefficient|
truncation, and the fast matcher; ``benchmarks/bench_wavelet.py`` measures
the speed/fidelity trade-off against full DTW matching.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

__all__ = ["haar_dwt", "haar_idwt", "compress", "reconstruct",
           "wavelet_distance", "wavelet_similarity", "match_series_wavelet"]

_SQRT2 = np.sqrt(2.0)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def haar_dwt(x: np.ndarray) -> np.ndarray:
    """Full Haar decomposition.  Pads (edge) to a power of two.

    Layout: [approx | level_k detail | ... | level_1 detail] — i.e. the
    coarsest coefficients first.
    """
    x = np.asarray(x, np.float64)
    n = _next_pow2(len(x))
    if n != len(x):
        x = np.pad(x, (0, n - len(x)), mode="edge")
    out = []
    cur = x
    while len(cur) > 1:
        even, odd = cur[0::2], cur[1::2]
        out.append((even - odd) / _SQRT2)     # detail
        cur = (even + odd) / _SQRT2           # approximation
    out.append(cur)                            # final approx, length 1
    return np.concatenate(out[::-1])


def haar_idwt(c: np.ndarray) -> np.ndarray:
    """Inverse of :func:`haar_dwt` (returns the padded power-of-two length)."""
    c = np.asarray(c, np.float64)
    n = len(c)
    cur = c[:1]
    pos = 1
    while pos < n:
        detail = c[pos:pos + len(cur)]
        even = (cur + detail) / _SQRT2
        odd = (cur - detail) / _SQRT2
        nxt = np.empty(2 * len(cur))
        nxt[0::2], nxt[1::2] = even, odd
        pos += len(cur)
        cur = nxt
    return cur


def compress(x: np.ndarray, m: int) -> np.ndarray:
    """Keep the M highest-energy coefficients (others zeroed), as the paper
    proposes; returns the full-length sparse coefficient vector so distance
    computation stays a plain vector op."""
    c = haar_dwt(x)
    if m >= len(c):
        return c
    keep = np.argsort(np.abs(c))[::-1][:m]
    out = np.zeros_like(c)
    out[keep] = c[keep]
    return out


def reconstruct(c: np.ndarray, length: int) -> np.ndarray:
    return haar_idwt(c)[:length]


def wavelet_distance(cx: np.ndarray, cy: np.ndarray) -> float:
    """Plain Euclidean distance between (equal-length) coefficient vectors —
    the paper's replacement for DTW on compressed series."""
    n = max(len(cx), len(cy))
    cx = np.pad(cx, (0, n - len(cx)))
    cy = np.pad(cy, (0, n - len(cy)))
    return float(np.linalg.norm(cx - cy))


def wavelet_similarity(x: np.ndarray, y: np.ndarray, m: int = 64) -> float:
    """Similarity in [0, 1] from compressed-domain correlation."""
    n = max(_next_pow2(len(x)), _next_pow2(len(y)))
    xp = np.pad(np.asarray(x, np.float64), (0, n - len(x)), mode="edge")
    yp = np.pad(np.asarray(y, np.float64), (0, n - len(y)), mode="edge")
    cx, cy = compress(xp, m), compress(yp, m)
    num = float((cx * cy).sum())
    den = float(np.linalg.norm(cx) * np.linalg.norm(cy))
    if den < 1e-12:
        return 1.0 if np.allclose(cx, cy) else 0.0
    return float(np.clip(num / den, 0.0, 1.0))


def match_series_wavelet(query: np.ndarray,
                         references: Mapping[str, np.ndarray],
                         m: int = 64) -> Mapping[str, float]:
    return {name: wavelet_similarity(query, ref, m=m)
            for name, ref in references.items()}
