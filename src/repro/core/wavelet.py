"""Haar wavelet compression of utilization series (the paper's §5 future
plan, implemented here as a first-class beyond-paper feature).

The paper notes DTW's quadratic cost makes cluster-scale matching (3N
series per N-node cluster) expensive, and proposes representing each series
by M wavelet coefficients so equal-length series can be compared with a
plain distance instead of DTW.  We implement a Haar DWT, top-|coefficient|
truncation, and the fast matcher; ``benchmarks/bench_wavelet.py`` measures
the speed/fidelity trade-off against full DTW matching.

The **streaming** half (:class:`StreamingHaar`) is the online analogue of
the offline prefilter: it maintains the Haar coefficients of an in-flight
job's edge-extended prefix incrementally — each arriving chunk dirties
only the coefficient pyramid to the right of the first changed sample, so
an update costs O(size - n) instead of an O(size log size) full
re-transform — and is pinned (tests/test_wavelet.py) to equal the offline
:func:`haar_dwt` of the same padded prefix at every chunk boundary,
bit-for-bit.  ``serve.tuning.TuningService`` ranks the reference bank
against these prefix coefficients to prune the fused streaming-DTW tick
at large K.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

__all__ = ["haar_dwt", "haar_idwt", "compress", "reconstruct",
           "wavelet_distance", "wavelet_similarity", "match_series_wavelet",
           "haar_dwt_bank", "compress_bank", "wavelet_similarity_bank",
           "StreamingHaar", "coeff_similarity_bank"]

_SQRT2 = np.sqrt(2.0)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def haar_dwt(x: np.ndarray) -> np.ndarray:
    """Full Haar decomposition.  Pads (edge) to a power of two.

    Layout: [approx | level_k detail | ... | level_1 detail] — i.e. the
    coarsest coefficients first.
    """
    x = np.asarray(x, np.float64)
    n = _next_pow2(len(x))
    if n != len(x):
        x = np.pad(x, (0, n - len(x)), mode="edge")
    out = []
    cur = x
    while len(cur) > 1:
        even, odd = cur[0::2], cur[1::2]
        out.append((even - odd) / _SQRT2)     # detail
        cur = (even + odd) / _SQRT2           # approximation
    out.append(cur)                            # final approx, length 1
    return np.concatenate(out[::-1])


def haar_idwt(c: np.ndarray) -> np.ndarray:
    """Inverse of :func:`haar_dwt` (returns the padded power-of-two length)."""
    c = np.asarray(c, np.float64)
    n = len(c)
    cur = c[:1]
    pos = 1
    while pos < n:
        detail = c[pos:pos + len(cur)]
        even = (cur + detail) / _SQRT2
        odd = (cur - detail) / _SQRT2
        nxt = np.empty(2 * len(cur))
        nxt[0::2], nxt[1::2] = even, odd
        pos += len(cur)
        cur = nxt
    return cur


def compress(x: np.ndarray, m: int) -> np.ndarray:
    """Keep the M highest-energy coefficients (others zeroed), as the paper
    proposes; returns the full-length sparse coefficient vector so distance
    computation stays a plain vector op."""
    c = haar_dwt(x)
    if m >= len(c):
        return c
    keep = np.argsort(np.abs(c))[::-1][:m]
    out = np.zeros_like(c)
    out[keep] = c[keep]
    return out


def reconstruct(c: np.ndarray, length: int) -> np.ndarray:
    return haar_idwt(c)[:length]


def wavelet_distance(cx: np.ndarray, cy: np.ndarray) -> float:
    """Plain Euclidean distance between (equal-length) coefficient vectors —
    the paper's replacement for DTW on compressed series."""
    n = max(len(cx), len(cy))
    cx = np.pad(cx, (0, n - len(cx)))
    cy = np.pad(cy, (0, n - len(cy)))
    return float(np.linalg.norm(cx - cy))


def wavelet_similarity(x: np.ndarray, y: np.ndarray, m: int = 64) -> float:
    """Similarity in [0, 1] from compressed-domain correlation."""
    n = max(_next_pow2(len(x)), _next_pow2(len(y)))
    xp = np.pad(np.asarray(x, np.float64), (0, n - len(x)), mode="edge")
    yp = np.pad(np.asarray(y, np.float64), (0, n - len(y)), mode="edge")
    cx, cy = compress(xp, m), compress(yp, m)
    num = float((cx * cy).sum())
    den = float(np.linalg.norm(cx) * np.linalg.norm(cy))
    if den < 1e-12:
        return 1.0 if np.allclose(cx, cy) else 0.0
    return float(np.clip(num / den, 0.0, 1.0))


def match_series_wavelet(query: np.ndarray,
                         references: Mapping[str, np.ndarray],
                         m: int = 64) -> Mapping[str, float]:
    return {name: wavelet_similarity(query, ref, m=m)
            for name, ref in references.items()}


# ---------------------------------------------------------------------------
# Batched (bank) variants — vectorized over K series at once
# ---------------------------------------------------------------------------

def haar_dwt_bank(x: np.ndarray) -> np.ndarray:
    """Row-wise Haar decomposition of ``[K, T]`` (edge-pads T to a power of
    two); same coefficient layout as :func:`haar_dwt` per row."""
    x = np.asarray(x, np.float64)
    n = _next_pow2(x.shape[1])
    if n != x.shape[1]:
        x = np.pad(x, ((0, 0), (0, n - x.shape[1])), mode="edge")
    out = []
    cur = x
    while cur.shape[1] > 1:
        even, odd = cur[:, 0::2], cur[:, 1::2]
        out.append((even - odd) / _SQRT2)
        cur = (even + odd) / _SQRT2
    out.append(cur)
    return np.concatenate(out[::-1], axis=1)


def compress_bank(c: np.ndarray, m: int) -> np.ndarray:
    """Per-row top-|coefficient| truncation of a ``[K, P]`` coefficient
    bank (row-wise :func:`compress` tail)."""
    c = np.asarray(c, np.float64)
    if m >= c.shape[1]:
        return c
    keep = np.argpartition(np.abs(c), -m, axis=1)[:, -m:]
    out = np.zeros_like(c)
    np.put_along_axis(out, keep, np.take_along_axis(c, keep, axis=1), axis=1)
    return out


def wavelet_similarity_bank(x: np.ndarray, bank: np.ndarray,
                            lengths: np.ndarray, m: int = 64) -> np.ndarray:
    """Compressed-domain similarity of one query against a padded bank ->
    [K] in [0, 1] — the whole-DB form of :func:`wavelet_similarity`, used
    as the AutoTuner's fast prefilter ranking.

    All series are edge-extended to one common power-of-two length (the
    scalar function picks it per pair), so values can differ slightly from
    per-pair calls when lengths are very unequal; the *ranking* is what the
    prefilter consumes.
    """
    bank = np.asarray(bank, np.float64)
    lengths = np.asarray(lengths)
    x = np.asarray(x, np.float64).reshape(-1)
    k, width = bank.shape
    if k == 0:
        return np.zeros((0,), np.float64)
    n = max(_next_pow2(len(x)),
            _next_pow2(int(lengths.max()) if k else 1))
    xp = np.pad(x, (0, n - len(x)), mode="edge")
    if n >= width:
        # bank rows already repeat their edge value past lengths[k]
        bp = np.pad(bank, ((0, 0), (0, n - width)), mode="edge")
    else:
        bp = bank[:, :n]
    cx = compress(xp, m)
    cb = compress_bank(haar_dwt_bank(bp), m)
    return coeff_similarity_bank(cx, cb)


def coeff_similarity_bank(cx: np.ndarray, cb: np.ndarray) -> np.ndarray:
    """Cosine similarity of one (compressed) coefficient vector against a
    ``[K, P]`` compressed coefficient bank -> [K] in [0, 1].

    The scoring tail of :func:`wavelet_similarity_bank`, split out so the
    streaming prefilter (which already holds :class:`StreamingHaar`
    prefix coefficients) can rank the bank without re-transforming
    anything."""
    num = cb @ cx
    den = np.linalg.norm(cx) * np.linalg.norm(cb, axis=1)
    sims = np.where(den < 1e-12,
                    np.all(np.isclose(cb, cx[None, :]), axis=1).astype(float),
                    num / np.maximum(den, 1e-300))
    return np.clip(sims, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Streaming (prefix) Haar — the online prefilter's transform
# ---------------------------------------------------------------------------

class StreamingHaar:
    """Incremental Haar decomposition of an in-flight job's prefix.

    After ``update()`` has consumed ``n`` samples, :meth:`coeffs` equals
    ``haar_dwt(edge-extension of x[:n] to the fixed power-of-two target
    length)`` exactly — same layout (coarsest first), bitwise-identical
    values — without re-transforming the whole series: appending a chunk
    changes samples ``[n_old, size)`` (the new samples plus the moved
    edge extension), so only pyramid positions at or right of
    ``n_old >> level`` are recomputed per level.

    ``total_len`` is the job's *expected* length (the prefilter target
    resolution); a job that overruns the power-of-two target transparently
    regrows to the next one (full O(size) rebuild, amortized by the
    doubling).
    """

    def __init__(self, total_len: int) -> None:
        if total_len < 1:
            raise ValueError("total_len must be >= 1")
        self.n = 0
        self._samples = np.zeros((0,), np.float64)
        self._alloc(_next_pow2(max(int(total_len), 2)))

    def _alloc(self, size: int) -> None:
        self.size = size
        self._x = np.zeros((size,), np.float64)
        self._detail = []
        self._approx = []
        while size > 1:
            size //= 2
            self._detail.append(np.zeros((size,), np.float64))
            self._approx.append(np.zeros((size,), np.float64))

    def _refresh(self, dirty: int) -> None:
        """Recompute the pyramid from level-0 position ``dirty`` up."""
        cur = self._x
        for det, apx in zip(self._detail, self._approx):
            dirty //= 2
            even = cur[2 * dirty::2]
            odd = cur[2 * dirty + 1::2]
            det[dirty:] = (even - odd) / _SQRT2
            apx[dirty:] = (even + odd) / _SQRT2
            cur = apx

    def update(self, chunk: np.ndarray) -> "StreamingHaar":
        """Consume one chunk of samples; O(size - n + log size) work."""
        chunk = np.asarray(chunk, np.float64).reshape(-1)
        if chunk.shape[0] == 0:
            return self
        self._samples = np.concatenate([self._samples, chunk])
        n0, self.n = self.n, self.n + chunk.shape[0]
        if self.n > self.size:
            self._alloc(_next_pow2(self.n))
            n0 = 0
        self._x[n0: self.n] = self._samples[n0: self.n]
        self._x[self.n:] = self._samples[-1]        # edge extension
        self._refresh(n0)
        return self

    def coeffs(self) -> np.ndarray:
        """Haar coefficients of the edge-extended prefix, in
        :func:`haar_dwt` layout (``[approx | coarsest .. finest
        detail]``) at the current target ``size``."""
        if not self._detail:                         # size == 1 degenerate
            return self._x.copy()
        return np.concatenate(
            [self._approx[-1]] + self._detail[::-1])

    def compressed(self, m: int) -> np.ndarray:
        """Top-|coefficient| truncation of :meth:`coeffs` (the vector the
        prefilter ranks the bank against)."""
        return compress_bank(self.coeffs()[None, :], m)[0]
