"""Core library: the paper's contribution.

Pipeline (paper Fig. 3/4): profile -> Chebyshev de-noise -> [0,1]
normalize -> store in ReferenceDB; match new workloads with DTW +
correlation (>= 0.9) and transfer the matched workload's best-known
configuration parameters (AutoTuner).
"""

from .filters import (cheby1_design, lfilter, filtfilt, denoise, normalize01,
                      preprocess, preprocess_bank, StreamingFilter)
from .dtw import (cost_matrix, dtw_matrix, dtw_distance, dtw_matrix_banded,
                  dtw_matrix_bank, dtw_matrix_pairs, dtw_distance_bank,
                  dtw_score_bank, dtw_score_bank_many, dtw_score_pairs,
                  query_moments, ScoreBankPlan, build_score_plan,
                  DtwBankState, dtw_bank_init, dtw_bank_extend,
                  backtrack, warp_to, dtw_warp)
from .similarity import (correlation, similarity, similarity_bank,
                         MatchResult, match_series, match_application,
                         MATCH_THRESHOLD, RunningMoments,
                         prefix_similarity_bank)
from .wavelet import (haar_dwt, haar_idwt, compress, reconstruct,
                      wavelet_distance, wavelet_similarity, match_series_wavelet,
                      haar_dwt_bank, compress_bank, wavelet_similarity_bank,
                      StreamingHaar, coeff_similarity_bank)
from .database import Entry, SeriesBank, pack_series, ReferenceDB
from .signatures import (ChipSpec, TPU_V5E, OpCost, jaxpr_costs,
                         utilization_series, signature_of)
from .tuner import AutoTuner, TuneDecision, OnlineMatcher
from . import hloparse

__all__ = [
    "cheby1_design", "lfilter", "filtfilt", "denoise", "normalize01",
    "preprocess", "preprocess_bank", "StreamingFilter",
    "cost_matrix", "dtw_matrix", "dtw_distance", "dtw_matrix_banded",
    "dtw_matrix_bank", "dtw_matrix_pairs", "dtw_distance_bank",
    "dtw_score_bank", "dtw_score_bank_many", "dtw_score_pairs",
    "query_moments", "ScoreBankPlan", "build_score_plan",
    "DtwBankState", "dtw_bank_init", "dtw_bank_extend",
    "backtrack", "warp_to", "dtw_warp",
    "correlation", "similarity", "similarity_bank", "MatchResult",
    "match_series", "match_application", "MATCH_THRESHOLD",
    "RunningMoments", "prefix_similarity_bank",
    "haar_dwt", "haar_idwt", "compress", "reconstruct",
    "wavelet_distance", "wavelet_similarity", "match_series_wavelet",
    "haar_dwt_bank", "compress_bank", "wavelet_similarity_bank",
    "StreamingHaar", "coeff_similarity_bank",
    "Entry", "SeriesBank", "pack_series", "ReferenceDB",
    "ChipSpec", "TPU_V5E", "OpCost", "jaxpr_costs", "utilization_series",
    "signature_of",
    "AutoTuner", "TuneDecision", "OnlineMatcher",
    "hloparse",
]
