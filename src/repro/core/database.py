"""Reference database of profiled workloads (paper Fig. 3-a / Fig. 4-a).

Each entry stores ``(workload, params, series, meta)`` — in the paper:
(application, {M, R, FS, I}, de-noised CPU series).  Here ``workload`` is a
free-form id (e.g. ``"deepseek-v2-236b/train_4k"`` or ``"wordcount"``),
``params`` the configuration-parameter values the series was captured
under, and ``meta`` carries whatever tuning knowledge exists for the
workload (best-known exec config, roofline terms, ...).

Persistence is a directory with one ``.npz`` for the series plus an
``index.json`` manifest — append-only, atomic (tmp+rename), safe for
concurrent readers; this is the on-disk format the AutoTuner ships between
jobs on a cluster.

Batched matching support: :meth:`ReferenceDB.bank` packs any selection of
entries into a :class:`SeriesBank` — all series padded (edge value) to a
common length in one ``[K, M]`` float32 array plus an ``int32 [K]`` vector
of true lengths — so the whole DB can be matched with a single batched DTW
dispatch (see ``core/dtw.py``).  Banks are cached per selection and
invalidated on :meth:`add`.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Entry", "SeriesBank", "pack_series", "ReferenceDB",
           "atomic_write_npz", "atomic_write_json"]


def atomic_write_npz(dir_path: str, filename: str,
                     arrays: Mapping[str, np.ndarray]) -> str:
    """Write ``dir_path/filename`` (an ``.npz``) atomically: compress
    into a tmp file in the same directory, then ``os.replace`` — readers
    (and crashed writers) never observe a torn archive.  Shared by the
    reference-DB persistence and the serving trace log."""
    fd, tmp = tempfile.mkstemp(dir=dir_path, suffix=".tmp")
    os.close(fd)
    np.savez_compressed(tmp + ".npz", **arrays)
    final = os.path.join(dir_path, filename)
    os.replace(tmp + ".npz", final)
    os.unlink(tmp)
    return final


def atomic_write_json(dir_path: str, filename: str, obj: Any) -> str:
    """Atomic (tmp+rename) JSON dump next to :func:`atomic_write_npz`."""
    fd, tmp = tempfile.mkstemp(dir=dir_path, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(obj, f, indent=1, default=str)
    final = os.path.join(dir_path, filename)
    os.replace(tmp, final)
    return final


def _params_key(params: Mapping[str, Any]) -> str:
    return json.dumps({k: params[k] for k in sorted(params)}, sort_keys=True)


@dataclasses.dataclass
class Entry:
    workload: str
    params: Dict[str, Any]
    series: np.ndarray
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SeriesBank:
    """K ragged series packed for one-dispatch batched matching.

    ``series[k, :lengths[k]]`` is series k; the tail ``series[k,
    lengths[k]:]`` repeats its edge value (padding never reaches the DTW
    distance — see ``core/dtw.py`` docstring).  ``labels[k]`` names row k
    (workload id for DB banks) and ``entries`` holds the source
    :class:`Entry` objects when the bank was built from a DB.
    """
    series: np.ndarray                       # [K, M] float32
    lengths: np.ndarray                      # [K] int32
    labels: Tuple[str, ...] = ()
    entries: Tuple[Entry, ...] = ()
    #: memoized device-side tiling for the matrix-free offline scorers
    #: (``core.dtw.ScoreBankPlan``) — series/lengths are frozen, so the
    #: plan can never go stale; ``dataclasses.replace`` copies start
    #: fresh.  Excluded from comparison/repr.
    _score_plan: object = dataclasses.field(default=None, init=False,
                                            repr=False, compare=False)
    #: memoized paper-pipeline-filtered copy (see :meth:`preprocessed`).
    _preprocessed: object = dataclasses.field(default=None, init=False,
                                              repr=False, compare=False)

    def __len__(self) -> int:
        return self.series.shape[0]

    def row(self, k: int) -> np.ndarray:
        """Unpadded series k."""
        return self.series[k, : int(self.lengths[k])]

    def score_plan(self):
        """Device-resident tiled upload of this bank for the closed-end
        moment scorers (``core.dtw.dtw_score_bank*``), built once and
        reused across verdicts — the finish()/match hot path must not
        re-pack and re-upload the same bank per call."""
        plan = self._score_plan
        if plan is None:
            from . import dtw as _dtw
            plan = _dtw.build_score_plan(self.series, self.lengths)
            object.__setattr__(self, "_score_plan", plan)
        return plan

    def preprocessed(self) -> "SeriesBank":
        """Paper-pipeline (Chebyshev de-noise + [0, 1] normalization)
        filtered copy of this bank, memoized — repeated
        ``preprocess=True`` scoring against the same bank reuses ONE
        filtered pack, and therefore one :meth:`score_plan` device
        upload, instead of re-filtering and re-uploading per call."""
        pb = self._preprocessed
        if pb is None:
            from . import filters as _filters
            pb = SeriesBank(np.asarray(_filters.preprocess_bank(
                self.series, self.lengths)), self.lengths, self.labels,
                self.entries)
            object.__setattr__(self, "_preprocessed", pb)
        return pb


def pack_series(series: Sequence[np.ndarray],
                labels: Sequence[str] = (),
                entries: Sequence[Entry] = (),
                pad_multiple: int = 8) -> SeriesBank:
    """Pack ragged 1-D series into a padded ``[K, M]`` bank.

    M is the max length rounded up to ``pad_multiple`` (keeps the last axis
    lane-friendly on TPU); padding repeats each series' final sample.
    """
    arrs = [np.asarray(s, np.float32).reshape(-1) for s in series]
    lengths = np.asarray([a.shape[0] for a in arrs], np.int32)
    if any(l == 0 for l in lengths):
        raise ValueError("cannot pack empty series into a bank")
    if not arrs:
        return SeriesBank(np.zeros((0, pad_multiple), np.float32), lengths,
                          tuple(labels), tuple(entries))
    m = max(int(lengths.max()), 2)
    m = ((m + pad_multiple - 1) // pad_multiple) * pad_multiple
    out = np.empty((len(arrs), m), np.float32)
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
        out[i, a.shape[0]:] = a[-1]
    return SeriesBank(out, lengths, tuple(labels), tuple(entries))


class ReferenceDB:
    """In-memory reference DB with directory persistence."""

    #: Each cached bank is a padded copy of its selection, and every
    #: distinct exclude-set produces a distinct selection (AutoTuner
    #: excludes the query workload), so the cache must be bounded: LRU
    #: over the most recent selections.
    BANK_CACHE_MAX = 8

    def __init__(self) -> None:
        self._entries: List[Entry] = []
        self._bank_cache: "collections.OrderedDict[Tuple[int, ...], SeriesBank]" \
            = collections.OrderedDict()
        #: accumulated match-decision records (dicts, see
        #: ``TuneDecision.to_record``) — the raw material for calibrating
        #: the streaming early-decision rule per workload family.
        self._decisions: List[Dict[str, Any]] = []

    # -- population ---------------------------------------------------------
    def add(self, workload: str, params: Mapping[str, Any],
            series: np.ndarray, meta: Optional[Mapping[str, Any]] = None,
            **extra_meta: Any) -> Entry:
        """Add one profiled series.  ``meta`` may be passed explicitly (a
        mapping — the persistence round-trip uses this so meta keys named
        ``workload``/``params``/``series`` can't shadow positional args) or
        as keyword arguments; both merge into the entry's meta dict."""
        md = dict(meta or {})
        md.update(extra_meta)
        e = Entry(workload=str(workload), params=dict(params),
                  series=np.asarray(series, np.float32), meta=md)
        self._entries.append(e)
        self._bank_cache.clear()
        return e

    # -- queries -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> Sequence[Entry]:
        return tuple(self._entries)

    def workloads(self) -> List[str]:
        seen: List[str] = []
        for e in self._entries:
            if e.workload not in seen:
                seen.append(e.workload)
        return seen

    def series_for(self, workload: str) -> List[Entry]:
        return [e for e in self._entries if e.workload == workload]

    def lookup(self, workload: str, params: Mapping[str, Any]) -> Optional[Entry]:
        key = _params_key(params)
        for e in self._entries:
            if e.workload == workload and _params_key(e.params) == key:
                return e
        return None

    def best_config(self, workload: str) -> Optional[Dict[str, Any]]:
        """The stored best-known execution config for a workload, if any."""
        best = None
        for e in self.series_for(workload):
            cfg = e.meta.get("best_config")
            if cfg is None:
                continue
            score = e.meta.get("score", 0.0)
            if best is None or score > best[0]:
                best = (score, cfg)
        return best[1] if best else None

    def set_best_config(self, workload: str, config: Mapping[str, Any],
                        score: float) -> None:
        for e in self.series_for(workload):
            e.meta["best_config"] = dict(config)
            e.meta["score"] = float(score)

    # -- batched matching ----------------------------------------------------
    def bank(self, workloads: Optional[Sequence[str]] = None,
             exclude: Sequence[str] = ()) -> SeriesBank:
        """Padded ``[K, M]`` bank over the selected entries (all by
        default), row-labelled with each entry's workload id.  LRU-cached
        per selection (:data:`BANK_CACHE_MAX` most recent); the cache is
        cleared by :meth:`add`."""
        inc = None if workloads is None else set(workloads)
        exc = set(exclude)
        sel = tuple(i for i, e in enumerate(self._entries)
                    if (inc is None or e.workload in inc)
                    and e.workload not in exc)
        cached = self._bank_cache.get(sel)
        if cached is not None:
            self._bank_cache.move_to_end(sel)
            return cached
        entries = [self._entries[i] for i in sel]
        bank = pack_series([e.series for e in entries],
                           labels=[e.workload for e in entries],
                           entries=entries)
        self._bank_cache[sel] = bank
        while len(self._bank_cache) > self.BANK_CACHE_MAX:
            self._bank_cache.popitem(last=False)
        return bank

    # -- decision history -----------------------------------------------------
    def record_decision(self, decision: Any) -> None:
        """Append one match decision to the history.

        ``decision`` is a ``tuner.TuneDecision`` (anything with a
        ``to_record()``) or an already-serialized record dict.  The
        streaming service calls this on :meth:`~repro.serve.tuning.
        TuningService.finish`, so every completed job contributes a
        ``decided_at_fraction`` datum; history persists with the DB.
        """
        rec = decision.to_record() if hasattr(decision, "to_record") \
            else dict(decision)
        self._decisions.append(rec)

    def decision_history(self, matched: Optional[str] = None
                         ) -> List[Dict[str, Any]]:
        """Recorded decisions, optionally filtered to one matched
        workload family (the calibration unit: "when did jobs that
        matched W become decidable?")."""
        if matched is None:
            return list(self._decisions)
        return [d for d in self._decisions if d.get("matched") == matched]

    def decided_at_fractions(self, matched: str) -> List[float]:
        """The ``decided_at_fraction`` data points for one workload
        family (finals without an early decision report 1.0 — they were
        never decidable in flight)."""
        return [float(d["decided_at_fraction"])
                for d in self._decisions
                if d.get("matched") == matched
                and d.get("decided_at_fraction") is not None]

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        index = []
        arrays = {}
        for i, e in enumerate(self._entries):
            key = f"s{i}"
            arrays[key] = e.series
            index.append({"workload": e.workload, "params": e.params,
                          "meta": e.meta, "key": key})
        atomic_write_npz(path, "series.npz", arrays)
        atomic_write_json(path, "index.json",
                          {"version": 1, "entries": index,
                           "decisions": self._decisions})

    @classmethod
    def load(cls, path: str) -> "ReferenceDB":
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        arrays = np.load(os.path.join(path, "series.npz"))
        db = cls()
        for rec in index["entries"]:
            # meta passed explicitly: a meta key named "workload"/"params"/
            # "series" must not shadow the positional arguments.
            db.add(rec["workload"], rec["params"], arrays[rec["key"]],
                   meta=rec.get("meta", {}))
        for rec in index.get("decisions", ()):   # absent in pre-v3 saves
            db.record_decision(rec)
        return db
