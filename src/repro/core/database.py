"""Reference database of profiled workloads (paper Fig. 3-a / Fig. 4-a).

Each entry stores ``(workload, params, series, meta)`` — in the paper:
(application, {M, R, FS, I}, de-noised CPU series).  Here ``workload`` is a
free-form id (e.g. ``"deepseek-v2-236b/train_4k"`` or ``"wordcount"``),
``params`` the configuration-parameter values the series was captured
under, and ``meta`` carries whatever tuning knowledge exists for the
workload (best-known exec config, roofline terms, ...).

Persistence is a directory with one ``.npz`` for the series plus an
``index.json`` manifest — append-only, atomic (tmp+rename), safe for
concurrent readers; this is the on-disk format the AutoTuner ships between
jobs on a cluster.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

__all__ = ["Entry", "ReferenceDB"]


def _params_key(params: Mapping[str, Any]) -> str:
    return json.dumps({k: params[k] for k in sorted(params)}, sort_keys=True)


@dataclasses.dataclass
class Entry:
    workload: str
    params: Dict[str, Any]
    series: np.ndarray
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ReferenceDB:
    """In-memory reference DB with directory persistence."""

    def __init__(self) -> None:
        self._entries: List[Entry] = []

    # -- population ---------------------------------------------------------
    def add(self, workload: str, params: Mapping[str, Any],
            series: np.ndarray, **meta: Any) -> Entry:
        e = Entry(workload=str(workload), params=dict(params),
                  series=np.asarray(series, np.float32), meta=dict(meta))
        self._entries.append(e)
        return e

    # -- queries -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> Sequence[Entry]:
        return tuple(self._entries)

    def workloads(self) -> List[str]:
        seen: List[str] = []
        for e in self._entries:
            if e.workload not in seen:
                seen.append(e.workload)
        return seen

    def series_for(self, workload: str) -> List[Entry]:
        return [e for e in self._entries if e.workload == workload]

    def lookup(self, workload: str, params: Mapping[str, Any]) -> Optional[Entry]:
        key = _params_key(params)
        for e in self._entries:
            if e.workload == workload and _params_key(e.params) == key:
                return e
        return None

    def best_config(self, workload: str) -> Optional[Dict[str, Any]]:
        """The stored best-known execution config for a workload, if any."""
        best = None
        for e in self.series_for(workload):
            cfg = e.meta.get("best_config")
            if cfg is None:
                continue
            score = e.meta.get("score", 0.0)
            if best is None or score > best[0]:
                best = (score, cfg)
        return best[1] if best else None

    def set_best_config(self, workload: str, config: Mapping[str, Any],
                        score: float) -> None:
        for e in self.series_for(workload):
            e.meta["best_config"] = dict(config)
            e.meta["score"] = float(score)

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        index = []
        arrays = {}
        for i, e in enumerate(self._entries):
            key = f"s{i}"
            arrays[key] = e.series
            index.append({"workload": e.workload, "params": e.params,
                          "meta": e.meta, "key": key})
        # atomic: write into tmp files then rename (np.savez appends .npz)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
        os.close(fd)
        np.savez_compressed(tmp + ".npz", **arrays)
        os.replace(tmp + ".npz", os.path.join(path, "series.npz"))
        os.unlink(tmp)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"version": 1, "entries": index}, f, indent=1, default=str)
        os.replace(tmp, os.path.join(path, "index.json"))

    @classmethod
    def load(cls, path: str) -> "ReferenceDB":
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        arrays = np.load(os.path.join(path, "series.npz"))
        db = cls()
        for rec in index["entries"]:
            db.add(rec["workload"], rec["params"], arrays[rec["key"]],
                   **rec.get("meta", {}))
        return db
