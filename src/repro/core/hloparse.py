"""Lightweight post-optimization HLO text parser.

Used by the dry-run roofline to extract **collective bytes** (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute operand
sizes), which ``compiled.cost_analysis()`` does not report, plus per-opcode
byte histograms for the perf loop.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Iterable, List, Tuple

__all__ = ["shape_bytes", "collective_bytes", "opcode_bytes", "count_ops"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|[a-z0-9_\[\]{},\s]*?)\s*"
    r"([a-z][a-z0-9\-]*(?:-start|-done)?)\(")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(text: str) -> List[float]:
    """Byte sizes of every dtype[dims] shape token in ``text``."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def _instructions(hlo_text: str) -> Iterable[Tuple[str, str]]:
    """(opcode, full line) for every instruction in every computation."""
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = _INSTR_RE.match(line)
        if m:
            yield m.group(1), line


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum of *output* shape bytes per collective opcode.

    For all-gather the output is the gathered (large) tensor; for
    reduce-scatter the input is the large one — we take max(result,
    operands)/result appropriately by summing ALL shape tokens on the line
    and halving (each line lists result + operands; collectives move ~the
    large side).  We report the conservative estimate: the largest shape on
    the line, per collective op.
    """
    out: Dict[str, float] = defaultdict(float)
    for opcode, line in _instructions(hlo_text):
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            sizes = shape_bytes(line)
            if sizes:
                out[base] += max(sizes)
    return dict(out)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(collective_bytes(hlo_text).values())


def opcode_bytes(hlo_text: str) -> Dict[str, float]:
    """Result-shape bytes summed per opcode (perf-loop diagnostics)."""
    out: Dict[str, float] = defaultdict(float)
    for opcode, line in _instructions(hlo_text):
        sizes = shape_bytes(line)
        if sizes:
            out[opcode] += sizes[0]
    return dict(out)


def count_ops(hlo_text: str, opcode_prefixes: Tuple[str, ...] = _COLLECTIVES
              ) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for opcode, _ in _instructions(hlo_text):
        base = opcode.replace("-start", "").replace("-done", "")
        if base.startswith(opcode_prefixes) and not opcode.endswith("-done"):
            out[base] += 1
    return dict(out)
