"""Dynamic Time Warping (paper §3.1.2, Eq. 1-2).

The paper's recurrence::

    D(i, j) = d(x_i, y_j) + min(D(i, j-1), D(i-1, j), D(i-1, j-1))

with ``d`` the pointwise Euclidean distance between utilization samples.

Three implementations, all agreeing to float tolerance:

* :func:`dtw_matrix` — pure-jnp, row-by-row ``lax.scan`` where each row is
  solved with a **min-plus associative scan** (the in-row dependence
  ``D[i,j] = min(m_j + d_j, D[i,j-1] + d_j)`` is an affine map in the
  tropical semiring, hence associative).  Depth O(N log M) instead of
  O(N·M); this is the TPU-friendly formulation and the ops-path default.
* ``repro.kernels.dtw`` — Pallas wavefront kernel (anti-diagonal
  parallelism across VPU lanes), validated against :mod:`ref` oracles.
* a numpy O(N·M) double loop lives in ``repro/kernels/dtw/ref.py`` as the
  oracle.

Backtracking (to build the warped series Y' of Eq. 3) is data-dependent and
O(N+M); it runs in numpy on the returned matrix.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cost_matrix",
    "dtw_matrix",
    "dtw_distance",
    "dtw_matrix_banded",
    "backtrack",
    "warp_to",
    "dtw_warp",
]

_INF = jnp.float32(3.0e38)


def cost_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise |x_i - y_j| (paper Eq. 2) -> [N, M]."""
    return jnp.abs(x[:, None] - y[None, :]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# min-plus scan formulation
# ---------------------------------------------------------------------------

def _minplus_row(prev_row: jax.Array, d_row: jax.Array) -> jax.Array:
    """Solve one DP row given the previous row.

    m_j   = min(D[i-1, j], D[i-1, j-1])
    D[i,j] = d[i,j] + min(m_j, D[i,j-1])
           = min(s_j, D[i,j-1] + a_j)   with s_j = m_j + d_j, a_j = d_j.

    The affine min-plus maps f_j(c) = min(c + a_j, s_j) compose
    associatively: (f2 o f1)(c) = min(c + a1 + a2, min(s1 + a2, s2)).
    """
    shifted = jnp.concatenate([jnp.full((1,), _INF, prev_row.dtype), prev_row[:-1]])
    m = jnp.minimum(prev_row, shifted)
    s = m + d_row
    a = d_row

    def combine(f1, f2):  # f1 applied first
        a1, s1 = f1
        a2, s2 = f2
        return a1 + a2, jnp.minimum(s1 + a2, s2)

    a_acc, s_acc = jax.lax.associative_scan(combine, (a, s))
    # initial carry c_{-1} = +inf  =>  D[i, j] = min(inf + a_acc, s_acc) = s_acc
    del a_acc
    return s_acc


@jax.jit
def dtw_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Full accumulated-cost matrix D — [N, M] (paper Eq. 1)."""
    d = cost_matrix(x, y)

    # Row 0: D[0, j] = cumsum(d[0, :j+1])
    row0 = jnp.cumsum(d[0])

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        return row, row

    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


@jax.jit
def dtw_distance(x: jax.Array, y: jax.Array) -> jax.Array:
    """Similarity distance D(N, M) between two series."""
    return dtw_matrix(x, y)[-1, -1]


# ---------------------------------------------------------------------------
# Sakoe-Chiba banded variant (beyond-paper: O(N*w) work)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_banded(x: jax.Array, y: jax.Array, band: int) -> jax.Array:
    """DTW restricted to |i*M/N - j| <= band.  Returns full [N, M] matrix
    with +inf outside the band (so backtracking still works)."""
    n, m = x.shape[0], y.shape[0]
    d = cost_matrix(x, y)
    jj = jnp.arange(m)

    def mask_row(i):
        center = (i * (m - 1)) // max(n - 1, 1)
        return (jnp.abs(jj - center) <= band)

    d = jnp.where(jax.vmap(mask_row)(jnp.arange(n)), d, _INF)
    row0 = jnp.where(mask_row(0), jnp.cumsum(d[0]), _INF)

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        row = jnp.where(d_row >= _INF, _INF, row)
        return row, row

    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


# ---------------------------------------------------------------------------
# Backtracking / warping (numpy; O(N+M), data-dependent)
# ---------------------------------------------------------------------------

def backtrack(D: np.ndarray) -> np.ndarray:
    """Minimum-distance path through D from (0,0) to (N-1,M-1).

    Returns an int array [P, 2] of (i, j) pairs, monotonically
    non-decreasing in both coordinates.
    """
    D = np.asarray(D)
    n, m = D.shape
    i, j = n - 1, m - 1
    path = [(i, j)]
    while i > 0 or j > 0:
        if i == 0:
            j -= 1
        elif j == 0:
            i -= 1
        else:
            candidates = (D[i - 1, j - 1], D[i - 1, j], D[i, j - 1])
            k = int(np.argmin(candidates))
            if k == 0:
                i, j = i - 1, j - 1
            elif k == 1:
                i -= 1
            else:
                j -= 1
        path.append((i, j))
    return np.asarray(path[::-1], dtype=np.int64)


def warp_to(y: np.ndarray, path: np.ndarray, n: int) -> np.ndarray:
    """Build Y' (length n, aligned with X) from Y by repeating elements
    along the DTW path (paper §3.1.2: "Y' is always made from Y by
    repeating some of its elements based on D(X,Y)")."""
    yp = np.empty(n, dtype=np.asarray(y).dtype)
    for i, j in path:          # path is sorted by i; later pairs overwrite
        yp[i] = y[j]
    return yp


def dtw_warp(x: np.ndarray, y: np.ndarray,
             band: Optional[int] = None) -> Tuple[np.ndarray, float]:
    """Full pipeline: DTW -> backtrack -> warped Y' and distance D(N,M)."""
    x = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    D = np.asarray(dtw_matrix(x, yj) if band is None
                   else dtw_matrix_banded(x, yj, band))
    path = backtrack(D)
    return warp_to(np.asarray(y), path, len(np.asarray(x))), float(D[-1, -1])
