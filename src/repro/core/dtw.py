"""Dynamic Time Warping (paper §3.1.2, Eq. 1-2).

The paper's recurrence::

    D(i, j) = d(x_i, y_j) + min(D(i, j-1), D(i-1, j), D(i-1, j-1))

with ``d`` the pointwise Euclidean distance between utilization samples.

Three implementations, all agreeing to float tolerance:

* :func:`dtw_matrix` — pure-jnp, row-by-row ``lax.scan`` where each row is
  solved with a **min-plus associative scan** (the in-row dependence
  ``D[i,j] = min(m_j + d_j, D[i,j-1] + d_j)`` is an affine map in the
  tropical semiring, hence associative).  Depth O(N log M) instead of
  O(N·M); this is the TPU-friendly formulation and the ops-path default.
* ``repro.kernels.dtw`` — Pallas wavefront kernel (anti-diagonal
  parallelism across VPU lanes), validated against :mod:`ref` oracles.
* a numpy O(N·M) double loop lives in ``repro/kernels/dtw/ref.py`` as the
  oracle.

Backtracking (to build the warped series Y' of Eq. 3) is data-dependent and
O(N+M); it runs in numpy on the returned matrix.

Batched bank API (matching-phase hot path)
------------------------------------------
The matching phase compares one query against *every* reference in the
database (paper Fig. 4-b), so the per-pair functions above would cost one
device dispatch per reference.  The ``*_bank`` / ``*_pairs`` functions
instead take all K references packed into one ``[K, M]`` array (padded to a
common length M, with an ``int32 [K]`` vector of true lengths) and solve
every DP in a single jit dispatch:

* :func:`dtw_distance_bank` — distances only; keeps one ``[K, M]`` DP row as
  the scan carry (no [K, N, M] matrix materialization) and reads each
  distance at the dynamic column ``lengths[k] - 1``.
* :func:`dtw_score_bank` / :func:`dtw_score_bank_many` /
  :func:`dtw_score_pairs` — **matrix-free offline scoring**: the Eq. 3
  warp correlation of complete queries, computed by carrying the
  warp-path correlation moments through the DP (backtrack-identical
  predecessor selection) and reading them at the closed alignment
  endpoint ``(N-1, lengths[k]-1)``.  One dispatch returns the final
  ``[K]`` / ``[J, K]`` / ``[P]`` scores — no matrix stack, no host
  backtracking; on TPU backends they route to the Pallas offline kernel
  (``kernels.dtw.score``).  This is the engine behind
  ``similarity.similarity_bank``, ``match_application`` and every
  ``TuningService`` finish verdict.
* :func:`dtw_matrix_bank` / :func:`dtw_matrix_pairs` — full matrices
  ``[K, N, M]`` for when the matrix itself is needed (``dtw_warp``
  consumers, ``similarity_bank(matrix_path=True)``'s reference scoring
  path).
* :class:`DtwBankState` / :func:`dtw_bank_init` / :func:`dtw_bank_extend` —
  the **streaming** engine: the DP state is carried across arriving query
  chunks (row-wise [K, M] carry), so an in-flight job can be matched while
  it executes; any chunking reproduces the one-shot solve exactly.
* :func:`bank_extend_tick` / :func:`bank_extend_tick_scored` — the
  **device-resident service tick** (serve.tuning's hot path): the same
  streaming recurrence evaluated along anti-diagonals of the chunk block
  (no per-sample [J, K, M] cost slab, no log(M) in-row scan), K-last
  layout so the reference axis vectorizes and shards, optionally fused
  with on-device open-end prefix scoring (warp-path correlation moments
  carried through the DP, [J, K] scores out — no row stack ever leaves
  the device).  On TPU both tick flavors route to the Pallas streaming
  kernels (``kernels.dtw.stream``): the distance-only tick via
  :func:`bank_extend_tick_dispatch`, the fused scoring tick via
  :func:`bank_extend_tick_scored_dispatch` (DP row AND the three moment
  slabs pinned in VMEM across the whole chunk).

Uncertain-series matching (variance mode)
-----------------------------------------
Real traces carry per-sample measurement noise; the variance-mode
entry points (:func:`bank_extend_tick_scored_var`,
``dtw_score_bank_many(xvars=...)`` and their Pallas twins) propagate a
per-sample variance ``v_i`` through the SAME warp path and emit a match
*probability* P[true warp correlation >= threshold] beside the point
score.  Slab layout: the moment slab doubles from three channels
(sy, syy, sxy) to SIX — (sy, syy, sxy, svy, svyy, svxy), where channel
3 + c's per-cell delta is exactly ``v_i * delta_c`` — and the
path-independent query folds grow a [·, 3] ``vstats`` = (sv, svx, svxx)
companion to sx/sxx.  The probability tail (:func:`_prob_from_moments`,
one definition shared by every path, exactly like
:func:`_corr_from_moments` for the point score) disattenuates the
observed correlation for noise-inflated query variance and applies
first-order (delta-method) error propagation; zero input variance
reduces BITWISE to the point rule, so variance mode is a strict
generalization.  Exact-mode entry points are untouched (separate jitted
functions, unchanged compiled graphs).

Two probability modes share that machinery:

* **exact** (six channels, above) — the verdict tail.  ``finish()``
  scoring and every offline probability goes through it; its numbers
  are the contract.
* **approx** (:func:`bank_extend_tick_scored_var_approx`, FOUR
  channels) — the serving tail.  Only ``svy = Σ v_i·y~_j(i)`` rides the
  warp path beside (sy, syy, sxy); the two dropped channels (svyy,
  svxy) are reconstructed at the score tail by
  :func:`_prob_from_moments_approx` from the carried proxy plus the
  path-independent folds, via the warp-path regression ``y~ ≈ α + β·x~``
  (see its docstring).  Slab traffic drops from 7 carried channels
  (cell + 6) to 5 (cell + 3 + 1) — ~1.3x the exact *scored* tick
  instead of ~2x — which is what makes probability-gated serving
  affordable at every tick (``serve.tuning prob_mode="approx"``).
  Zero input variance reduces BITWISE to the same point rule as the
  exact tail, and the approx probability computed from an exact
  six-channel slab's first four channels is bit-identical to the
  dedicated four-channel carry (channel 3 IS svy in both layouts).

Padding correctness: ``D[:, j]`` only ever depends on columns ``<= j`` and
rows ``<= i``, so values in the padded tail cannot reach ``D[n-1, len_k-1]``
— banks may be padded with anything; we pad with the series' edge value.
The banded variants re-derive the Sakoe-Chiba band per series from its
*true* length (dynamic ``lengths[k]``), so a banked banded solve is exactly
the scalar banded solve of the unpadded series.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cost_matrix",
    "dtw_matrix",
    "dtw_distance",
    "dtw_matrix_banded",
    "dtw_matrix_bank",
    "dtw_matrix_pairs",
    "dtw_distance_bank",
    "dtw_score_bank",
    "dtw_score_bank_many",
    "dtw_score_pairs",
    "query_moments",
    "query_var_moments",
    "ScoreBankPlan",
    "build_score_plan",
    "DtwBankState",
    "dtw_bank_init",
    "dtw_bank_extend",
    "bank_extend_tick",
    "bank_extend_tick_scored",
    "bank_extend_tick_scored_var",
    "bank_extend_tick_scored_var_approx",
    "bank_extend_tick_dispatch",
    "bank_extend_tick_scored_dispatch",
    "bank_extend_tick_scored_var_dispatch",
    "bank_extend_tick_scored_var_approx_dispatch",
    "backtrack",
    "warp_to",
    "dtw_warp",
]

_INF = jnp.float32(3.0e38)


def cost_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise |x_i - y_j| (paper Eq. 2) -> [N, M]."""
    return jnp.abs(x[:, None] - y[None, :]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# min-plus scan formulation
# ---------------------------------------------------------------------------

def _minplus_affine_scan(a: jax.Array, s: jax.Array) -> jax.Array:
    """Inclusive composition of min-plus affine maps f_j(c) = min(c + a_j,
    s_j) along the last axis, applied to the initial carry c_{-1} = +inf.

    The maps compose associatively: (f2 o f1)(c) = min(c + a1 + a2,
    min(s1 + a2, s2)).  Applying the prefix composition to +inf leaves only
    the s-part.
    """

    def combine(f1, f2):  # f1 applied first
        a1, s1 = f1
        a2, s2 = f2
        return a1 + a2, jnp.minimum(s1 + a2, s2)

    _, s_acc = jax.lax.associative_scan(combine, (a, s), axis=-1)
    return s_acc


def _minplus_row(prev_row: jax.Array, d_row: jax.Array) -> jax.Array:
    """Solve one DP row given the previous row.

    m_j   = min(D[i-1, j], D[i-1, j-1])
    D[i,j] = d[i,j] + min(m_j, D[i,j-1])
           = min(s_j, D[i,j-1] + a_j)   with s_j = m_j + d_j, a_j = d_j.
    """
    shifted = jnp.concatenate([jnp.full((1,), _INF, prev_row.dtype),
                               prev_row[:-1]])
    m = jnp.minimum(prev_row, shifted)
    return _minplus_affine_scan(d_row, m + d_row)


@jax.jit
def dtw_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Full accumulated-cost matrix D — [N, M] (paper Eq. 1)."""
    d = cost_matrix(x, y)

    # Row 0: D[0, j] = cumsum(d[0, :j+1])
    row0 = jnp.cumsum(d[0])

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        return row, row

    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


@jax.jit
def dtw_distance(x: jax.Array, y: jax.Array) -> jax.Array:
    """Similarity distance D(N, M) between two series."""
    return dtw_matrix(x, y)[-1, -1]


# ---------------------------------------------------------------------------
# Sakoe-Chiba banded variant (beyond-paper: O(N*w) work)
# ---------------------------------------------------------------------------

def _lengths_or_full(lengths: Optional[jax.Array], k: int, m: int) -> jax.Array:
    """int32 [K] true-length vector; defaults to the full padded width."""
    return jnp.asarray(lengths, jnp.int32) if lengths is not None \
        else jnp.full((k,), m, jnp.int32)


def _band_center(i: jax.Array, qlen: jax.Array, rlen: jax.Array) -> jax.Array:
    """Sakoe-Chiba band center (reference-axis column) of query row(s) i
    for a (qlen, rlen) series pair — THE band geometry; every banded
    variant (scalar, bank, pairs, wavefront) must derive its mask from
    this so batched == scalar stays structural."""
    return (i * (rlen - 1)) // jnp.maximum(qlen - 1, 1)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_banded(x: jax.Array, y: jax.Array, band: int) -> jax.Array:
    """DTW restricted to |i*M/N - j| <= band.  Returns full [N, M] matrix
    with +inf outside the band (so backtracking still works)."""
    return _masked_matrix(x, y, None, None, band)


# ---------------------------------------------------------------------------
# Batched bank / pairs API (matching-phase hot path; single jit dispatch)
# ---------------------------------------------------------------------------

def _band_mask(n: int, m: int, qlen: jax.Array, rlen: jax.Array,
               band: int) -> jax.Array:
    """Sakoe-Chiba mask [n, m] for a (qlen, rlen) series pair embedded in an
    [n, m] padded grid.  For j < rlen, i < qlen this is exactly the mask of
    the unpadded scalar solve; the padded region is don't-care."""
    ii = jnp.arange(n, dtype=jnp.int32)[:, None]
    jj = jnp.arange(m, dtype=jnp.int32)[None, :]
    return jnp.abs(jj - _band_center(ii, qlen, rlen)) <= band


def _masked_matrix(x: jax.Array, y: jax.Array, qlen: Optional[jax.Array],
                   rlen: Optional[jax.Array], band: Optional[int]) -> jax.Array:
    """Full [N, M] accumulated-cost matrix for one (possibly padded) pair.
    Unbanded padding needs no mask at all: D[i, j] depends only on cells
    (<=i, <=j), so the valid region is untouched by the padded tail."""
    d = cost_matrix(x, y)
    n, m = d.shape
    if band is not None:
        ql = jnp.int32(n) if qlen is None else qlen.astype(jnp.int32)
        rl = jnp.int32(m) if rlen is None else rlen.astype(jnp.int32)
        d = jnp.where(_band_mask(n, m, ql, rl, band), d, _INF)

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        if band is not None:
            row = jnp.where(d_row >= _INF, _INF, row)
        return row, row

    row0 = jnp.where(d[0] >= _INF, _INF, jnp.cumsum(d[0])) if band is not None \
        else jnp.cumsum(d[0])
    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_bank(x: jax.Array, bank: jax.Array,
                    lengths: Optional[jax.Array] = None,
                    band: Optional[int] = None) -> jax.Array:
    """One query x [N] against a padded bank [K, M] -> D matrices [K, N, M].

    ``lengths`` (int32 [K], true series lengths) is only consulted by the
    banded variant (the band is re-derived per series from its true
    length); callers slice ``D[k, :, :lengths[k]]`` before backtracking.
    """
    x = jnp.asarray(x, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    if band is None:
        return jax.vmap(lambda y: _masked_matrix(x, y, None, None, None))(bank)
    ls = _lengths_or_full(lengths, bank.shape[0], bank.shape[1])
    return jax.vmap(
        lambda y, l: _masked_matrix(x, y, None, l, band))(bank, ls)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_pairs(xs: jax.Array, ys: jax.Array,
                     xlens: Optional[jax.Array] = None,
                     ylens: Optional[jax.Array] = None,
                     band: Optional[int] = None) -> jax.Array:
    """Pairwise batched DTW: queries xs [P, N] vs references ys [P, M] ->
    D matrices [P, N, M], one jit dispatch for all P pairs (used to batch
    the whole of ``match_application`` — every (param set, app) pair at
    once, ragged on both sides)."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if band is None:
        return jax.vmap(
            lambda x, y: _masked_matrix(x, y, None, None, None))(xs, ys)
    p = xs.shape[0]
    ql = _lengths_or_full(xlens, p, xs.shape[1])
    rl = _lengths_or_full(ylens, p, ys.shape[1])
    return jax.vmap(
        lambda x, y, a, b: _masked_matrix(x, y, a, b, band))(xs, ys, ql, rl)


#: Out-of-range sentinel for the wavefront cost gather: large enough that
#: |x - _BIG| dominates any real path cost, small enough that a handful of
#: additions stay representable before saturating at f32 +inf (which the
#: min-reductions handle fine either way).
_BIG = jnp.float32(1.0e38)

#: lax.scan unroll factor for the wavefront distance scan; 2 measurably
#: beats 1 and 4 on CPU (less loop overhead vs. live-range pressure).
_WAVEFRONT_UNROLL = 2


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_distance_bank(x: jax.Array, bank: jax.Array,
                      lengths: Optional[jax.Array] = None,
                      band: Optional[int] = None) -> jax.Array:
    """Distances D(N, len_k) of one query against the whole bank -> [K].

    Anti-diagonal wavefront formulation: cell (i, j) lives on diagonal
    t = i + j at slot i, so the recurrence

        c_t[i] = d(i, t-i) + min(c_{t-1}[i], c_{t-1}[i-1], c_{t-2}[i-1])

    is purely elementwise over a [K, N] diagonal slab — O(K·N·M) total
    work with **no** log(M) scan factor, N+M-1 scan steps total (vs K·N
    for a per-pair loop), and a [K, N] carry (never [K, N, M]).  The cost
    diagonal d(·, t-·) is one contiguous dynamic-slice of the reversed,
    sentinel-padded bank.  Each distance is D[N-1, len_k-1], i.e. slot
    N-1 of diagonal t = N + len_k - 2; padding beyond ``lengths[k]`` can
    never influence it (D[i, j] depends only on cells (<=i, <=j)).

    The banded variant masks each diagonal with the per-series
    Sakoe-Chiba corridor re-derived from true lengths, so it equals the
    scalar ``dtw_matrix_banded(x, y_k[:len_k], band)[-1, -1]`` loop.
    """
    x = jnp.asarray(x, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    k, m = bank.shape
    n = x.shape[0]
    ls = _lengths_or_full(lengths, k, m)

    # reversed bank, sentinel-padded so slot i of diagonal t reads
    # y[t - i] = yrp[:, (n + m - 1 - t) + i] (out-of-range j -> _BIG).
    yrp = jnp.concatenate([jnp.full((k, n), _BIG), bank[:, ::-1],
                           jnp.full((k, n), _BIG)], axis=1)
    ii = jnp.arange(n, dtype=jnp.int32)
    if band is not None:
        # Sakoe-Chiba center of row i for series k (true length ls[k]).
        centers = _band_center(ii[None, :], jnp.int32(n),
                               ls[:, None])                      # [K, N]

    def step(carry, t):
        prev, prev2 = carry                     # c_{t-1}, c_{t-2}: [K, N]
        yd = jax.lax.dynamic_slice(yrp, (0, n + m - 1 - t), (k, n))
        d = jnp.abs(x[None, :] - yd)
        if band is not None:
            jj = t - ii                          # column of slot i
            d = jnp.where(jnp.abs(jj[None, :] - centers) <= band, d, _INF)
        # virtual corner D[-1, -1] = 0 enters as the shifted-in value of
        # the diagonal predecessor on the t == 0 step only.
        corner = jnp.where(t == 0, jnp.float32(0.0), _INF)
        p_left = jnp.concatenate(
            [jnp.full((k, 1), _INF), prev[:, : n - 1]], axis=1)
        p_diag = jnp.concatenate(
            [jnp.full((k, 1), corner), prev2[:, : n - 1]], axis=1)
        c = d + jnp.minimum(jnp.minimum(prev, p_left), p_diag)
        return (c, prev), c[:, n - 1]

    init = (jnp.full((k, n), _INF), jnp.full((k, n), _INF))
    _, outs = jax.lax.scan(step, init,
                           jnp.arange(n + m - 1, dtype=jnp.int32),
                           unroll=_WAVEFRONT_UNROLL)
    # distance_k = slot n-1 of diagonal n - 1 + (len_k - 1)
    return jnp.take_along_axis(outs.T, (ls + (n - 2))[:, None],
                               axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Streaming (prefix) bank DTW — the online matching engine
# ---------------------------------------------------------------------------
#
# The offline ``dtw_distance_bank`` wavefront needs the full query up front
# (its carry is indexed by query row).  The streaming engine instead carries
# the *row-wise* DP state: after consuming i query samples the state holds
# D[i-1, :] for every reference — a single [K, M] slab — and each new sample
# applies one ``_minplus_row`` update.  Any chunking of the query therefore
# reproduces the one-shot solve exactly: the DP recurrence is identical,
# only the dispatch boundaries move (tests/test_streaming.py pins this
# under random chunkings, ragged and banded).
#
# Row 0 rides on the same update via a virtual corner: D[-1, -1] = 0 enters
# as the shifted-in value of the first update only, turning it into the
# cumsum initialisation of ``dtw_matrix``.
#
# Everything is batched one level further for the serving layer: the jitted
# kernel takes J independent in-flight jobs stacked as [J, K, M] rows so a
# whole tick of a multi-job service is ONE device dispatch (invalid tail
# samples of ragged per-job chunks are masked out and leave the state
# untouched).

#: Chunks are padded up to the next power of two (>= _CHUNK_MIN) before
#: hitting the jitted kernel so arbitrary tick sizes reuse a handful of
#: compiled shapes.
_CHUNK_MIN = 8


def _chunk_bucket(c: int) -> int:
    return max(_CHUNK_MIN, 1 << (max(c, 1) - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("band", "collect_rows"))
def _bank_extend_many(rows: jax.Array, ns: jax.Array, bank: jax.Array,
                      lengths: jax.Array, chunks: jax.Array,
                      nvalid: jax.Array, qlens: jax.Array,
                      band: Optional[int], collect_rows: bool):
    """Advance J streaming DPs by one padded chunk each — one dispatch.

    rows    [J, K, M]  last DP row per job (init +inf)
    ns      [J] int32  query samples consumed per job
    chunks  [J, C]     new samples (tail beyond ``nvalid[j]`` is ignored)
    qlens   [J] int32  expected total query length (banded variant only;
                       the Sakoe-Chiba center of row i needs it)

    Returns (rows, ns, collected) where ``collected`` is the [C, J, K, M]
    stack of post-step rows (the D-matrix rows the scoring layer backtracks
    over) when ``collect_rows``, else None.
    """
    j, c = chunks.shape
    k, m = bank.shape
    jj = jnp.arange(m, dtype=jnp.int32)

    def step(carry, inp):
        rows, ns = carry
        x_s, s = inp                               # [J], scalar
        valid = s < nvalid                         # [J]
        d = jnp.abs(x_s[:, None, None] - bank[None, :, :])     # [J, K, M]
        if band is not None:
            centers = _band_center(ns[:, None], qlens[:, None],
                                   lengths[None, :])           # [J, K]
            d = jnp.where(
                jnp.abs(jj[None, None, :] - centers[:, :, None]) <= band,
                d, _INF)
        # virtual corner D[-1, -1] = 0 for each job's first sample only
        corner = jnp.where(ns == 0, jnp.float32(0.0), _INF)    # [J]
        shifted = jnp.concatenate(
            [jnp.broadcast_to(corner[:, None, None], (j, k, 1)),
             rows[:, :, :-1]], axis=2)
        mn = jnp.minimum(rows, shifted)
        new = _minplus_affine_scan(d, mn + d)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        rows = jnp.where(valid[:, None, None], new, rows)
        ns = ns + valid.astype(jnp.int32)
        return (rows, ns), (rows if collect_rows else jnp.zeros((0,)))

    (rows, ns), collected = jax.lax.scan(
        step, (rows, ns), (chunks.T, jnp.arange(c, dtype=jnp.int32)))
    return rows, ns, (collected if collect_rows else None)


# ---------------------------------------------------------------------------
# Device-resident streaming tick: wavefront chunk-extend + fused prefix
# scoring (the serving-layer hot path; see serve/tuning.py)
# ---------------------------------------------------------------------------
#
# ``_bank_extend_many`` advances row-by-row: every query sample costs a full
# [J, K, M] cost slab plus a log(M) Hillis-Steele scan over the reference
# axis — fine as a reference formulation, but the slab traffic dominates a
# service tick.  ``_bank_extend_diag_impl`` instead sweeps the [C, M] chunk
# block along anti-diagonals (the ``dtw_distance_bank`` trick lifted to a
# *resumable* chunk): cell (i, j) lives on diagonal t = i + j at slot i, so
# each of the C + M - 1 steps is a purely elementwise update of a [J, K, C]
# diagonal — no in-row scan, no [J, K, M] intermediate at all, and the
# previous tick's DP row enters as the t-indexed boundary of the block.
# Ragged per-job chunks pass through by forcing the vertical predecessor for
# padded samples (the row above slides down unchanged, keeping column
# alignment for the final-row extraction at slot C - 1).
#
# The same sweep optionally fuses the scoring layer on-device.  The host
# scorer (``similarity.prefix_similarity_bank``) backtracks D and
# correlates the query against the warped reference — which forces the
# [C, S, K, M] row stack back to the host every tick.  Instead we carry the
# warp-path correlation moments *forward* through the DP: each cell picks
# the predecessor ``backtrack`` would pick (argmin over (diag, vert, horiz)
# with the same tie order), and updates running (sy, syy, sxy) moments of
# the aligned pairs along that path.  ``warp_to`` keeps one pair per query
# row (later columns overwrite), so the transitions are
#
#     diag/vert:  m(i, j) = m(pred) + pair(x_i, y_j)
#     horiz:      m(i, j) = m(i, j-1) - pair(x_i, y_{j-1}) + pair(x_i, y_j)
#
# and the moments at the open-end argmin of the final row reproduce the
# host backtrack + RunningMoments score — without ever materializing a row
# stack.  sx/sxx/n are path-independent (one pair per query row) and ride
# as [J] scalars.  Values are centered by ``_MOM_SHIFT`` before
# accumulation (correlation is shift-invariant; centering keeps the f32
# cancellation in cov = sxy - sx*sy/n benign for [0, 1] utilization data).
#
# Tick layout: the tick functions put K on the LAST axis (state [J, M, K],
# bank transposed to [M, K]) so every diagonal update vectorizes over the
# large reference axis instead of the small chunk axis — measured 1.5-3x
# on CPU over the K-major layout, and it makes sharding the bank a plain
# last-axis partition.  The offline/collect APIs (``DtwBankState``,
# ``_bank_extend_many``) keep their [K, M] layout; ``serve.tuning`` owns
# the transposed state.

#: Center for the on-device correlation moments (utilization series live
#: in [0, 1]; any constant shift leaves the correlation unchanged).
_MOM_SHIFT = jnp.float32(0.5)

#: Sentinel guard: reference values beyond this magnitude are padding from
#: the reversed-bank gather, not data — their moment contribution is
#: zeroed so f32 overflow can never poison a valid path's accumulators.
_Y_VALID = jnp.float32(1.0e30)


def _bank_extend_diag_impl(rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                           nvalid, qlens, *, band: Optional[int],
                           score: bool, vchunks=None, vstats=None,
                           threshold: Optional[float] = None):
    """Wavefront chunk-extend of J streaming bank DPs, optionally fused
    with on-device open-end prefix scoring.  Pure function of arrays (jit
    and shard_map wrappers live below / in serve.tuning) — everything is
    elementwise per reference k, so sharding the K axis is exact.

    rows    [J, M, K]    last DP row per job (init +inf), K-last layout
    moms    [3, J, M, K] warp-path (sy, syy, sxy) moments of ``rows``'s
                         cells (init 0; ignored unless ``score``)
    ns      [J] int32    query samples consumed per job
    sx, sxx [J] f32      centered query moment scalars (ignored w/o score)
    bank_t  [M, K]       transposed reference bank
    chunks  [J, C]       new samples (tail beyond ``nvalid[j]`` ignored)
    qlens   [J] int32    total expected query length (banded only)

    Variance mode (``vchunks`` [J, C] per-sample measurement variances,
    ``vstats`` [J, 3] running (sv, svx, svxx) folds, ``threshold`` the
    static match threshold): ``moms`` doubles to SIX channels
    [6, J, M, K] — (sy, syy, sxy, svy, svyy, svxy), where each variance
    channel's per-cell delta is exactly ``v_i *`` the matching base
    channel's delta, so the identical anchored/telescoped transitions
    propagate them along the same backtrack-identical warp path.  A
    FOUR-channel ``moms`` [4, J, M, K] selects the approx tail instead:
    only the svy proxy rides the path (delta ``v_i * delta_sy``) and
    the probability reduction is :func:`_prob_from_moments_approx`.

    Returns ``(rows, moms, ns, sx, sxx, scores)``; ``scores`` is the
    [J, K] open-end warp correlation per (job, reference) when ``score``
    (the fused replacement for host ``prefix_similarity_bank``), else a
    zero-size placeholder.  In variance mode two more results follow:
    ``(..., vstats2, probs)`` with ``probs`` [J, K] the
    :func:`_prob_from_moments` match probabilities at the same open-end
    endpoints.  Cell values match ``_bank_extend_many`` to f32
    tolerance (same recurrence, different evaluation order).
    """
    j, c = chunks.shape
    m, k = bank_t.shape
    ii = jnp.arange(c, dtype=jnp.int32)
    # reversed, sentinel-padded bank: slot i of diagonal t reads y[t - i]
    # (out-of-grid columns -> _BIG, which |x - .| turns into a huge cost).
    yrp = jnp.concatenate([jnp.full((c, k), _BIG), bank_t[::-1],
                           jnp.full((c, k), _BIG)], axis=0)        # [M+2C, K]
    # virtual corner D[-1, -1] = 0 for each job's very first sample.
    corner = jnp.where(ns == 0, jnp.float32(0.0), _INF)            # [J]
    # boundary row of the chunk block plus its moments, merged into ONE
    # diagonal-indexed array so each step needs a single dynamic slice:
    # index t is the diag predecessor D[-1, t-1], t + 1 the vert D[-1, t].
    prow = jnp.concatenate(
        [jnp.broadcast_to(corner[:, None, None], (j, 1, k)), rows,
         jnp.full((j, c, k), _INF)], axis=1)                       # [J,M+C+1,K]
    nch = moms.shape[0]                       # 3, or 6 in variance mode
    if score:
        bpad = jnp.concatenate(
            [prow[None], jnp.concatenate(
                [jnp.zeros((nch, j, 1, k)), moms,
                 jnp.zeros((nch, j, c, k))], axis=2)], axis=0)  # [1+nch,J,.,K]
    else:
        bpad = prow[None]
    valid = ii[None, :] < nvalid[:, None]                          # [J, C]
    xm = chunks - _MOM_SHIFT                                       # [J, C]
    if band is not None:
        centers = _band_center((ns[:, None] + ii[None, :])[:, :, None],
                               qlens[:, None, None],
                               lengths[None, None, :])             # [J, C, K]

    def step(carry, t):
        # Diagonal-reuse carry: step t's diag predecessors and previous-
        # column deltas equal step t-1's vert predecessors and deltas
        # bit-for-bit (both splice bpad[..., t] ahead of the t-2
        # diagonal; delta(t-1) pairs x_i with y[t-1-i] exactly as
        # delta_prev(t) would), so they ride in the carry instead of
        # being re-gathered/re-multiplied every step — one slab copy per
        # moment channel per step instead of two, which is what keeps
        # the 6-channel variance slab's tick well under 2x the
        # 3-channel tick's cost.
        prev, pvert, mprev, mvert, dprev = carry    # [J,C,K] / [nch,J,C,K]
        # y diagonal: slot i of diagonal t reads y[t - i].
        yd = jax.lax.dynamic_slice(yrp, (c + m - 1 - t, 0), (c, k))
        d = jnp.abs(chunks[:, :, None] - yd[None])                 # [J,C,K]
        if band is not None:
            d = jnp.where(jnp.abs((t - ii)[None, :, None] - centers)
                          <= band, d, _INF)
        bsl = jax.lax.dynamic_slice(bpad, (0, 0, t + 1, 0),
                                    (bpad.shape[0], j, 1, k))
        p_vert = jnp.concatenate([bsl[0], prev[:, : c - 1]], axis=1)
        p_diag = pvert
        p_horiz = prev
        best = jnp.minimum(jnp.minimum(p_diag, p_vert), p_horiz)
        # clamp at _INF: keeps banded / out-of-grid cells finite (f32
        # would overflow to inf after a few accumulations otherwise).
        cell = jnp.minimum(d + best, _INF)
        # padded samples pass through vertically: the row above slides
        # down unchanged, so slot C-1 always carries the last VALID row.
        cell = jnp.where(valid[:, :, None], cell, p_vert)
        if not score:
            return (cell, p_vert, mprev, mvert, dprev), cell[:, c - 1]

        # -- fused warp-path moments ------------------------------------
        yc = jnp.where(jnp.abs(yd) < _Y_VALID, yd - _MOM_SHIFT, 0.0)
        ycb = jnp.broadcast_to(yc[None, None], (1, j, c, k))
        delta = jnp.concatenate(
            [ycb, ycb * ycb, xm[None, :, :, None] * ycb], axis=0)
        if vchunks is not None:
            # variance channels: v_i times the matching base channel,
            # so the same transitions carry them along the same path.
            # Exact mode (nch == 6) twins all three base channels;
            # approx mode (nch == 4) twins only sy — the svy proxy.
            delta = jnp.concatenate(
                [delta, vchunks[None, :, :, None] * delta[:nch - 3]],
                axis=0)
        m_vert = jnp.concatenate([bsl[1:], mprev[:, :, : c - 1]], axis=2)
        m_diag = mvert
        # predecessor choice mirrors backtrack()'s np.argmin tie order:
        # diag first, then vert, then horiz.
        sel_diag = p_diag <= jnp.minimum(p_vert, p_horiz)          # [J,C,K]
        sel_vert = jnp.logical_and(~sel_diag, p_vert <= p_horiz)
        m_base = jnp.where(sel_diag[None], m_diag,
                           jnp.where(sel_vert[None], m_vert,
                                     mprev - dprev))
        m_cell = jnp.where(valid[None, :, :, None], m_base + delta,
                           m_vert)
        return (cell, p_vert, m_cell, m_vert, delta), (cell[:, c - 1],
                                                       m_cell[:, :, c - 1])

    minit = jnp.zeros((nch, j, c, k)) if score else jnp.zeros((3, 1, 1, 1))
    # pvert's init is step 0's diag predecessor: the boundary column
    # bpad[..., 0] (the virtual corner / carried row) ahead of +inf;
    # dprev's init is delta(-1) == 0 (step 0's previous column is the
    # all-sentinel diagonal, whose masked deltas vanish).
    pvinit = jnp.concatenate([prow[:, 0:1], jnp.full((j, c - 1, k), _INF)],
                             axis=1)
    init = (jnp.full((j, c, k), _INF), pvinit, minit, minit, minit)
    _, outs = jax.lax.scan(step, init,
                           jnp.arange(c + m - 1, dtype=jnp.int32),
                           unroll=_WAVEFRONT_UNROLL)
    if score:
        row_outs, mom_outs = outs
    else:
        row_outs, mom_outs = outs, None
    # slot C-1 finishes column j = t - (C-1): steps C-1 .. C+M-2 emit the
    # post-chunk DP row (and its moments) column by column.
    new_rows = row_outs[c - 1:].transpose(1, 0, 2)                 # [J, M, K]
    ns2 = ns + nvalid
    if not score:
        return new_rows, moms, ns2, sx, sxx, jnp.zeros((j, 0))

    new_moms = mom_outs[c - 1:].transpose(1, 2, 0, 3)            # [nch,J,M,K]
    vmask = valid.astype(jnp.float32)
    sx2 = sx + jnp.sum(xm * vmask, axis=1)
    sxx2 = sxx + jnp.sum(xm * xm * vmask, axis=1)
    if vchunks is None:
        scores = _moment_scores(new_rows, new_moms, ns2, sx2, sxx2, lengths)
        return new_rows, new_moms, ns2, sx2, sxx2, scores
    vq = vchunks * vmask
    vstats2 = vstats + jnp.stack(
        [jnp.sum(vq, axis=1), jnp.sum(vq * xm, axis=1),
         jnp.sum(vq * xm * xm, axis=1)], axis=1)                 # [J, 3]
    scores = _moment_scores(new_rows, new_moms[:3], ns2, sx2, sxx2, lengths)
    prob_fn = _moment_scores_prob if nch == 6 else _moment_scores_prob_approx
    probs = prob_fn(new_rows, new_moms, ns2, sx2, sxx2,
                    vstats2, lengths, threshold)
    return new_rows, new_moms, ns2, sx2, sxx2, scores, vstats2, probs


def _corr_from_moments(sy, syy, sxy, sx, sxx, n):
    """``similarity.RunningMoments``'s correlation formula (and degenerate
    conventions) evaluated elementwise from broadcast-compatible moment
    arrays.  THE single definition of the on-device score tail: the fused
    streaming tick, the offline scorers and the Pallas offline kernel all
    call this, so device scores can only differ by the moments they feed
    in."""
    vx = jnp.maximum(sxx - sx * sx / n, 0.0)
    vy = jnp.maximum(syy - sy * sy / n, 0.0)
    cov = sxy - sx * sy / n
    denom = jnp.sqrt(vx * vy)
    corr = jnp.clip(cov / jnp.where(denom > 0, denom, 1.0), -1.0, 1.0)
    # Degeneracy is judged RELATIVE to the cancellation scale: a constant
    # f32 prefix does not yield vx == 0 but vx ~ eps * (sxx + sx^2/n)
    # (rounding garbage from the catastrophic cancellation), so an
    # absolute epsilon let garbage/garbage through as an arbitrary
    # clipped "correlation" that silently poisoned rankings.  Variance
    # within ~1e-5 of the cancellation scale is rounding noise, not
    # signal: the score is pinned to the degenerate conventions (1.0
    # for an identical constant pair, else 0.0).
    degx = vx <= 1e-5 * (sxx + sx * sx / n) + 1e-12
    degy = vy <= 1e-5 * (syy + sy * sy / n) + 1e-12
    both = degx & degy & (jnp.abs(sx - sy) / n < 1e-6)
    return jnp.where(degx | degy, jnp.where(both, 1.0, 0.0), corr)


def _moment_scores(rows, moms, ns, sx, sxx, lengths):
    """Open-end warp correlation per (job, reference) -> [J, K].

    The on-device tail of the fused scorer: mask the DP row to true
    columns, take the open-end argmin (the best reference *prefix*), read
    the warp-path moments at that cell, and evaluate the correlation with
    ``similarity.RunningMoments``'s formula and degenerate conventions.
    """
    m = rows.shape[1]
    colmask = jnp.arange(m, dtype=jnp.int32)[:, None] < lengths[None, :]
    masked = jnp.where(colmask[None], rows, _INF)
    j_end = jnp.argmin(masked, axis=1)                             # [J, K]
    msel = jnp.take_along_axis(moms, j_end[None, :, None, :],
                               axis=2)[:, :, 0, :]                 # [3, J, K]
    n = jnp.maximum(ns, 1).astype(jnp.float32)[:, None]            # [J, 1]
    out = _corr_from_moments(msel[0], msel[1], msel[2], sx[:, None],
                             sxx[:, None], n)
    # empty slots (no samples yet) follow RunningMoments' n == 0
    # convention — score 0, not the vacuous all-zero-moments 1.0.
    return jnp.where(ns[:, None] > 0, out, 0.0)


def _prob_from_moments(sy, syy, sxy, svy, svyy, svxy, sx, sxx,
                       sv, svx, svxx, n, threshold):
    """Match probability P[true warp correlation >= threshold] from the
    variance-carrying moment slabs — THE single probabilistic score tail
    (streaming tick, offline jnp scorer and the Pallas twins all call
    this, exactly like :func:`_corr_from_moments` for the point score).

    Model: each query sample x_i carries measurement variance v_i.  With
    the warp path held fixed (one aligned pair per query row, the
    ``warp_to`` convention), the observed correlation r is a smooth
    function of the moment sums, so first-order (delta-method) error
    propagation gives

        dr/dx_i  = a + 2 b x~_i + c y~_j(i)
        sigma_r^2 = a^2 sv + 4ab svx + 4b^2 svxx
                    + 2ac svy + 4bc svxy + c^2 svyy

    with a = dr/dsx, b = dr/dsxx, c = dr/dsxy = 1/sqrt(vx*vy) — every
    sum is one of the six path accumulators, carried through the DP by
    the same telescoping transitions as (sy, syy, sxy) (the variance
    channels are exactly ``v_i *`` the base channels).  Noise also
    BIASES r downward (it inflates vx while leaving cov unbiased), so r
    is disattenuated by sqrt(vx / (vx - sv)) — capped at 2x so a
    variance overestimate cannot manufacture a match — before the tail
    probability Phi((r^ - threshold) / sigma_r) is taken.

    Zero input variance makes every v-moment zero: the disattenuation
    factor is exactly 1.0 (vx/vx), sigma_r is exactly 0, and the result
    reduces BITWISE to the point rule ``r >= threshold`` (probability in
    {0.0, 1.0}), which is what pins probabilistic == point decisions on
    noise-free traces.
    """
    r = _corr_from_moments(sy, syy, sxy, sx, sxx, n)
    vx = jnp.maximum(sxx - sx * sx / n, 0.0)
    vy = jnp.maximum(syy - sy * sy / n, 0.0)
    denom = jnp.sqrt(vx * vy)
    safe_vx = jnp.where(vx > 0, vx, 1.0)
    # disattenuation: E[vx_obs] = vx_true + sv, cov unbiased.
    den = jnp.clip(vx - sv, vx * 0.25, vx)
    g = jnp.where(den > 0, jnp.sqrt(vx / jnp.where(den > 0, den, 1.0)),
                  1.0)
    r_hat = jnp.clip(r * g, -1.0, 1.0)
    c = 1.0 / jnp.where(denom > 0, denom, 1.0)
    a = -c * sy / n + r * sx / (n * safe_vx)
    b = -r / (2.0 * safe_vx)
    var_r = (a * a * sv + 4.0 * a * b * svx + 4.0 * b * b * svxx
             + 2.0 * a * c * svy + 4.0 * b * c * svxy + c * c * svyy)
    sigma = jnp.sqrt(jnp.maximum(var_r, 0.0))
    z = (r_hat - threshold) / jnp.where(sigma > 0, sigma, 1.0)
    phi = 0.5 * jax.lax.erfc(-z / jnp.sqrt(jnp.float32(2.0)))
    point = (r_hat >= threshold).astype(phi.dtype)
    return jnp.where(sigma > 0, phi, point)


def _moment_scores_prob(rows, moms, ns, sx, sxx, vstats, lengths,
                        threshold):
    """Open-end match probability per (job, reference) -> [J, K].

    The probabilistic twin of :func:`_moment_scores`: same masked
    open-end argmin endpoint, but the gather reads all SIX moment
    channels ([6, J, M, K] slab: (sy, syy, sxy, svy, svyy, svxy)) and
    the tail is :func:`_prob_from_moments` with the path-independent
    variance folds ``vstats`` = [J, 3] (sv, svx, svxx).  Empty slots
    get probability 0.0 (no evidence -> abstain).
    """
    m = rows.shape[1]
    colmask = jnp.arange(m, dtype=jnp.int32)[:, None] < lengths[None, :]
    masked = jnp.where(colmask[None], rows, _INF)
    j_end = jnp.argmin(masked, axis=1)                             # [J, K]
    msel = jnp.take_along_axis(moms, j_end[None, :, None, :],
                               axis=2)[:, :, 0, :]                 # [6, J, K]
    n = jnp.maximum(ns, 1).astype(jnp.float32)[:, None]            # [J, 1]
    probs = _prob_from_moments(
        msel[0], msel[1], msel[2], msel[3], msel[4], msel[5],
        sx[:, None], sxx[:, None], vstats[:, 0][:, None],
        vstats[:, 1][:, None], vstats[:, 2][:, None], n,
        jnp.float32(threshold))
    return jnp.where(ns[:, None] > 0, probs, 0.0)


def _prob_from_moments_approx(sy, syy, sxy, svy, sx, sxx, sv, svx, svxx,
                              n, threshold):
    """Approximate match probability from ONE carried variance channel —
    the serving-tick tail (:func:`_prob_from_moments` stays the verdict
    tail; THE single approx definition, shared by the jnp wavefront and
    both Pallas approx twins).

    Of the three path-dependent variance accumulators only
    ``svy = Σ v_i·y~_j(i)`` rides the warp path; the two dropped ones
    are reconstructed at the tail from the path-independent folds
    (sv, svx, svxx — note Σ v_i along the path IS sv: the warp keeps
    one pair per query row) via the warp-path regression
    ``y~_j(i) ≈ α + β·x~_i`` with β = cov/vx, α = (sy − β·sx)/n:

        svxy ≈ α·svx + β·svxx + (svx/sv)·resid
        svyy ≈ α²·sv + 2αβ·svx + β²·svxx
               + 2(α + β·svx/sv)·resid + sv·σ_ε²

    where ``resid = svy − (α·sv + β·svx)`` is the part of the carried
    proxy the regression line misses (it re-centers both
    reconstructions on the measured channel, so well-fit paths are
    reproduced almost exactly) and ``σ_ε² = max(vy − cov²/vx, 0)/n`` is
    the per-row regression residual variance.  Disattenuation, the
    delta-method variance algebra and every degenerate clamp are the
    exact tail's, with the reconstructed channels substituted.

    Zero input variance zeroes sv/svx/svxx/svy, hence resid, both
    reconstructions and every var_r term: sigma is exactly 0 and the
    result reduces BITWISE to the exact tail's point rule
    ``r^ >= threshold`` — approx and exact agree bit-for-bit on
    noise-free traces.  Constant queries/references ride the same
    safe-guards as the exact tail (safe_vx / sv_safe / clamped sqrt
    args), so the output is always finite, never NaN.
    """
    r = _corr_from_moments(sy, syy, sxy, sx, sxx, n)
    vx = jnp.maximum(sxx - sx * sx / n, 0.0)
    vy = jnp.maximum(syy - sy * sy / n, 0.0)
    cov = sxy - sx * sy / n
    denom = jnp.sqrt(vx * vy)
    safe_vx = jnp.where(vx > 0, vx, 1.0)
    den = jnp.clip(vx - sv, vx * 0.25, vx)
    g = jnp.where(den > 0, jnp.sqrt(vx / jnp.where(den > 0, den, 1.0)),
                  1.0)
    r_hat = jnp.clip(r * g, -1.0, 1.0)
    c = 1.0 / jnp.where(denom > 0, denom, 1.0)
    a = -c * sy / n + r * sx / (n * safe_vx)
    b = -r / (2.0 * safe_vx)
    # tail reconstruction of the dropped channels (see docstring)
    beta = cov / safe_vx
    alpha = (sy - beta * sx) / n
    sv_safe = jnp.where(sv > 0, sv, 1.0)
    resid = svy - (alpha * sv + beta * svx)
    svxy_hat = alpha * svx + beta * svxx + (svx / sv_safe) * resid
    sige2 = jnp.maximum(vy - cov * cov / safe_vx, 0.0) / n
    svyy_hat = jnp.maximum(
        alpha * alpha * sv + 2.0 * alpha * beta * svx
        + beta * beta * svxx
        + 2.0 * (alpha + beta * svx / sv_safe) * resid + sv * sige2,
        0.0)
    var_r = (a * a * sv + 4.0 * a * b * svx + 4.0 * b * b * svxx
             + 2.0 * a * c * svy + 4.0 * b * c * svxy_hat
             + c * c * svyy_hat)
    sigma = jnp.sqrt(jnp.maximum(var_r, 0.0))
    z = (r_hat - threshold) / jnp.where(sigma > 0, sigma, 1.0)
    phi = 0.5 * jax.lax.erfc(-z / jnp.sqrt(jnp.float32(2.0)))
    point = (r_hat >= threshold).astype(phi.dtype)
    return jnp.where(sigma > 0, phi, point)


def _moment_scores_prob_approx(rows, moms, ns, sx, sxx, vstats, lengths,
                               threshold):
    """Open-end approx match probability per (job, reference) -> [J, K].

    The four-channel twin of :func:`_moment_scores_prob`: same masked
    open-end argmin endpoint, but the gather reads the [4, J, M, K]
    slab (sy, syy, sxy, svy) and the tail is
    :func:`_prob_from_moments_approx`.  Feeding it the first four
    channels of an exact six-channel slab gives bit-identical output
    (channel 3 is svy in both layouts) — which is how the degraded
    approx tick under an exact-mode service reuses its slab.
    """
    m = rows.shape[1]
    colmask = jnp.arange(m, dtype=jnp.int32)[:, None] < lengths[None, :]
    masked = jnp.where(colmask[None], rows, _INF)
    j_end = jnp.argmin(masked, axis=1)                             # [J, K]
    msel = jnp.take_along_axis(moms, j_end[None, :, None, :],
                               axis=2)[:, :, 0, :]                 # [4, J, K]
    n = jnp.maximum(ns, 1).astype(jnp.float32)[:, None]            # [J, 1]
    probs = _prob_from_moments_approx(
        msel[0], msel[1], msel[2], msel[3],
        sx[:, None], sxx[:, None], vstats[:, 0][:, None],
        vstats[:, 1][:, None], vstats[:, 2][:, None], n,
        jnp.float32(threshold))
    return jnp.where(ns[:, None] > 0, probs, 0.0)


@functools.partial(jax.jit, static_argnames=("band",))
def bank_extend_tick(rows, ns, bank_t, lengths, chunks, nvalid, qlens,
                     band: Optional[int] = None):
    """Distance-only streaming tick (jnp wavefront) -> (rows, ns).

    K-last layout (rows [J, M, K], bank_t [M, K]).  The non-TPU fallback
    of the fused tick; ``kernels.dtw.stream`` is the Pallas twin for TPU
    backends (see :func:`bank_extend_tick_dispatch`).
    """
    z3 = jnp.zeros((3, 1, 1, 1))
    zj = jnp.zeros(chunks.shape[:1])
    new_rows, _, ns2, _, _, _ = _bank_extend_diag_impl(
        rows, z3, ns, zj, zj, bank_t, lengths, chunks, nvalid, qlens,
        band=band, score=False)
    return new_rows, ns2


@functools.partial(jax.jit, static_argnames=("band",))
def bank_extend_tick_scored(rows, moms, ns, sx, sxx, bank_t, lengths,
                            chunks, nvalid, qlens,
                            band: Optional[int] = None):
    """Fused scoring tick -> (rows, moms, ns, sx, sxx, scores [J, K])."""
    return _bank_extend_diag_impl(rows, moms, ns, sx, sxx, bank_t, lengths,
                                  chunks, nvalid, qlens, band=band,
                                  score=True)


@functools.partial(jax.jit, static_argnames=("band", "threshold"))
def bank_extend_tick_scored_var(rows, moms, ns, sx, sxx, vstats, bank_t,
                                lengths, chunks, vchunks, nvalid, qlens,
                                band: Optional[int] = None,
                                threshold: float = 0.9):
    """Variance-carrying fused scoring tick (jnp wavefront) ->
    ``(rows, moms, ns, sx, sxx, scores, vstats, probs)``.

    Same recurrence as :func:`bank_extend_tick_scored` with the moment
    slab doubled to six channels ([6, J, M, K]: sy, syy, sxy, svy, svyy,
    svxy), per-sample variances ``vchunks`` [J, C] riding beside the
    samples and the [J, 3] path-independent variance folds ``vstats``
    (sv, svx, svxx) riding beside sx/sxx.  ``probs`` [J, K] are the
    :func:`_prob_from_moments` match probabilities at the open-end
    endpoints; ``scores`` stays the point correlation.  A separate entry
    point (not a flag on the exact tick) so the exact tick's compiled
    graph and cost are untouched when variance mode is off.
    """
    return _bank_extend_diag_impl(rows, moms, ns, sx, sxx, bank_t, lengths,
                                  chunks, nvalid, qlens, band=band,
                                  score=True, vchunks=vchunks,
                                  vstats=vstats, threshold=threshold)


def bank_extend_tick_dispatch(rows, ns, bank_t, lengths, chunks, nvalid,
                              qlens, band: Optional[int] = None,
                              use_kernel: Optional[bool] = None):
    """Distance-only tick routed to the best backend: the Pallas streaming
    kernel on TPU (DP row pinned in VMEM across the chunk), the jnp
    wavefront everywhere else.  ``use_kernel=False`` forces the jnp
    wavefront (the dispatch-resilience fallback twin).  Tick layout in
    and out ([J, M, K])."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from ..kernels.dtw import stream_bank_extend
        new_rows, ns2 = stream_bank_extend(
            rows.transpose(0, 2, 1), ns, bank_t.T, lengths, chunks,
            nvalid, qlens, band=band)
        return new_rows.transpose(0, 2, 1), ns2
    return bank_extend_tick(rows, ns, bank_t, lengths, chunks, nvalid,
                            qlens, band=band)


@functools.partial(jax.jit,
                   static_argnames=("band", "interpret", "block_k"))
def _scored_kernel_tick(rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                        nvalid, qlens, band: Optional[int],
                        interpret: bool, block_k: int):
    """Fused Pallas scoring tick in tick (K-last) layout — the layout
    shuffles into/out of the kernel's K-major convention, the pallas_call
    itself, the query-moment fold and the open-end score reduction all
    trace into ONE jit, so nothing materializes between them beyond what
    XLA schedules."""
    from ..kernels.dtw import stream_bank_extend_scored_kernel
    rows_km, moms_km, _ = stream_bank_extend_scored_kernel(
        rows.transpose(0, 2, 1), moms.transpose(0, 1, 3, 2), ns,
        bank_t.T, lengths, chunks, nvalid, qlens, band=band,
        block_k=block_k, interpret=interpret)
    new_rows = rows_km.transpose(0, 2, 1)                  # [J, M, K]
    new_moms = moms_km.transpose(0, 1, 3, 2)               # [3, J, M, K]
    c = chunks.shape[1]
    xm = chunks - _MOM_SHIFT
    vmask = (jnp.arange(c, dtype=jnp.int32)[None, :]
             < nvalid[:, None]).astype(jnp.float32)
    sx2 = sx + jnp.sum(xm * vmask, axis=1)
    sxx2 = sxx + jnp.sum(xm * xm * vmask, axis=1)
    ns2 = ns + nvalid
    scores = _moment_scores(new_rows, new_moms, ns2, sx2, sxx2, lengths)
    return new_rows, new_moms, ns2, sx2, sxx2, scores


def bank_extend_tick_scored_dispatch(rows, moms, ns, sx, sxx, bank_t,
                                     lengths, chunks, nvalid, qlens,
                                     band: Optional[int] = None,
                                     use_kernel: Optional[bool] = None,
                                     interpret: Optional[bool] = None,
                                     block_k: int = 128):
    """Fused scoring tick routed to the best backend: the moment-carrying
    Pallas streaming kernel on TPU (DP row AND the three [BK, M] moment
    slabs pinned in VMEM across the whole chunk), the jnp wavefront
    everywhere else.  Tick layout in and out (rows [J, M, K], moms
    [3, J, M, K]); returns the same 6-tuple as
    :func:`bank_extend_tick_scored`.

    ``use_kernel``/``interpret`` exist for tests: forcing the kernel path
    on a CPU host runs it in Pallas interpret mode, which is how the
    cell-by-cell equivalence suite pins kernel == jnp wavefront.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            from ..kernels.common import default_interpret
            interpret = default_interpret()
        return _scored_kernel_tick(rows, moms, ns, sx, sxx, bank_t,
                                   lengths, chunks, nvalid, qlens,
                                   band=band, interpret=interpret,
                                   block_k=block_k)
    return bank_extend_tick_scored(rows, moms, ns, sx, sxx, bank_t,
                                   lengths, chunks, nvalid, qlens,
                                   band=band)


@functools.partial(jax.jit, static_argnames=("band", "threshold",
                                             "interpret", "block_k"))
def _scored_kernel_tick_var(rows, moms, ns, sx, sxx, vstats, bank_t,
                            lengths, chunks, vchunks, nvalid, qlens,
                            band: Optional[int], threshold: float,
                            interpret: bool, block_k: int):
    """Variance-carrying Pallas scoring tick in tick (K-last) layout —
    the six-channel twin of :func:`_scored_kernel_tick`."""
    from ..kernels.dtw import stream_bank_extend_scored_kernel
    rows_km, moms_km, _ = stream_bank_extend_scored_kernel(
        rows.transpose(0, 2, 1), moms.transpose(0, 1, 3, 2), ns,
        bank_t.T, lengths, chunks, nvalid, qlens, band=band,
        block_k=block_k, interpret=interpret, vchunks=vchunks)
    new_rows = rows_km.transpose(0, 2, 1)                  # [J, M, K]
    new_moms = moms_km.transpose(0, 1, 3, 2)               # [6, J, M, K]
    c = chunks.shape[1]
    xm = chunks - _MOM_SHIFT
    vmask = (jnp.arange(c, dtype=jnp.int32)[None, :]
             < nvalid[:, None]).astype(jnp.float32)
    sx2 = sx + jnp.sum(xm * vmask, axis=1)
    sxx2 = sxx + jnp.sum(xm * xm * vmask, axis=1)
    vq = vchunks * vmask
    vstats2 = vstats + jnp.stack(
        [jnp.sum(vq, axis=1), jnp.sum(vq * xm, axis=1),
         jnp.sum(vq * xm * xm, axis=1)], axis=1)
    ns2 = ns + nvalid
    scores = _moment_scores(new_rows, new_moms[:3], ns2, sx2, sxx2,
                            lengths)
    probs = _moment_scores_prob(new_rows, new_moms, ns2, sx2, sxx2,
                                vstats2, lengths, threshold)
    return new_rows, new_moms, ns2, sx2, sxx2, scores, vstats2, probs


def bank_extend_tick_scored_var_dispatch(rows, moms, ns, sx, sxx, vstats,
                                         bank_t, lengths, chunks, vchunks,
                                         nvalid, qlens,
                                         band: Optional[int] = None,
                                         threshold: float = 0.9,
                                         use_kernel: Optional[bool] = None,
                                         interpret: Optional[bool] = None,
                                         block_k: int = 128):
    """Variance-carrying fused scoring tick routed to the best backend
    (Pallas streaming kernel with six VMEM moment slabs on TPU, jnp
    wavefront elsewhere) — the probabilistic twin of
    :func:`bank_extend_tick_scored_dispatch`, returning the 8-tuple of
    :func:`bank_extend_tick_scored_var`."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            from ..kernels.common import default_interpret
            interpret = default_interpret()
        return _scored_kernel_tick_var(rows, moms, ns, sx, sxx, vstats,
                                       bank_t, lengths, chunks, vchunks,
                                       nvalid, qlens, band=band,
                                       threshold=threshold,
                                       interpret=interpret,
                                       block_k=block_k)
    return bank_extend_tick_scored_var(rows, moms, ns, sx, sxx, vstats,
                                       bank_t, lengths, chunks, vchunks,
                                       nvalid, qlens, band=band,
                                       threshold=threshold)


@functools.partial(jax.jit, static_argnames=("band", "threshold"))
def bank_extend_tick_scored_var_approx(rows, moms, ns, sx, sxx, vstats,
                                       bank_t, lengths, chunks, vchunks,
                                       nvalid, qlens,
                                       band: Optional[int] = None,
                                       threshold: float = 0.9):
    """Approximate variance-carrying fused scoring tick (jnp wavefront)
    -> ``(rows, moms, ns, sx, sxx, scores, vstats, probs)``.

    The serving-rate probability tick: same recurrence and return
    contract as :func:`bank_extend_tick_scored_var` but the moment slab
    is FOUR channels ([4, J, M, K]: sy, syy, sxy, svy) — one carried
    σ²-proxy instead of three — and ``probs`` comes from the
    :func:`_prob_from_moments_approx` tail (reconstructed svyy/svxy).
    ~1.3x the exact scored tick's slab traffic instead of ~2x; the
    exact six-channel tick stays the verdict/finish scorer.  Zero
    input variance reduces probs BITWISE to the point rule, exactly
    like the exact tail.
    """
    if moms.shape[0] != 4:
        raise ValueError("approx variance mode needs a four-channel "
                         f"moment slab, got {moms.shape[0]} channels")
    return _bank_extend_diag_impl(rows, moms, ns, sx, sxx, bank_t, lengths,
                                  chunks, nvalid, qlens, band=band,
                                  score=True, vchunks=vchunks,
                                  vstats=vstats, threshold=threshold)


@functools.partial(jax.jit, static_argnames=("band", "threshold",
                                             "interpret", "block_k"))
def _scored_kernel_tick_var_approx(rows, moms, ns, sx, sxx, vstats, bank_t,
                                   lengths, chunks, vchunks, nvalid, qlens,
                                   band: Optional[int], threshold: float,
                                   interpret: bool, block_k: int):
    """Approx variance-carrying Pallas scoring tick in tick (K-last)
    layout — the four-channel twin of :func:`_scored_kernel_tick_var`
    (same kernel, one variance slab instead of three, approx tail)."""
    from ..kernels.dtw import stream_bank_extend_scored_kernel
    rows_km, moms_km, _ = stream_bank_extend_scored_kernel(
        rows.transpose(0, 2, 1), moms.transpose(0, 1, 3, 2), ns,
        bank_t.T, lengths, chunks, nvalid, qlens, band=band,
        block_k=block_k, interpret=interpret, vchunks=vchunks)
    new_rows = rows_km.transpose(0, 2, 1)                  # [J, M, K]
    new_moms = moms_km.transpose(0, 1, 3, 2)               # [4, J, M, K]
    c = chunks.shape[1]
    xm = chunks - _MOM_SHIFT
    vmask = (jnp.arange(c, dtype=jnp.int32)[None, :]
             < nvalid[:, None]).astype(jnp.float32)
    sx2 = sx + jnp.sum(xm * vmask, axis=1)
    sxx2 = sxx + jnp.sum(xm * xm * vmask, axis=1)
    vq = vchunks * vmask
    vstats2 = vstats + jnp.stack(
        [jnp.sum(vq, axis=1), jnp.sum(vq * xm, axis=1),
         jnp.sum(vq * xm * xm, axis=1)], axis=1)
    ns2 = ns + nvalid
    scores = _moment_scores(new_rows, new_moms[:3], ns2, sx2, sxx2,
                            lengths)
    probs = _moment_scores_prob_approx(new_rows, new_moms, ns2, sx2, sxx2,
                                       vstats2, lengths, threshold)
    return new_rows, new_moms, ns2, sx2, sxx2, scores, vstats2, probs


def bank_extend_tick_scored_var_approx_dispatch(
        rows, moms, ns, sx, sxx, vstats, bank_t, lengths, chunks, vchunks,
        nvalid, qlens, band: Optional[int] = None, threshold: float = 0.9,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None, block_k: int = 128):
    """Approx variance-carrying fused scoring tick routed to the best
    backend (Pallas streaming kernel with FOUR VMEM moment slabs on TPU,
    jnp wavefront elsewhere) — the serving twin of
    :func:`bank_extend_tick_scored_var_dispatch`, returning the 8-tuple
    of :func:`bank_extend_tick_scored_var_approx`."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            from ..kernels.common import default_interpret
            interpret = default_interpret()
        return _scored_kernel_tick_var_approx(
            rows, moms, ns, sx, sxx, vstats, bank_t, lengths, chunks,
            vchunks, nvalid, qlens, band=band, threshold=threshold,
            interpret=interpret, block_k=block_k)
    return bank_extend_tick_scored_var_approx(
        rows, moms, ns, sx, sxx, vstats, bank_t, lengths, chunks, vchunks,
        nvalid, qlens, band=band, threshold=threshold)


# ---------------------------------------------------------------------------
# Matrix-free offline scoring: closed-end moment-carrying bank / pairs
# scorers (the offline mirror of the fused streaming tick)
# ---------------------------------------------------------------------------
#
# ``similarity.similarity_bank`` historically materialized every [N, M]
# accumulated-cost matrix on device ([K, N, M] per dispatch), shipped the
# stack to the host and backtracked per reference in a Python loop.  The
# scorers below instead carry the warp-path correlation moments THROUGH the
# DP (the PR-4 streaming trick) and read them at the closed alignment
# endpoint ``(N-1, lengths[k]-1)`` — one dispatch returns the final [K]
# (or [J, K]) warp correlations directly, with no [K, N, M] materialization
# and no host backtrack.
#
# Formulation (column-indexed wavefront): slot j of the diagonal carry
# holds cell (i, j) with i = t - j, so the reference axis never moves —
# the bank (and every y-derived moment delta) is a static array pinned to
# the slots, and the per-step dynamic slice is only the tiny reversed-query
# window.  Predecessors: vert (i-1, j) = same slot, previous diagonal
# (UNSHIFTED); horiz (i, j-1) and diag (i-1, j-1) = slot j-1 of the
# previous / previous-previous diagonal (one shift each).  A slot stops
# updating once its query rows are exhausted (i >= xlen), so after the
# last step the carry IS the final DP row — nothing is emitted per step.
#
# Moments ride in BASE form: B(i, j) = m(i, j) - delta(i, j) (the cell's
# path moments excluding its own aligned pair).  Transitions become
#
#     diag/vert:  B(i, j) = B(pred) + delta(pred)
#     horiz:      B(i, j) = B(i, j-1)              (pure copy)
#
# — the horizontal telescoping of the streaming kernel with the subtract
# re-add replaced by a no-op; the final moments are reconstructed as
# B + delta(endpoint).  Both forms add the same pair values at the same
# path positions, so on dyadic-grid data they are bit-identical to the
# streaming wavefront / Pallas kernels (tests/test_scored_matching.py);
# on smooth data they agree to float tolerance and the usual caveat
# applies: near-tie argmin flips move individual warp paths, so scores
# match the host backtrack to ~1e-3, not ulps (same caveat as the fused
# streaming tick, see tests/test_kernels.py).
#
# The reference axis is tiled (``block_k``-wide, ascending-length-sorted
# with per-tile trimmed padding, pre-uploaded as a memoized
# ``ScoreBankPlan``) so the per-step working set stays cache-resident on
# CPU hosts and ragged banks pay for their own lengths — the same tiling
# the Pallas offline twin (``kernels.dtw.score``) gets from its
# (query, ref-tile) grid and VMEM pinning.  On TPU backends the public
# entry points route to that kernel.

#: Reference-tile width of the jnp offline scorer: slabs are
#: [4, block_k, M] f32, so 64 keeps the whole step working set around a
#: megabyte — L2-resident on CPU hosts (measured 2.5-3x over untiled).
_SCORE_BLOCK_K = 64

#: Job-group width of one jnp scorer dispatch: groups are dispatched
#: asynchronously so independent wavefronts overlap across host cores
#: (an in-program lax.map over the whole batch would serialize them);
#: within a group lax.map bounds the working set.
_SCORE_J_GROUP = 4


def _score_tile(x, xlen, bank_km, lengths, sx, sxx, band: Optional[int],
                unroll: int = _WAVEFRONT_UNROLL,
                steps: Optional[int] = None):
    """One query [N] vs one reference tile [BK, M] -> (scores, dists) [BK].

    Pure function of arrays (jit wrappers live below); ``x`` is the
    (possibly padded) query, ``xlen`` its true length — padded rows freeze
    the carry, so any padding reproduces the unpadded solve bitwise.
    ``steps`` truncates the wavefront (default n + m - 1): every cell is
    frozen once past its final DP row, so any ``steps`` covering the last
    live anti-diagonal — ``max(xlen) + max(lengths) - 1`` over the batch —
    reproduces the full sweep bitwise while skipping pure-freeze steps
    that query padding would otherwise pay for.
    """
    bk, m = bank_km.shape
    n = x.shape[0]
    jj = jnp.arange(m, dtype=jnp.int32)
    ts = jnp.arange(n + m - 1 if steps is None else min(steps, n + m - 1),
                    dtype=jnp.int32)
    # reversed query, sentinel-padded: the window starting at offset
    # m + n - 1 - t reads x[t - j] at position j (x[t-j-1] one further).
    xrp = jnp.concatenate([jnp.full((m,), _BIG), x[::-1],
                           jnp.full((m,), _BIG)])
    # Sakoe-Chiba mask for EVERY wavefront step, hoisted: the in-scan
    # center multiply/floordiv/compare chain costs as much as the DP
    # itself on CPU hosts, while the precomputed [T, BK, M] mask is one
    # boolean read per step (identical integer arithmetic, so scores are
    # bitwise unchanged).
    if band is not None:
        centers = _band_center(ts[:, None, None] - jj[None, None, :],
                               xlen, lengths[None, :, None])
        inband = jnp.abs(jj[None, None, :] - centers) <= band
    else:
        inband = jnp.zeros((ts.shape[0], 1, 1), jnp.bool_)
    # live-row window per step, hoisted for the same reason: slot j is
    # live at step t iff 0 <= t - j < xlen.
    ii = ts[:, None] - jj[None, :]
    lives = jnp.logical_and(ii >= 0, ii < xlen)          # [T, M]
    # centered bank + its shifted twin (the diag predecessor's y column)
    # and their squares: every y-derived moment delta, hoisted out of the
    # scan because slot j's reference value never changes.
    yc = bank_km - _MOM_SHIFT
    yc_sh = jnp.concatenate([jnp.zeros((bk, 1)), yc[:, :-1]], axis=1)
    yc2, yc_sh2 = yc * yc, yc_sh * yc_sh

    bcol = jnp.concatenate([jnp.full((1, bk, 1), _INF),
                            jnp.zeros((3, bk, 1))], axis=0)

    def step(carry, scanned):
        # P* pack [cell; sy; syy; sxy] as 4 channels; P1/P2 are the two
        # previous diagonals (frozen slots hold their final row).
        t, ok, live = scanned
        P1, P2 = carry                                       # [4, BK, M]
        xsl = jax.lax.dynamic_slice(xrp, (m + n - 1 - t,), (m + 1,))
        d = jnp.abs(xsl[:m][None, :] - bank_km)
        if band is not None:
            d = jnp.where(ok, d, _INF)
        P1s = jnp.concatenate([bcol, P1[:, :, :-1]], axis=2)
        # the virtual corner D[-1, -1] = 0 (empty-path moments) is the
        # shifted-in diag predecessor of cell (0, 0) on the t == 0 step.
        ccol = bcol.at[0].set(jnp.where(t == 0, 0.0, _INF))
        P2s = jnp.concatenate([ccol, P2[:, :, :-1]], axis=2)
        pd, pv, ph = P2s[0], P1[0], P1s[0]
        m1 = jnp.minimum(pv, ph)
        cell = jnp.minimum(d + jnp.minimum(pd, m1), _INF)
        # predecessor choice mirrors backtrack()'s np.argmin tie order
        # (diag, then vert, then horiz) — identical to the streaming
        # wavefront and the Pallas kernels.
        sd = pd <= m1
        anch = jnp.logical_or(sd, pv <= ph)
        # base-moment update: anchor cells read their predecessor's base
        # plus the predecessor's own pair delta; horizontal runs copy.
        # The predecessor row's x value is x[t-j-1] (sentinel windows
        # only feed don't-care cells: any finite path's predecessors are
        # in-grid, and the corner transition's y delta is zero because
        # yc_sh's first column is).
        xp = xsl[1:][None, :] - _MOM_SHIFT
        ysel = jnp.where(sd, yc_sh, yc)
        dpred = jnp.stack([ysel, jnp.where(sd, yc_sh2, yc2), xp * ysel])
        Bnew = jnp.where(anch[None],
                         jnp.where(sd[None], P2s[1:], P1[1:]) + dpred,
                         P1s[1:])
        Pnew = jnp.concatenate([cell[None], Bnew], axis=0)
        # slots freeze outside their live query rows: before row 0 they
        # keep the init boundary, after row xlen-1 the final DP row.
        Pnew = jnp.where(live[None, None, :], Pnew, P1)
        return (Pnew, P1), None

    init = jnp.concatenate([jnp.full((1, bk, m), _INF),
                            jnp.zeros((3, bk, m))], axis=0)
    (P1, _), _ = jax.lax.scan(step, (init, init), (ts, inband, lives),
                              unroll=unroll)
    jend = (lengths - 1).astype(jnp.int32)
    sel = jnp.take_along_axis(P1, jnp.broadcast_to(
        jend[None, :, None], (4, bk, 1)), axis=2)[:, :, 0]  # [4, BK]
    dist, Bf = sel[0], sel[1:]
    # reconstruct full moments: B + delta(endpoint) with the TRUE last
    # query sample (pass-through copies base moments untouched, so this
    # holds for padded queries too).
    yce = jnp.take_along_axis(bank_km, jend[:, None], axis=1)[:, 0] \
        - _MOM_SHIFT
    xme = jnp.take_along_axis(
        x, jnp.maximum(xlen - 1, 0)[None], axis=0)[0] - _MOM_SHIFT
    mf = Bf + jnp.stack([yce, yce * yce, xme * yce])
    nn = jnp.maximum(xlen, 1).astype(jnp.float32)
    scores = _corr_from_moments(mf[0], mf[1], mf[2], sx, sxx, nn)
    return jnp.where(xlen > 0, scores, 0.0), dist


@functools.partial(jax.jit, static_argnames=("band",))
def _score_tile_many(xs, xlens, bank_km, lengths, sx, sxx,
                     band: Optional[int]):
    """J queries x one reference tile -> (scores, dists) [J, BK].

    ``lax.map`` over jobs keeps the inner wavefront's [4, BK, M] working
    set cache-sized whatever J is; results are bitwise independent of J,
    of the tile split and of query padding (per-cell arithmetic never
    sees either).
    """

    def one_job(args):
        x, xlen, sxj, sxxj = args
        return _score_tile(x, xlen, bank_km, lengths, sxj, sxxj, band)

    return jax.lax.map(one_job, (xs, xlens, sx, sxx))


def _score_tile_var(x, xv, xlen, bank_km, lengths, sx, sxx, sv, svx, svxx,
                    band: Optional[int], threshold: float,
                    unroll: int = _WAVEFRONT_UNROLL,
                    approx: bool = False):
    """Variance-carrying twin of :func:`_score_tile`: one query [N] with
    per-sample variances ``xv`` [N] vs one reference tile [BK, M] ->
    (scores, probs, dists) [BK].

    The P pack grows to SEVEN channels [cell; sy; syy; sxy; svy; svyy;
    svxy]: each variance channel's predecessor delta is the matching base
    delta times the predecessor row's variance (the same BASE-form
    anchored/copy transitions carry all six), and the endpoint
    reconstruction adds ``v[xlen-1] *`` the base endpoint delta.  The
    variance window is ZERO-sentinel-padded (unlike the _BIG query
    sentinel): out-of-grid reads only feed don't-care cells, and zeros
    can never overflow a moment accumulator.

    ``approx=True`` switches the probability tail to
    :func:`_prob_from_moments_approx`, fed only (sy, syy, sxy, svy) —
    bit-identical to a dedicated four-channel carry (the svy channel's
    path arithmetic is unchanged), so this is the offline calibration
    reference for the approx serving tick without a second DP variant.
    """
    bk, m = bank_km.shape
    n = x.shape[0]
    jj = jnp.arange(m, dtype=jnp.int32)
    ts = jnp.arange(n + m - 1, dtype=jnp.int32)
    xrp = jnp.concatenate([jnp.full((m,), _BIG), x[::-1],
                           jnp.full((m,), _BIG)])
    vrp = jnp.concatenate([jnp.zeros((m,)), xv[::-1], jnp.zeros((m,))])
    if band is not None:
        centers = _band_center(ts[:, None, None] - jj[None, None, :],
                               xlen, lengths[None, :, None])
        inband = jnp.abs(jj[None, None, :] - centers) <= band
    else:
        inband = jnp.zeros((ts.shape[0], 1, 1), jnp.bool_)
    ii = ts[:, None] - jj[None, :]
    lives = jnp.logical_and(ii >= 0, ii < xlen)          # [T, M]
    yc = bank_km - _MOM_SHIFT
    yc_sh = jnp.concatenate([jnp.zeros((bk, 1)), yc[:, :-1]], axis=1)
    yc2, yc_sh2 = yc * yc, yc_sh * yc_sh

    bcol = jnp.concatenate([jnp.full((1, bk, 1), _INF),
                            jnp.zeros((6, bk, 1))], axis=0)

    def step(carry, scanned):
        t, ok, live = scanned
        P1, P2 = carry                                       # [7, BK, M]
        xsl = jax.lax.dynamic_slice(xrp, (m + n - 1 - t,), (m + 1,))
        vsl = jax.lax.dynamic_slice(vrp, (m + n - 1 - t,), (m + 1,))
        d = jnp.abs(xsl[:m][None, :] - bank_km)
        if band is not None:
            d = jnp.where(ok, d, _INF)
        P1s = jnp.concatenate([bcol, P1[:, :, :-1]], axis=2)
        ccol = bcol.at[0].set(jnp.where(t == 0, 0.0, _INF))
        P2s = jnp.concatenate([ccol, P2[:, :, :-1]], axis=2)
        pd, pv, ph = P2s[0], P1[0], P1s[0]
        m1 = jnp.minimum(pv, ph)
        cell = jnp.minimum(d + jnp.minimum(pd, m1), _INF)
        sd = pd <= m1
        anch = jnp.logical_or(sd, pv <= ph)
        xp = xsl[1:][None, :] - _MOM_SHIFT
        vp = vsl[1:][None, :]            # predecessor row's variance
        ysel = jnp.where(sd, yc_sh, yc)
        dpred3 = jnp.stack([ysel, jnp.where(sd, yc_sh2, yc2), xp * ysel])
        dpred = jnp.concatenate([dpred3, vp[None] * dpred3], axis=0)
        Bnew = jnp.where(anch[None],
                         jnp.where(sd[None], P2s[1:], P1[1:]) + dpred,
                         P1s[1:])
        Pnew = jnp.concatenate([cell[None], Bnew], axis=0)
        Pnew = jnp.where(live[None, None, :], Pnew, P1)
        return (Pnew, P1), None

    init = jnp.concatenate([jnp.full((1, bk, m), _INF),
                            jnp.zeros((6, bk, m))], axis=0)
    (P1, _), _ = jax.lax.scan(step, (init, init), (ts, inband, lives),
                              unroll=unroll)
    jend = (lengths - 1).astype(jnp.int32)
    sel = jnp.take_along_axis(P1, jnp.broadcast_to(
        jend[None, :, None], (7, bk, 1)), axis=2)[:, :, 0]  # [7, BK]
    dist, Bf = sel[0], sel[1:]
    yce = jnp.take_along_axis(bank_km, jend[:, None], axis=1)[:, 0] \
        - _MOM_SHIFT
    xme = jnp.take_along_axis(
        x, jnp.maximum(xlen - 1, 0)[None], axis=0)[0] - _MOM_SHIFT
    vme = jnp.take_along_axis(
        xv, jnp.maximum(xlen - 1, 0)[None], axis=0)[0]
    base_d = jnp.stack([yce, yce * yce, xme * yce])
    mf = Bf + jnp.concatenate([base_d, vme * base_d], axis=0)
    nn = jnp.maximum(xlen, 1).astype(jnp.float32)
    scores = _corr_from_moments(mf[0], mf[1], mf[2], sx, sxx, nn)
    if approx:
        probs = _prob_from_moments_approx(mf[0], mf[1], mf[2], mf[3],
                                          sx, sxx, sv, svx, svxx, nn,
                                          jnp.float32(threshold))
    else:
        probs = _prob_from_moments(mf[0], mf[1], mf[2], mf[3], mf[4],
                                   mf[5], sx, sxx, sv, svx, svxx, nn,
                                   jnp.float32(threshold))
    return (jnp.where(xlen > 0, scores, 0.0),
            jnp.where(xlen > 0, probs, 0.0), dist)


@functools.partial(jax.jit, static_argnames=("band", "threshold", "approx"))
def _score_tile_var_many(xs, xvs, xlens, bank_km, lengths, sx, sxx,
                         vstats, band: Optional[int], threshold: float,
                         approx: bool = False):
    """J queries (with variances) x one reference tile ->
    (scores, probs, dists) [J, BK]; the variance-mode column of
    :func:`_score_tile_many` (``lax.map`` over jobs, [7, BK, M] slabs).
    ``approx`` selects the single-proxy probability tail."""

    def one_job(args):
        x, xv, xlen, sxj, sxxj, vst = args
        return _score_tile_var(x, xv, xlen, bank_km, lengths, sxj, sxxj,
                               vst[0], vst[1], vst[2], band, threshold,
                               approx=approx)

    return jax.lax.map(one_job, (xs, xvs, xlens, sx, sxx, vstats))


#: Inner vmap width of one batched-verdict dispatch: wide enough to
#: amortize XLA's per-op loop overhead across jobs, narrow enough that
#: the [VW, 4, BK, M] per-op slab stays cache-resident on the small
#: banks the full-width verdict path serves (larger banks route to the
#: windowed wavefront instead).
_VERDICT_VMAP = 4


@functools.partial(jax.jit, static_argnames=("band", "steps"))
def _score_tile_verdict(xs, xlens, bank_km, lengths, sx, sxx,
                        band: Optional[int], steps: int):
    """J queries x one reference tile in ONE dispatch -> (scores, dists)
    [J, BK], the batched-verdict column of :func:`_score_tile_many`.

    ``lax.map`` over job groups of an inner ``vmap`` trades
    :func:`_score_tile_many`'s per-job op dispatches (the sequential-J
    cost on CPU hosts) for ``_VERDICT_VMAP``-wide slabs, and ``steps``
    (host-derived from the TRUE query lengths, bucketed so repeat drains
    reuse jit shapes) skips the pure-freeze tail that pow2 query padding
    appends.  Bitwise equal to per-job :func:`_score_tile` whatever J,
    the grouping, or the padding."""
    j = xs.shape[0]
    g = math.gcd(j, _VERDICT_VMAP)

    def one_job(x, xlen, sxj, sxxj):
        return _score_tile(x, xlen, bank_km, lengths, sxj, sxxj, band,
                           steps=steps)

    def one_group(args):
        return jax.vmap(one_job)(*args)

    ng = j // g
    scores, dists = jax.lax.map(one_group, (
        xs.reshape(ng, g, -1), xlens.reshape(ng, g),
        sx.reshape(ng, g), sxx.reshape(ng, g)))
    return scores.reshape(j, -1), dists.reshape(j, -1)


def _window_offset(t, xlen, min_len, band: int):
    """Leftmost column the banded wavefront can reach at step ``t``
    (minus one slack column), in exact int32 arithmetic.

    In-band cells of step t satisfy ``j >= (t*R - (band+1)*q)/(q + R)``
    with ``q = xlen-1`` and ``R = len_k-1`` (from inverting
    :func:`_band_center`'s floor); the bound is increasing in R, so the
    shortest reference in the tile gives the tile-wide minimum.  Every
    column strictly left of the returned offset is out-of-band for EVERY
    reference, which is what lets the windowed wavefront represent them
    as frozen (+inf, 0-moment) cells without computing them.
    """
    q = jnp.maximum(xlen - 1, 1).astype(jnp.int32)
    r = jnp.maximum(min_len - 1, 1).astype(jnp.int32)
    return (t * r - (band + 1) * q) // (q + r) - 1


def _window_width(xlens, lengths, m: int, band: int) -> int:
    """Static window width covering the band of every (query, tile
    reference) pair at every wavefront step, host-side exact integer
    arithmetic mirroring :func:`_window_offset`; padded to a multiple of
    16 so repeat verdicts reuse jit shapes."""
    xl = np.maximum(np.asarray(xlens, np.int64), 2)
    lengths = np.asarray(lengths, np.int64)
    q_lo, q_hi = int(xl.min()) - 1, int(xl.max()) - 1
    r_lo = max(int(lengths.min()) - 1, 1)
    r_hi = max(int(lengths.max()) - 1, 1)
    # exact sweep over every wavefront step: the kernel's SHARED left
    # offset uses (q_hi, r_lo); the right band edge is maximized over the
    # (q, r) corners (the bound is monotone in each variable separately,
    # so corner evaluation is exact).
    t = np.arange(q_hi + m - 1, dtype=np.int64)
    # offsets FREEZE for _VERDICT_SUPER consecutive steps (static
    # sub-step slicing in the kernel), so each step is covered by the
    # offset of its super-step start
    ts = (t // _VERDICT_SUPER) * _VERDICT_SUPER
    o = (ts * r_lo - (band + 1) * q_hi) // (q_hi + r_lo) - 1
    hi = np.full_like(t, -1)
    for q in (q_lo, q_hi):
        for r in (r_lo, r_hi):
            hi = np.maximum(hi, (t * r + band * q) // (q + r) + 1)
    w = int((np.minimum(hi, m - 1) - np.maximum(o, 0)).max()) + 4
    return min(m, -(-w // 16) * 16)


_VERDICT_GROUP = 8
#: wavefront steps per frozen-offset super-step in the windowed scorer
_VERDICT_SUPER = 4


@functools.partial(jax.jit, static_argnames=("band", "w", "group"))
def _score_tile_banded_many(xs, xlens, bank_km, lengths, sx, sxx,
                            band: int, w: int,
                            group: int = _VERDICT_GROUP):
    """Windowed twin of :func:`_score_tile_many` for banded verdicts:
    the scan carries only a ``w``-wide sliding window of each
    anti-diagonal instead of the full [BK, M] slab, so a banded verdict
    does O((N+M)*w) work instead of O((N+M)*M) — and the window offset
    is SHARED across the batch (derived from the batch's longest query),
    so the whole batch runs as one scan over [J, 4, BK, w'] slabs whose
    slices are plain scalar-offset copies.  A J=1 dispatch is dominated
    by per-step op overhead at these slab sizes; batching amortizes that
    overhead across jobs, which is what makes ``finish_many`` beat
    sequential finishes on a one-core host.

    Exactness: the window provably covers every in-band cell of every
    job (:func:`_window_offset` with the batch-max query length lower-
    bounds each job's own left band edge), in-window cells run the
    identical per-cell arithmetic (including the :func:`_band_center`
    mask), and everything outside the window is out-of-band for every
    (job, reference) — a (+inf, 0-moment) cell, which is exactly what
    the edge padding supplies.  The final query row's cell leaves the
    window one column per step, so it is emitted as scan output and the
    per-(job, reference) endpoints are gathered afterwards.  Scores and
    distances are bitwise identical to the full-width tile for any
    sufficient window, hence independent of batch composition.
    """
    jall, n = xs.shape
    bk, m = bank_km.shape
    u_sup = _VERDICT_SUPER
    # stored/computed span per SUPER-step: columns [o-2, o+w+2); the
    # offset freezes for u_sup consecutive wavefront steps so every
    # intra-super-step predecessor read is a STATIC slice (XLA fuses the
    # whole unrolled chain); one dynamic realignment per super-step.
    ws = w + 4
    g = math.gcd(jall, group)
    j = g
    yc_full = bank_km - _MOM_SHIFT
    # left-padded twins so the shifted (diag-predecessor) column is a
    # plain re-slice; column -1's yc_sh is 0 as in the full-width tile.
    # extra columns of back-fill keep every dynamic_slice in range
    # (reads there only feed out-of-band cells).
    ycp = jnp.concatenate([jnp.zeros((bk, 3)), yc_full,
                           jnp.zeros((bk, 2))], axis=1)
    ybp = jnp.concatenate([jnp.zeros((bk, 2)), bank_km,
                           jnp.zeros((bk, 2))], axis=1)
    r_min = jnp.maximum(jnp.min(lengths) - 1, 1).astype(jnp.int32)
    jend = (lengths - 1).astype(jnp.int32)
    n_steps = n + m - 1
    n_sup = -(-n_steps // u_sup)

    # frozen out-of-window cell: +inf distance, zero moments
    def blank(width):
        return jnp.concatenate(
            [jnp.full((j, 1, bk, width), _INF),
             jnp.zeros((j, 3, bk, width))], axis=1)

    edge1 = blank(1)
    edgeu = blank(u_sup + 2)

    def one_group(xs, xlens, sx, sxx):
        xrp = jnp.concatenate(
            [jnp.full((j, m + 2), _BIG), xs[:, ::-1],
             jnp.full((j, m + 2), _BIG)], axis=1)
        q_max = jnp.maximum(jnp.max(xlens) - 1, 1)

        def offset(t):
            return jnp.clip(
                (t * r_min - (band + 1) * q_max) // (q_max + r_min) - 1,
                0, max(m - w, 0))

        def super_step(carry, t0):
            P1, P2, o_prev = carry
            o = offset(t0)
            jj = o - 2 + jnp.arange(ws, dtype=jnp.int32)     # [ws] abs
            # realign both carries to the new span in ONE dynamic slice
            # each (the right edge-padding stands in for columns that
            # are out-of-band at every step it can be read for)
            sh = jnp.clip(o - o_prev, 0, u_sup + 1)
            P1 = jax.lax.dynamic_slice(
                jnp.concatenate([P1, edgeu], axis=3),
                (0, 0, 0, sh), (j, 4, bk, ws))
            P2 = jax.lax.dynamic_slice(
                jnp.concatenate([P2, edgeu], axis=3),
                (0, 0, 0, sh), (j, 4, bk, ws))
            # query / bank slabs for the whole super-step (o is frozen,
            # so sub-steps take static sub-slices)
            xbig = jax.lax.dynamic_slice(
                xrp, (0, m + n - 1 - (t0 + u_sup - 1) + o),
                (j, ws + u_sup))
            ysl = jax.lax.dynamic_slice(ycp, (0, o), (bk, ws + 1))
            yc, yc_sh = ysl[:, 1:], ysl[:, :-1]              # [BK, ws]
            yraw = jax.lax.dynamic_slice(ybp, (0, o), (bk, ws))
            emits = []
            for u in range(u_sup):
                t = t0 + u
                xsl = xbig[:, u_sup - 1 - u: u_sup - u + ws]  # [J, ws+1]
                d = jnp.abs(xsl[:, None, :ws] - yraw[None])   # [J,BK,ws]
                ii = t - jj                                   # [ws] rows
                centers = _band_center(ii[None, None, :],
                                       xlens[:, None, None],
                                       lengths[None, :, None])
                ok = jnp.logical_and(
                    jnp.abs(jj[None, None, :] - centers) <= band,
                    jnp.logical_and(jj >= 0, jj < m)[None, None, :])
                d = jnp.where(ok, d, _INF)
                # static shift-by-one: horiz/diag predecessors
                P1s = jnp.concatenate([edge1, P1[..., :-1]], axis=3)
                P2s = jnp.concatenate([edge1, P2[..., :-1]], axis=3)
                pd, pv, ph = P2s[:, 0], P1[:, 0], P1s[:, 0]
                # virtual corner D[-1,-1] = 0: diag predecessor of
                # column 0 on the t == 0 step
                pd = jnp.where(
                    jnp.logical_and(t == 0, jj == 0)[None, None, :],
                    0.0, pd)
                m1 = jnp.minimum(pv, ph)
                cell = jnp.minimum(d + jnp.minimum(pd, m1), _INF)
                sd = pd <= m1
                anch = jnp.logical_or(sd, pv <= ph)
                xp = xsl[:, None, 1:] - _MOM_SHIFT            # [J, 1, ws]
                ysel = jnp.where(sd, yc_sh[None], yc[None])
                dpred = jnp.stack(
                    [ysel, jnp.where(sd, (yc_sh * yc_sh)[None],
                                     (yc * yc)[None]), xp * ysel],
                    axis=1)
                Bnew = jnp.where(anch[:, None],
                                 jnp.where(sd[:, None], P2s[:, 1:],
                                           P1[:, 1:]) + dpred,
                                 P1s[:, 1:])
                Pnew = jnp.concatenate([cell[:, None], Bnew], axis=1)
                live = jnp.logical_and(ii[None, :] >= 0,
                                       ii[None, :] < xlens[:, None])
                Pnew = jnp.where(live[:, None, None, :], Pnew, P1)
                # final query row's cell: column t - (xlen_j - 1), per
                # job, captured the step it is computed
                eidx = jnp.clip(t - (xlens - 1) - (o - 2), 0, ws - 1)
                emits.append(jnp.take_along_axis(
                    Pnew, eidx[:, None, None, None], axis=3)[..., 0])
                P2, P1 = P1, Pnew
            return (P1, P2, o), jnp.stack(emits)  # [U, J, 4, BK]

        init = blank(ws)
        t0s = jnp.arange(n_sup, dtype=jnp.int32) * u_sup
        (_, _, _), ys = jax.lax.scan(
            super_step, (init, init, jnp.int32(0)), t0s)
        ys = ys.reshape(n_sup * u_sup, j, 4, bk)
        # ref k's closed-end endpoint was emitted at step
        # xlen_j - 1 + jend_k (always a true, non-overhang step)
        eidx = jnp.broadcast_to(
            (xlens[:, None] - 1 + jend[None, :])[:, None, :],
            (j, 4, bk))[None]
        sel = jnp.take_along_axis(ys, eidx, axis=0)[0]        # [J, 4, BK]
        dist, Bf = sel[:, 0], sel[:, 1:]                      # [J, BK]
        yce = jnp.take_along_axis(bank_km, jend[:, None], axis=1)[:, 0] \
            - _MOM_SHIFT                                      # [BK]
        xme = jnp.take_along_axis(
            xs, jnp.maximum(xlens - 1, 0)[:, None], axis=1)[:, 0] \
            - _MOM_SHIFT                                      # [J]
        mf = Bf + jnp.stack([jnp.broadcast_to(yce[None], (j, bk)),
                             jnp.broadcast_to((yce * yce)[None], (j, bk)),
                             xme[:, None] * yce[None]], axis=1)
        nn = jnp.maximum(xlens, 1).astype(jnp.float32)[:, None]
        scores = _corr_from_moments(mf[:, 0], mf[:, 1], mf[:, 2],
                                    sx[:, None], sxx[:, None], nn)
        return jnp.where(xlens[:, None] > 0, scores, 0.0), dist

    xlens = xlens.astype(jnp.int32)
    if g == jall:
        return one_group(xs, xlens, sx, sxx)
    ng = jall // g
    scores, dist = jax.lax.map(
        lambda a: one_group(*a),
        (xs.reshape(ng, g, n), xlens.reshape(ng, g),
         sx.reshape(ng, g), sxx.reshape(ng, g)))
    return scores.reshape(jall, bk), dist.reshape(jall, bk)



@functools.partial(jax.jit, static_argnames=("band",))
def _score_pairs_impl(xs, ys, xlens, ylens, sx, sxx,
                      band: Optional[int]):
    """P ragged (query, reference) pairs -> (scores, dists) [P]; one
    dispatch (vmapped single-pair tiles — [4, P, M] slabs stay small)."""

    def one(x, y, xlen, ylen, sxp, sxxp):
        sc, di = _score_tile(x, xlen, y[None, :], ylen[None], sxp, sxxp,
                             band)
        return sc[0], di[0]

    return jax.vmap(one)(xs, ys, xlens, ylens, sx, sxx)


def query_moments(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side centered query folds (sx, sxx) for the closed-end
    scorers, accumulated in float64 from the UNPADDED samples — the same
    job always contributes bit-identical folds however its verdict is
    batched, which is what makes ``finish_many`` == sequential
    ``finish`` exact (device moments are per-cell arithmetic and already
    batch-invariant)."""
    xm = np.asarray(x, np.float64).reshape(-1) - float(_MOM_SHIFT)
    return (np.float32(xm.sum()), np.float32((xm * xm).sum()))


def query_var_moments(x: np.ndarray, v: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side path-independent variance folds (sv, svx, svxx) of a
    query with per-sample variances ``v`` — the variance-mode companions
    of :func:`query_moments` (same float64 accumulation, same
    batch-invariance argument)."""
    xm = np.asarray(x, np.float64).reshape(-1) - float(_MOM_SHIFT)
    vv = np.asarray(v, np.float64).reshape(-1)
    return (np.float32(vv.sum()), np.float32((vv * xm).sum()),
            np.float32((vv * xm * xm).sum()))


def _pad_pow2(n: int, lo: int = 8) -> int:
    return max(lo, 1 << (max(n, 1) - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class ScoreBankPlan:
    """Device-resident tiling of a reference bank for the offline
    scorers: the bank sorted by ascending true length, split into
    ``block_k``-wide tiles each trimmed to its own padded width, already
    uploaded.  Build once per bank (``database.SeriesBank.score_plan``
    caches it) and reuse across verdicts — re-deriving it per call would
    re-upload the whole bank every ``finish()``.
    """
    k: int
    inv: np.ndarray                     # [K] un-permutation of tile order
    tiles: Tuple[Tuple[jax.Array, jax.Array], ...]   # ([BK, M_t], [BK])


def build_score_plan(series, lengths=None,
                     block_k: int = _SCORE_BLOCK_K) -> ScoreBankPlan:
    """Sort, tile, trim and upload a [K, M] bank for the offline
    scorers.  Per-reference scores are independent of the ordering and
    tiling, so any plan of the same bank scores identically."""
    series = np.asarray(series, np.float32)
    k, m = series.shape
    lengths = np.full((k,), m, np.int32) if lengths is None \
        else np.asarray(lengths, np.int32)
    order = np.argsort(lengths, kind="stable")
    tiles = []
    for lo in range(0, k, block_k):
        sel = order[lo: lo + block_k]
        m_t = min(m, max(8, -(-int(lengths[sel].max()) // 8) * 8))
        tiles.append((jnp.asarray(series[sel, :m_t]),
                      jnp.asarray(lengths[sel])))
    inv = np.empty((k,), np.int64)
    inv[order] = np.arange(k)
    return ScoreBankPlan(k=k, inv=inv, tiles=tuple(tiles))


def dtw_score_bank_many(xs, bank, lengths=None, xlens=None,
                        band: Optional[int] = None,
                        sx=None, sxx=None, *,
                        xvars=None, vstats=None,
                        threshold: float = 0.9,
                        prob_mode: str = "exact",
                        plan: Optional[ScoreBankPlan] = None,
                        use_kernel: Optional[bool] = None,
                        interpret: Optional[bool] = None,
                        block_k: int = _SCORE_BLOCK_K,
                        return_distances: bool = False):
    """Closed-end warp correlations of J queries against a padded bank in
    ONE dispatch -> float32 [J, K] (optionally also the DTW distances
    D(xlen_j, len_k) [J, K]).

    ``xs`` is [J, N] (padded; ``xlens`` holds true lengths, default N),
    ``bank`` [K, M] with ``lengths`` as everywhere else.  ``sx``/``sxx``
    are the per-query centered folds (:func:`query_moments`); when None
    they are computed here on the host.  Scores equal
    ``similarity_bank``'s host backtrack + correlation: bitwise-path on
    tie-free (dyadic-grid) data, to warp-path-tie tolerance elsewhere.

    Variance mode: passing ``xvars`` [J, N] (per-sample measurement
    variances; ``vstats`` [J, 3] = (sv, svx, svxx) folds optional, see
    :func:`query_var_moments`) switches to the seven-channel scorer and
    the return value becomes ``(scores, probs)`` (plus dists when
    ``return_distances``), where ``probs`` [J, K] is
    P[true warp correlation >= ``threshold``] per
    :func:`_prob_from_moments` — all-zero ``xvars`` reduces ``probs``
    to the point rule ``scores >= threshold`` exactly.
    ``prob_mode="approx"`` swaps in the single-proxy
    :func:`_prob_from_moments_approx` tail (the serving tick's
    probability model) — the calibration reference for pinning approx
    against exact offline; verdict paths keep the default exact tail.

    Routed to the Pallas offline kernel (``kernels.dtw.score``) on TPU
    backends — DP row and moment slabs pinned in VMEM per (query,
    ref-tile) program — and to the tiled jnp wavefront elsewhere;
    ``use_kernel``/``interpret`` exist so tests can pin kernel == jnp in
    interpret mode on CPU hosts.
    """
    xs = np.asarray(xs, np.float32)
    if xs.ndim != 2:
        raise ValueError(f"xs must be [J, N], got shape {xs.shape}")
    j, n = xs.shape
    if xlens is None:
        xlens = np.full((j,), n, np.int32)
    xlens = np.asarray(xlens, np.int32)
    series = np.asarray(bank, np.float32)
    k, m = series.shape
    lengths = np.full((k,), m, np.int32) if lengths is None \
        else np.asarray(lengths, np.int32)
    if sx is None or sxx is None:
        folds = [query_moments(xs[i, :xlens[i]]) for i in range(j)]
        sx = np.asarray([f[0] for f in folds], np.float32)
        sxx = np.asarray([f[1] for f in folds], np.float32)
    if xvars is not None:
        xvars = np.asarray(xvars, np.float32)
        if xvars.shape != xs.shape:
            raise ValueError(f"xvars must match xs shape {xs.shape}, "
                             f"got {xvars.shape}")
        if vstats is None:
            vstats = np.asarray(
                [query_var_moments(xs[i, :xlens[i]], xvars[i, :xlens[i]])
                 for i in range(j)], np.float32)
        vstats = np.asarray(vstats, np.float32)
    if prob_mode not in ("exact", "approx"):
        raise ValueError(f"prob_mode must be 'exact' or 'approx', "
                         f"got {prob_mode!r}")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if k == 0:
        z = jnp.zeros((j, 0), jnp.float32)
        out = (z, z) if xvars is not None else (z,)
        out = out + (z,) if return_distances else out
        return out if len(out) > 1 else out[0]
    if xvars is not None:
        if use_kernel:
            if interpret is None:
                from ..kernels.common import default_interpret
                interpret = default_interpret()
            if prob_mode == "approx":
                from ..kernels.dtw import \
                    score_bank_offline_var_approx_kernel as var_kernel
            else:
                from ..kernels.dtw import \
                    score_bank_offline_var_kernel as var_kernel
            scores, probs, dists = var_kernel(
                jnp.asarray(xs), jnp.asarray(xvars), jnp.asarray(xlens),
                jnp.asarray(series), jnp.asarray(lengths),
                jnp.asarray(sx), jnp.asarray(sxx), jnp.asarray(vstats),
                band=band, threshold=float(threshold),
                block_k=min(128, _pad_pow2(k)), interpret=interpret)
            return (scores, probs, dists) if return_distances \
                else (scores, probs)
        # jnp path: the simple tiled wavefront always (the windowed /
        # batched-verdict perf variants have no variance twins —
        # _score_tile_var supports the band mask directly).
        if plan is None:
            plan = build_score_plan(series, lengths, block_k)
        parts = []
        for lo in range(0, j, _SCORE_J_GROUP):
            hi = min(lo + _SCORE_J_GROUP, j)
            parts.append([
                _score_tile_var_many(
                    jnp.asarray(xs[lo:hi]), jnp.asarray(xvars[lo:hi]),
                    jnp.asarray(xlens[lo:hi]), tb, tl,
                    jnp.asarray(sx[lo:hi]), jnp.asarray(sxx[lo:hi]),
                    jnp.asarray(vstats[lo:hi]), band, float(threshold),
                    approx=prob_mode == "approx")
                for tb, tl in plan.tiles])
        jax.block_until_ready(parts)
        scores, probs, dists = (np.concatenate(
            [np.concatenate([np.asarray(p[i]) for p in grp], axis=1)
             for grp in parts], axis=0)[:, plan.inv] for i in range(3))
        return (scores, probs, dists) if return_distances \
            else (scores, probs)
    if use_kernel:
        if interpret is None:
            from ..kernels.common import default_interpret
            interpret = default_interpret()
        from ..kernels.dtw import score_bank_offline_kernel
        scores, dists = score_bank_offline_kernel(
            jnp.asarray(xs), jnp.asarray(xlens), jnp.asarray(series),
            jnp.asarray(lengths), jnp.asarray(sx), jnp.asarray(sxx),
            band=band, block_k=min(128, _pad_pow2(k)),
            interpret=interpret)
        return (scores, dists) if return_distances else scores
    # jnp path: tile the bank in ascending-length order with a trimmed
    # per-tile width (ragged banks pay for their own lengths, not the
    # global max) and dispatch the tiles asynchronously — the [4, BK, M_t]
    # per-step working set stays cache-resident on CPU hosts, which is
    # where this path runs.  Per-reference results are independent of the
    # ordering/tiling, so the column un-permutation below is exact.
    if plan is None:
        plan = build_score_plan(series, lengths, block_k)
    elif plan.k != k:
        raise ValueError(
            f"ScoreBankPlan is for a {plan.k}-reference bank but "
            f"{k} references were passed — plans are bank-specific "
            "(rebuild via build_score_plan / SeriesBank.score_plan)")
    # dispatch per (job-group, tile) WITHOUT blocking in between: the
    # independent wavefronts overlap across host cores via async
    # dispatch, which an in-program lax.map over all J would serialize.
    # Small groups keep the dispatch count O(J/4 * K/BK), not O(J*K).
    #
    # Banded verdicts take the windowed wavefront instead: per-job work
    # drops from O((N+M)*M) to O((N+M)*w) and the [4, BK, w] window
    # carry is small enough to vmap whole batches into one dispatch, so
    # the group is the batch (this is what makes finish_many actually
    # faster than sequential finishes on a one-core host, where the
    # full-width wavefront is compute-bound either way).
    windowed = []
    if band is not None:
        for tb, tl in plan.tiles:
            m_t = int(tb.shape[1])
            w = _window_width(xlens, np.asarray(tl), m_t, band)
            windowed.append(w if w + 16 <= m_t else None)
    parts = []
    # banded calls are verdict-shaped: the whole batch goes out in ONE
    # call per tile (windowed wavefront on wide tiles, grouped-vmap
    # full-width scorer on narrow ones, both internally grouped), with
    # the scan truncated at the last live anti-diagonal of the TRUE
    # query lengths (bucketed to 16 so repeat drains reuse jit shapes).
    group = j if band is not None else _SCORE_J_GROUP
    n_live = int(xlens.max()) if j else 0
    for lo in range(0, j, group):
        hi = min(lo + group, j)
        xs_j = jnp.asarray(xs[lo:hi])
        xlens_j = jnp.asarray(xlens[lo:hi])
        sx_j = jnp.asarray(sx[lo:hi])
        sxx_j = jnp.asarray(sxx[lo:hi])
        parts.append([
            _score_tile_banded_many(xs_j, xlens_j, tb, tl, sx_j, sxx_j,
                                    band, windowed[ti], _VERDICT_GROUP)
            if windowed and windowed[ti] is not None else
            _score_tile_verdict(xs_j, xlens_j, tb, tl, sx_j, sxx_j, band,
                                min(n + int(tb.shape[1]) - 1,
                                    -(-(n_live + int(tb.shape[1]) - 1)
                                      // 16) * 16))
            if band is not None else
            _score_tile_many(xs_j, xlens_j, tb, tl, sx_j, sxx_j, band)
            for ti, (tb, tl) in enumerate(plan.tiles)])
    jax.block_until_ready(parts)
    scores = np.concatenate(
        [np.concatenate([np.asarray(p[0]) for p in group], axis=1)
         for group in parts], axis=0)[:, plan.inv]
    dists = np.concatenate(
        [np.concatenate([np.asarray(p[1]) for p in group], axis=1)
         for group in parts], axis=0)[:, plan.inv]
    return (scores, dists) if return_distances else scores


def dtw_score_bank(x, bank, lengths=None, band: Optional[int] = None, *,
                   plan: Optional[ScoreBankPlan] = None,
                   use_kernel: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   block_k: int = _SCORE_BLOCK_K,
                   return_distances: bool = False):
    """One query against the whole bank -> float32 [K] closed-end warp
    correlations (the matrix-free ``similarity_bank`` engine).  See
    :func:`dtw_score_bank_many`; this is its J == 1 column."""
    x = np.asarray(x, np.float32).reshape(-1)
    out = dtw_score_bank_many(
        x[None], bank, lengths, None, band, plan=plan,
        use_kernel=use_kernel, interpret=interpret, block_k=block_k,
        return_distances=return_distances)
    return (out[0][0], out[1][0]) if return_distances else out[0]


def dtw_score_pairs(xs, ys, xlens=None, ylens=None,
                    band: Optional[int] = None, *,
                    return_distances: bool = False):
    """Pairwise closed-end warp correlations -> float32 [P]: query p vs
    reference p, ragged on both sides (the matrix-free engine behind
    ``match_application``'s per-parameter-set scoring)."""
    xs = np.asarray(xs, np.float32)
    ys = jnp.asarray(ys, jnp.float32)
    p, n = xs.shape
    xl = np.full((p,), n, np.int32) if xlens is None \
        else np.asarray(xlens, np.int32)
    yl = _lengths_or_full(None if ylens is None else jnp.asarray(ylens),
                          *ys.shape)
    folds = [query_moments(xs[i, :xl[i]]) for i in range(p)]
    sx = np.asarray([f[0] for f in folds], np.float32)
    sxx = np.asarray([f[1] for f in folds], np.float32)
    scores, dists = _score_pairs_impl(
        jnp.asarray(xs), ys, jnp.asarray(xl), yl, jnp.asarray(sx),
        jnp.asarray(sxx), band)
    return (scores, dists) if return_distances else scores


@dataclasses.dataclass(frozen=True)
class DtwBankState:
    """Streaming DP state of one query against a padded [K, M] bank.

    Immutable: :func:`dtw_bank_extend` returns a new state.  ``row`` holds
    D[n-1, :] per reference (all +inf before the first sample); ``n`` is
    the number of query samples consumed so far.
    """
    row: jax.Array                    # [K, M] float32
    n: int                            # samples consumed
    bank: jax.Array                   # [K, M] float32
    lengths: jax.Array                # [K] int32
    band: Optional[int] = None
    query_len: Optional[int] = None   # required (and fixed) when banded

    def __len__(self) -> int:
        return int(self.bank.shape[0])

    def distances(self) -> jax.Array:
        """D(n, len_k) against every *complete* reference -> [K].

        Equals ``dtw_distance_bank(x[:n], bank, lengths)`` for the consumed
        prefix x[:n] (banded: once n == query_len — mid-stream banded
        values use the corridor anchored at the full query length, which
        a shorter one-shot solve would place differently); +inf before any
        sample arrived.
        """
        return jnp.take_along_axis(
            self.row, (self.lengths - 1)[:, None].astype(jnp.int32),
            axis=1)[:, 0]

    def prefix_distances(self) -> jax.Array:
        """Open-end distances min_j D(n, j) over true columns -> [K].

        The best alignment of the consumed prefix against *any* prefix of
        each reference — monotonically non-decreasing in ``n`` (every
        longer-prefix path extends a shorter one with non-negative cost),
        which is what makes early pruning sound.
        """
        m = self.row.shape[1]
        masked = jnp.where(jnp.arange(m, dtype=jnp.int32)[None, :]
                           < self.lengths[:, None], self.row, _INF)
        return jnp.min(masked, axis=1)

    # -- (de)hydration (crash-safe serving, serve.recovery) ------------------
    def dehydrate(self) -> Dict[str, np.ndarray]:
        """Host-resident dict of the full streaming state — flat string
        keys, numpy leaves, so it drops straight into a dict-nested
        checkpoint tree (``checkpoint.load_checkpoint_tree``).  Scalars
        ride as 0-d/1-element arrays; ``hydrate`` reverses exactly."""
        meta = np.asarray([self.n,
                           -1 if self.band is None else self.band,
                           -1 if self.query_len is None
                           else self.query_len], np.int64)
        return {"row": np.asarray(self.row), "bank": np.asarray(self.bank),
                "lengths": np.asarray(self.lengths), "meta": meta}

    @staticmethod
    def hydrate(tree: Dict[str, np.ndarray]) -> "DtwBankState":
        """Rebuild a :class:`DtwBankState` from :meth:`dehydrate` output
        (device placement via plain ``jnp.asarray`` — callers needing a
        sharded bank re-place afterwards).  The round trip is bitwise:
        every leaf is stored verbatim, nothing is recomputed."""
        n, band, qlen = (int(v) for v in np.asarray(tree["meta"]))
        return DtwBankState(
            row=jnp.asarray(tree["row"]), n=n,
            bank=jnp.asarray(tree["bank"]),
            lengths=jnp.asarray(tree["lengths"]),
            band=None if band < 0 else band,
            query_len=None if qlen < 0 else qlen)


def dtw_bank_init(bank: jax.Array, lengths: Optional[jax.Array] = None,
                  band: Optional[int] = None,
                  query_len: Optional[int] = None) -> DtwBankState:
    """Fresh streaming state for one query against a padded [K, M] bank.

    ``query_len`` (the expected total query length) is required for the
    banded variant: the Sakoe-Chiba corridor of row i is positioned
    relative to the *full* query, so an open-ended banded stream is
    ill-defined without it.
    """
    bank = jnp.asarray(bank, jnp.float32)
    k, m = bank.shape
    if band is not None and query_len is None:
        raise ValueError("banded streaming needs query_len (the band "
                         "geometry depends on the full query length)")
    return DtwBankState(row=jnp.full((k, m), _INF), n=0, bank=bank,
                        lengths=_lengths_or_full(lengths, k, m),
                        band=band, query_len=query_len)


def dtw_bank_extend(state: DtwBankState, chunk: jax.Array,
                    collect_rows: bool = False
                    ) -> Tuple[DtwBankState, Optional[jax.Array]]:
    """Consume one chunk of query samples; one jitted dispatch.

    Returns ``(new_state, rows)`` where ``rows`` is the [c, K, M] stack of
    DP rows produced by this chunk (for warp-based prefix scoring) when
    ``collect_rows``, else None.  The chunk is padded to a power-of-two
    bucket internally so arbitrary chunkings reuse a few compiled shapes.
    """
    chunk = jnp.asarray(chunk, jnp.float32).reshape(-1)
    c = int(chunk.shape[0])
    if c == 0:
        return state, (jnp.zeros((0,) + state.row.shape) if collect_rows
                       else None)
    cp = _chunk_bucket(c)
    padded = jnp.concatenate([chunk, jnp.zeros((cp - c,), jnp.float32)]) \
        if cp != c else chunk
    qlen = state.query_len if state.query_len is not None else 0
    rows, ns, collected = _bank_extend_many(
        state.row[None], jnp.asarray([state.n], jnp.int32), state.bank,
        state.lengths, padded[None], jnp.asarray([c], jnp.int32),
        jnp.asarray([qlen], jnp.int32), state.band, collect_rows)
    new = dataclasses.replace(state, row=rows[0], n=state.n + c)
    return new, (collected[:c, 0] if collect_rows else None)


# ---------------------------------------------------------------------------
# Backtracking / warping (numpy; O(N+M), data-dependent)
# ---------------------------------------------------------------------------

def backtrack(D: np.ndarray) -> np.ndarray:
    """Minimum-distance path through D from (0,0) to (N-1,M-1).

    Returns an int array [P, 2] of (i, j) pairs, monotonically
    non-decreasing in both coordinates.
    """
    D = np.asarray(D)
    n, m = D.shape
    i, j = n - 1, m - 1
    path = [(i, j)]
    while i > 0 or j > 0:
        if i == 0:
            j -= 1
        elif j == 0:
            i -= 1
        else:
            candidates = (D[i - 1, j - 1], D[i - 1, j], D[i, j - 1])
            k = int(np.argmin(candidates))
            if k == 0:
                i, j = i - 1, j - 1
            elif k == 1:
                i -= 1
            else:
                j -= 1
        path.append((i, j))
    return np.asarray(path[::-1], dtype=np.int64)


def warp_to(y: np.ndarray, path: np.ndarray, n: int) -> np.ndarray:
    """Build Y' (length n, aligned with X) from Y by repeating elements
    along the DTW path (paper §3.1.2: "Y' is always made from Y by
    repeating some of its elements based on D(X,Y)")."""
    yp = np.empty(n, dtype=np.asarray(y).dtype)
    for i, j in path:          # path is sorted by i; later pairs overwrite
        yp[i] = y[j]
    return yp


def dtw_warp(x: np.ndarray, y: np.ndarray,
             band: Optional[int] = None) -> Tuple[np.ndarray, float]:
    """Full pipeline: DTW -> backtrack -> warped Y' and distance D(N,M)."""
    x = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    D = np.asarray(dtw_matrix(x, yj) if band is None
                   else dtw_matrix_banded(x, yj, band))
    path = backtrack(D)
    return warp_to(np.asarray(y), path, len(np.asarray(x))), float(D[-1, -1])
