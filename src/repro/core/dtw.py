"""Dynamic Time Warping (paper §3.1.2, Eq. 1-2).

The paper's recurrence::

    D(i, j) = d(x_i, y_j) + min(D(i, j-1), D(i-1, j), D(i-1, j-1))

with ``d`` the pointwise Euclidean distance between utilization samples.

Three implementations, all agreeing to float tolerance:

* :func:`dtw_matrix` — pure-jnp, row-by-row ``lax.scan`` where each row is
  solved with a **min-plus associative scan** (the in-row dependence
  ``D[i,j] = min(m_j + d_j, D[i,j-1] + d_j)`` is an affine map in the
  tropical semiring, hence associative).  Depth O(N log M) instead of
  O(N·M); this is the TPU-friendly formulation and the ops-path default.
* ``repro.kernels.dtw`` — Pallas wavefront kernel (anti-diagonal
  parallelism across VPU lanes), validated against :mod:`ref` oracles.
* a numpy O(N·M) double loop lives in ``repro/kernels/dtw/ref.py`` as the
  oracle.

Backtracking (to build the warped series Y' of Eq. 3) is data-dependent and
O(N+M); it runs in numpy on the returned matrix.

Batched bank API (matching-phase hot path)
------------------------------------------
The matching phase compares one query against *every* reference in the
database (paper Fig. 4-b), so the per-pair functions above would cost one
device dispatch per reference.  The ``*_bank`` / ``*_pairs`` functions
instead take all K references packed into one ``[K, M]`` array (padded to a
common length M, with an ``int32 [K]`` vector of true lengths) and solve
every DP in a single jit dispatch:

* :func:`dtw_distance_bank` — distances only; keeps one ``[K, M]`` DP row as
  the scan carry (no [K, N, M] matrix materialization) and reads each
  distance at the dynamic column ``lengths[k] - 1``.
* :func:`dtw_matrix_bank` / :func:`dtw_matrix_pairs` — full matrices
  ``[K, N, M]`` for when backtracking (Eq. 3 warping) is needed.
* :class:`DtwBankState` / :func:`dtw_bank_init` / :func:`dtw_bank_extend` —
  the **streaming** engine: the DP state is carried across arriving query
  chunks (row-wise [K, M] carry), so an in-flight job can be matched while
  it executes; any chunking reproduces the one-shot solve exactly.

Padding correctness: ``D[:, j]`` only ever depends on columns ``<= j`` and
rows ``<= i``, so values in the padded tail cannot reach ``D[n-1, len_k-1]``
— banks may be padded with anything; we pad with the series' edge value.
The banded variants re-derive the Sakoe-Chiba band per series from its
*true* length (dynamic ``lengths[k]``), so a banked banded solve is exactly
the scalar banded solve of the unpadded series.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cost_matrix",
    "dtw_matrix",
    "dtw_distance",
    "dtw_matrix_banded",
    "dtw_matrix_bank",
    "dtw_matrix_pairs",
    "dtw_distance_bank",
    "DtwBankState",
    "dtw_bank_init",
    "dtw_bank_extend",
    "backtrack",
    "warp_to",
    "dtw_warp",
]

_INF = jnp.float32(3.0e38)


def cost_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise |x_i - y_j| (paper Eq. 2) -> [N, M]."""
    return jnp.abs(x[:, None] - y[None, :]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# min-plus scan formulation
# ---------------------------------------------------------------------------

def _minplus_affine_scan(a: jax.Array, s: jax.Array) -> jax.Array:
    """Inclusive composition of min-plus affine maps f_j(c) = min(c + a_j,
    s_j) along the last axis, applied to the initial carry c_{-1} = +inf.

    The maps compose associatively: (f2 o f1)(c) = min(c + a1 + a2,
    min(s1 + a2, s2)).  Applying the prefix composition to +inf leaves only
    the s-part.
    """

    def combine(f1, f2):  # f1 applied first
        a1, s1 = f1
        a2, s2 = f2
        return a1 + a2, jnp.minimum(s1 + a2, s2)

    _, s_acc = jax.lax.associative_scan(combine, (a, s), axis=-1)
    return s_acc


def _minplus_row(prev_row: jax.Array, d_row: jax.Array) -> jax.Array:
    """Solve one DP row given the previous row.

    m_j   = min(D[i-1, j], D[i-1, j-1])
    D[i,j] = d[i,j] + min(m_j, D[i,j-1])
           = min(s_j, D[i,j-1] + a_j)   with s_j = m_j + d_j, a_j = d_j.
    """
    shifted = jnp.concatenate([jnp.full((1,), _INF, prev_row.dtype),
                               prev_row[:-1]])
    m = jnp.minimum(prev_row, shifted)
    return _minplus_affine_scan(d_row, m + d_row)


@jax.jit
def dtw_matrix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Full accumulated-cost matrix D — [N, M] (paper Eq. 1)."""
    d = cost_matrix(x, y)

    # Row 0: D[0, j] = cumsum(d[0, :j+1])
    row0 = jnp.cumsum(d[0])

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        return row, row

    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


@jax.jit
def dtw_distance(x: jax.Array, y: jax.Array) -> jax.Array:
    """Similarity distance D(N, M) between two series."""
    return dtw_matrix(x, y)[-1, -1]


# ---------------------------------------------------------------------------
# Sakoe-Chiba banded variant (beyond-paper: O(N*w) work)
# ---------------------------------------------------------------------------

def _lengths_or_full(lengths: Optional[jax.Array], k: int, m: int) -> jax.Array:
    """int32 [K] true-length vector; defaults to the full padded width."""
    return jnp.asarray(lengths, jnp.int32) if lengths is not None \
        else jnp.full((k,), m, jnp.int32)


def _band_center(i: jax.Array, qlen: jax.Array, rlen: jax.Array) -> jax.Array:
    """Sakoe-Chiba band center (reference-axis column) of query row(s) i
    for a (qlen, rlen) series pair — THE band geometry; every banded
    variant (scalar, bank, pairs, wavefront) must derive its mask from
    this so batched == scalar stays structural."""
    return (i * (rlen - 1)) // jnp.maximum(qlen - 1, 1)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_banded(x: jax.Array, y: jax.Array, band: int) -> jax.Array:
    """DTW restricted to |i*M/N - j| <= band.  Returns full [N, M] matrix
    with +inf outside the band (so backtracking still works)."""
    return _masked_matrix(x, y, None, None, band)


# ---------------------------------------------------------------------------
# Batched bank / pairs API (matching-phase hot path; single jit dispatch)
# ---------------------------------------------------------------------------

def _band_mask(n: int, m: int, qlen: jax.Array, rlen: jax.Array,
               band: int) -> jax.Array:
    """Sakoe-Chiba mask [n, m] for a (qlen, rlen) series pair embedded in an
    [n, m] padded grid.  For j < rlen, i < qlen this is exactly the mask of
    the unpadded scalar solve; the padded region is don't-care."""
    ii = jnp.arange(n, dtype=jnp.int32)[:, None]
    jj = jnp.arange(m, dtype=jnp.int32)[None, :]
    return jnp.abs(jj - _band_center(ii, qlen, rlen)) <= band


def _masked_matrix(x: jax.Array, y: jax.Array, qlen: Optional[jax.Array],
                   rlen: Optional[jax.Array], band: Optional[int]) -> jax.Array:
    """Full [N, M] accumulated-cost matrix for one (possibly padded) pair.
    Unbanded padding needs no mask at all: D[i, j] depends only on cells
    (<=i, <=j), so the valid region is untouched by the padded tail."""
    d = cost_matrix(x, y)
    n, m = d.shape
    if band is not None:
        ql = jnp.int32(n) if qlen is None else qlen.astype(jnp.int32)
        rl = jnp.int32(m) if rlen is None else rlen.astype(jnp.int32)
        d = jnp.where(_band_mask(n, m, ql, rl, band), d, _INF)

    def step(prev_row, d_row):
        row = _minplus_row(prev_row, d_row)
        if band is not None:
            row = jnp.where(d_row >= _INF, _INF, row)
        return row, row

    row0 = jnp.where(d[0] >= _INF, _INF, jnp.cumsum(d[0])) if band is not None \
        else jnp.cumsum(d[0])
    _, rows = jax.lax.scan(step, row0, d[1:])
    return jnp.concatenate([row0[None, :], rows], axis=0)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_bank(x: jax.Array, bank: jax.Array,
                    lengths: Optional[jax.Array] = None,
                    band: Optional[int] = None) -> jax.Array:
    """One query x [N] against a padded bank [K, M] -> D matrices [K, N, M].

    ``lengths`` (int32 [K], true series lengths) is only consulted by the
    banded variant (the band is re-derived per series from its true
    length); callers slice ``D[k, :, :lengths[k]]`` before backtracking.
    """
    x = jnp.asarray(x, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    if band is None:
        return jax.vmap(lambda y: _masked_matrix(x, y, None, None, None))(bank)
    ls = _lengths_or_full(lengths, bank.shape[0], bank.shape[1])
    return jax.vmap(
        lambda y, l: _masked_matrix(x, y, None, l, band))(bank, ls)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_matrix_pairs(xs: jax.Array, ys: jax.Array,
                     xlens: Optional[jax.Array] = None,
                     ylens: Optional[jax.Array] = None,
                     band: Optional[int] = None) -> jax.Array:
    """Pairwise batched DTW: queries xs [P, N] vs references ys [P, M] ->
    D matrices [P, N, M], one jit dispatch for all P pairs (used to batch
    the whole of ``match_application`` — every (param set, app) pair at
    once, ragged on both sides)."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if band is None:
        return jax.vmap(
            lambda x, y: _masked_matrix(x, y, None, None, None))(xs, ys)
    p = xs.shape[0]
    ql = _lengths_or_full(xlens, p, xs.shape[1])
    rl = _lengths_or_full(ylens, p, ys.shape[1])
    return jax.vmap(
        lambda x, y, a, b: _masked_matrix(x, y, a, b, band))(xs, ys, ql, rl)


#: Out-of-range sentinel for the wavefront cost gather: large enough that
#: |x - _BIG| dominates any real path cost, small enough that a handful of
#: additions stay representable before saturating at f32 +inf (which the
#: min-reductions handle fine either way).
_BIG = jnp.float32(1.0e38)

#: lax.scan unroll factor for the wavefront distance scan; 2 measurably
#: beats 1 and 4 on CPU (less loop overhead vs. live-range pressure).
_WAVEFRONT_UNROLL = 2


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_distance_bank(x: jax.Array, bank: jax.Array,
                      lengths: Optional[jax.Array] = None,
                      band: Optional[int] = None) -> jax.Array:
    """Distances D(N, len_k) of one query against the whole bank -> [K].

    Anti-diagonal wavefront formulation: cell (i, j) lives on diagonal
    t = i + j at slot i, so the recurrence

        c_t[i] = d(i, t-i) + min(c_{t-1}[i], c_{t-1}[i-1], c_{t-2}[i-1])

    is purely elementwise over a [K, N] diagonal slab — O(K·N·M) total
    work with **no** log(M) scan factor, N+M-1 scan steps total (vs K·N
    for a per-pair loop), and a [K, N] carry (never [K, N, M]).  The cost
    diagonal d(·, t-·) is one contiguous dynamic-slice of the reversed,
    sentinel-padded bank.  Each distance is D[N-1, len_k-1], i.e. slot
    N-1 of diagonal t = N + len_k - 2; padding beyond ``lengths[k]`` can
    never influence it (D[i, j] depends only on cells (<=i, <=j)).

    The banded variant masks each diagonal with the per-series
    Sakoe-Chiba corridor re-derived from true lengths, so it equals the
    scalar ``dtw_matrix_banded(x, y_k[:len_k], band)[-1, -1]`` loop.
    """
    x = jnp.asarray(x, jnp.float32)
    bank = jnp.asarray(bank, jnp.float32)
    k, m = bank.shape
    n = x.shape[0]
    ls = _lengths_or_full(lengths, k, m)

    # reversed bank, sentinel-padded so slot i of diagonal t reads
    # y[t - i] = yrp[:, (n + m - 1 - t) + i] (out-of-range j -> _BIG).
    yrp = jnp.concatenate([jnp.full((k, n), _BIG), bank[:, ::-1],
                           jnp.full((k, n), _BIG)], axis=1)
    ii = jnp.arange(n, dtype=jnp.int32)
    if band is not None:
        # Sakoe-Chiba center of row i for series k (true length ls[k]).
        centers = _band_center(ii[None, :], jnp.int32(n),
                               ls[:, None])                      # [K, N]

    def step(carry, t):
        prev, prev2 = carry                     # c_{t-1}, c_{t-2}: [K, N]
        yd = jax.lax.dynamic_slice(yrp, (0, n + m - 1 - t), (k, n))
        d = jnp.abs(x[None, :] - yd)
        if band is not None:
            jj = t - ii                          # column of slot i
            d = jnp.where(jnp.abs(jj[None, :] - centers) <= band, d, _INF)
        # virtual corner D[-1, -1] = 0 enters as the shifted-in value of
        # the diagonal predecessor on the t == 0 step only.
        corner = jnp.where(t == 0, jnp.float32(0.0), _INF)
        p_left = jnp.concatenate(
            [jnp.full((k, 1), _INF), prev[:, : n - 1]], axis=1)
        p_diag = jnp.concatenate(
            [jnp.full((k, 1), corner), prev2[:, : n - 1]], axis=1)
        c = d + jnp.minimum(jnp.minimum(prev, p_left), p_diag)
        return (c, prev), c[:, n - 1]

    init = (jnp.full((k, n), _INF), jnp.full((k, n), _INF))
    _, outs = jax.lax.scan(step, init,
                           jnp.arange(n + m - 1, dtype=jnp.int32),
                           unroll=_WAVEFRONT_UNROLL)
    # distance_k = slot n-1 of diagonal n - 1 + (len_k - 1)
    return jnp.take_along_axis(outs.T, (ls + (n - 2))[:, None],
                               axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Streaming (prefix) bank DTW — the online matching engine
# ---------------------------------------------------------------------------
#
# The offline ``dtw_distance_bank`` wavefront needs the full query up front
# (its carry is indexed by query row).  The streaming engine instead carries
# the *row-wise* DP state: after consuming i query samples the state holds
# D[i-1, :] for every reference — a single [K, M] slab — and each new sample
# applies one ``_minplus_row`` update.  Any chunking of the query therefore
# reproduces the one-shot solve exactly: the DP recurrence is identical,
# only the dispatch boundaries move (tests/test_streaming.py pins this
# under random chunkings, ragged and banded).
#
# Row 0 rides on the same update via a virtual corner: D[-1, -1] = 0 enters
# as the shifted-in value of the first update only, turning it into the
# cumsum initialisation of ``dtw_matrix``.
#
# Everything is batched one level further for the serving layer: the jitted
# kernel takes J independent in-flight jobs stacked as [J, K, M] rows so a
# whole tick of a multi-job service is ONE device dispatch (invalid tail
# samples of ragged per-job chunks are masked out and leave the state
# untouched).

#: Chunks are padded up to the next power of two (>= _CHUNK_MIN) before
#: hitting the jitted kernel so arbitrary tick sizes reuse a handful of
#: compiled shapes.
_CHUNK_MIN = 8


def _chunk_bucket(c: int) -> int:
    return max(_CHUNK_MIN, 1 << (max(c, 1) - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("band", "collect_rows"))
def _bank_extend_many(rows: jax.Array, ns: jax.Array, bank: jax.Array,
                      lengths: jax.Array, chunks: jax.Array,
                      nvalid: jax.Array, qlens: jax.Array,
                      band: Optional[int], collect_rows: bool):
    """Advance J streaming DPs by one padded chunk each — one dispatch.

    rows    [J, K, M]  last DP row per job (init +inf)
    ns      [J] int32  query samples consumed per job
    chunks  [J, C]     new samples (tail beyond ``nvalid[j]`` is ignored)
    qlens   [J] int32  expected total query length (banded variant only;
                       the Sakoe-Chiba center of row i needs it)

    Returns (rows, ns, collected) where ``collected`` is the [C, J, K, M]
    stack of post-step rows (the D-matrix rows the scoring layer backtracks
    over) when ``collect_rows``, else None.
    """
    j, c = chunks.shape
    k, m = bank.shape
    jj = jnp.arange(m, dtype=jnp.int32)

    def step(carry, inp):
        rows, ns = carry
        x_s, s = inp                               # [J], scalar
        valid = s < nvalid                         # [J]
        d = jnp.abs(x_s[:, None, None] - bank[None, :, :])     # [J, K, M]
        if band is not None:
            centers = _band_center(ns[:, None], qlens[:, None],
                                   lengths[None, :])           # [J, K]
            d = jnp.where(
                jnp.abs(jj[None, None, :] - centers[:, :, None]) <= band,
                d, _INF)
        # virtual corner D[-1, -1] = 0 for each job's first sample only
        corner = jnp.where(ns == 0, jnp.float32(0.0), _INF)    # [J]
        shifted = jnp.concatenate(
            [jnp.broadcast_to(corner[:, None, None], (j, k, 1)),
             rows[:, :, :-1]], axis=2)
        mn = jnp.minimum(rows, shifted)
        new = _minplus_affine_scan(d, mn + d)
        if band is not None:
            new = jnp.where(d >= _INF, _INF, new)
        rows = jnp.where(valid[:, None, None], new, rows)
        ns = ns + valid.astype(jnp.int32)
        return (rows, ns), (rows if collect_rows else jnp.zeros((0,)))

    (rows, ns), collected = jax.lax.scan(
        step, (rows, ns), (chunks.T, jnp.arange(c, dtype=jnp.int32)))
    return rows, ns, (collected if collect_rows else None)


@dataclasses.dataclass(frozen=True)
class DtwBankState:
    """Streaming DP state of one query against a padded [K, M] bank.

    Immutable: :func:`dtw_bank_extend` returns a new state.  ``row`` holds
    D[n-1, :] per reference (all +inf before the first sample); ``n`` is
    the number of query samples consumed so far.
    """
    row: jax.Array                    # [K, M] float32
    n: int                            # samples consumed
    bank: jax.Array                   # [K, M] float32
    lengths: jax.Array                # [K] int32
    band: Optional[int] = None
    query_len: Optional[int] = None   # required (and fixed) when banded

    def __len__(self) -> int:
        return int(self.bank.shape[0])

    def distances(self) -> jax.Array:
        """D(n, len_k) against every *complete* reference -> [K].

        Equals ``dtw_distance_bank(x[:n], bank, lengths)`` for the consumed
        prefix x[:n] (banded: once n == query_len — mid-stream banded
        values use the corridor anchored at the full query length, which
        a shorter one-shot solve would place differently); +inf before any
        sample arrived.
        """
        return jnp.take_along_axis(
            self.row, (self.lengths - 1)[:, None].astype(jnp.int32),
            axis=1)[:, 0]

    def prefix_distances(self) -> jax.Array:
        """Open-end distances min_j D(n, j) over true columns -> [K].

        The best alignment of the consumed prefix against *any* prefix of
        each reference — monotonically non-decreasing in ``n`` (every
        longer-prefix path extends a shorter one with non-negative cost),
        which is what makes early pruning sound.
        """
        m = self.row.shape[1]
        masked = jnp.where(jnp.arange(m, dtype=jnp.int32)[None, :]
                           < self.lengths[:, None], self.row, _INF)
        return jnp.min(masked, axis=1)


def dtw_bank_init(bank: jax.Array, lengths: Optional[jax.Array] = None,
                  band: Optional[int] = None,
                  query_len: Optional[int] = None) -> DtwBankState:
    """Fresh streaming state for one query against a padded [K, M] bank.

    ``query_len`` (the expected total query length) is required for the
    banded variant: the Sakoe-Chiba corridor of row i is positioned
    relative to the *full* query, so an open-ended banded stream is
    ill-defined without it.
    """
    bank = jnp.asarray(bank, jnp.float32)
    k, m = bank.shape
    if band is not None and query_len is None:
        raise ValueError("banded streaming needs query_len (the band "
                         "geometry depends on the full query length)")
    return DtwBankState(row=jnp.full((k, m), _INF), n=0, bank=bank,
                        lengths=_lengths_or_full(lengths, k, m),
                        band=band, query_len=query_len)


def dtw_bank_extend(state: DtwBankState, chunk: jax.Array,
                    collect_rows: bool = False
                    ) -> Tuple[DtwBankState, Optional[jax.Array]]:
    """Consume one chunk of query samples; one jitted dispatch.

    Returns ``(new_state, rows)`` where ``rows`` is the [c, K, M] stack of
    DP rows produced by this chunk (for warp-based prefix scoring) when
    ``collect_rows``, else None.  The chunk is padded to a power-of-two
    bucket internally so arbitrary chunkings reuse a few compiled shapes.
    """
    chunk = jnp.asarray(chunk, jnp.float32).reshape(-1)
    c = int(chunk.shape[0])
    if c == 0:
        return state, (jnp.zeros((0,) + state.row.shape) if collect_rows
                       else None)
    cp = _chunk_bucket(c)
    padded = jnp.concatenate([chunk, jnp.zeros((cp - c,), jnp.float32)]) \
        if cp != c else chunk
    qlen = state.query_len if state.query_len is not None else 0
    rows, ns, collected = _bank_extend_many(
        state.row[None], jnp.asarray([state.n], jnp.int32), state.bank,
        state.lengths, padded[None], jnp.asarray([c], jnp.int32),
        jnp.asarray([qlen], jnp.int32), state.band, collect_rows)
    new = dataclasses.replace(state, row=rows[0], n=state.n + c)
    return new, (collected[:c, 0] if collect_rows else None)


# ---------------------------------------------------------------------------
# Backtracking / warping (numpy; O(N+M), data-dependent)
# ---------------------------------------------------------------------------

def backtrack(D: np.ndarray) -> np.ndarray:
    """Minimum-distance path through D from (0,0) to (N-1,M-1).

    Returns an int array [P, 2] of (i, j) pairs, monotonically
    non-decreasing in both coordinates.
    """
    D = np.asarray(D)
    n, m = D.shape
    i, j = n - 1, m - 1
    path = [(i, j)]
    while i > 0 or j > 0:
        if i == 0:
            j -= 1
        elif j == 0:
            i -= 1
        else:
            candidates = (D[i - 1, j - 1], D[i - 1, j], D[i, j - 1])
            k = int(np.argmin(candidates))
            if k == 0:
                i, j = i - 1, j - 1
            elif k == 1:
                i -= 1
            else:
                j -= 1
        path.append((i, j))
    return np.asarray(path[::-1], dtype=np.int64)


def warp_to(y: np.ndarray, path: np.ndarray, n: int) -> np.ndarray:
    """Build Y' (length n, aligned with X) from Y by repeating elements
    along the DTW path (paper §3.1.2: "Y' is always made from Y by
    repeating some of its elements based on D(X,Y)")."""
    yp = np.empty(n, dtype=np.asarray(y).dtype)
    for i, j in path:          # path is sorted by i; later pairs overwrite
        yp[i] = y[j]
    return yp


def dtw_warp(x: np.ndarray, y: np.ndarray,
             band: Optional[int] = None) -> Tuple[np.ndarray, float]:
    """Full pipeline: DTW -> backtrack -> warped Y' and distance D(N,M)."""
    x = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    D = np.asarray(dtw_matrix(x, yj) if band is None
                   else dtw_matrix_banded(x, yj, band))
    path = backtrack(D)
    return warp_to(np.asarray(y), path, len(np.asarray(x))), float(D[-1, -1])
