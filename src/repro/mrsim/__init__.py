from .simulator import (APPS, JobParams, simulate_cpu_series,
                        simulate_cpu_series_uncertain,
                        iter_cpu_series, paper_param_sets)

__all__ = ["APPS", "JobParams", "simulate_cpu_series",
           "simulate_cpu_series_uncertain", "iter_cpu_series",
           "paper_param_sets"]
