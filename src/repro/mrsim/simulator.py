"""MapReduce CPU-utilization trace simulator.

Hadoop itself is out of scope for this reproduction, so the paper's
Table-1 experiment (WordCount / TeraSort / Exim-mainlog similarity) is
evaluated on traces generated with the same structure the paper measures:
a map phase executed in waves (``ceil(ceil(I/FS) / M)`` waves of task
sawtooth), a shuffle valley, and a reduce phase — with per-application CPU
intensities.  WordCount and Exim parsing are both per-line text tokenisers
(map-heavy, high CPU, small intermediate data); TeraSort is a sort
(IO-heavy map, long shuffle, merge-heavy reduce).  Measurement noise is
additive Gaussian plus occasional scheduler spikes, seeded per
(app, params) so experiments are deterministic.

The knobs are exactly the paper's four configuration parameters: number of
mappers M, number of reducers R, file-split size FS (MB), input size I (MB).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["AppProfile", "APPS", "JobParams", "simulate_cpu_series",
           "simulate_cpu_series_uncertain", "iter_cpu_series",
           "paper_param_sets"]


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    map_cpu: float          # plateau CPU utilization during a map wave
    map_cost: float         # seconds of map work per MB per task slot
    shuffle_cpu: float      # CPU level during shuffle
    shuffle_ratio: float    # intermediate-data size relative to input
    reduce_cpu: float       # plateau CPU during reduce
    reduce_cost: float      # seconds of reduce work per MB of intermediate
    ramp: float             # seconds to ramp a wave up/down
    burstiness: float       # amplitude of within-wave oscillation


#: Three applications from the paper (§5).  WordCount and Exim share the
#: text-parse profile family; TeraSort is sort/shuffle dominated.
APPS: Dict[str, AppProfile] = {
    "wordcount": AppProfile("wordcount", map_cpu=0.88, map_cost=0.55,
                            shuffle_cpu=0.30, shuffle_ratio=0.18,
                            reduce_cpu=0.62, reduce_cost=0.65, ramp=3.0,
                            burstiness=0.06),
    "exim":      AppProfile("exim",      map_cpu=0.84, map_cost=0.60,
                            shuffle_cpu=0.33, shuffle_ratio=0.22,
                            reduce_cpu=0.58, reduce_cost=0.70, ramp=3.5,
                            burstiness=0.07),
    "terasort":  AppProfile("terasort",  map_cpu=0.46, map_cost=0.35,
                            shuffle_cpu=0.24, shuffle_ratio=1.0,
                            reduce_cpu=0.78, reduce_cost=1.25, ramp=5.0,
                            burstiness=0.12),
}


@dataclasses.dataclass(frozen=True)
class JobParams:
    """The paper's configuration parameters."""
    mappers: int      # M
    reducers: int     # R
    split_mb: int     # FS
    input_mb: int     # I

    def as_dict(self) -> Dict[str, int]:
        return {"M": self.mappers, "R": self.reducers,
                "FS": self.split_mb, "I": self.input_mb}


def paper_param_sets() -> List[JobParams]:
    """The four parameter sets of paper Table 1."""
    return [JobParams(11, 6, 20, 30), JobParams(21, 30, 10, 80),
            JobParams(32, 21, 30, 80), JobParams(42, 33, 20, 60)]


def _seed_for(app: str, p: JobParams, run: int) -> int:
    h = hashlib.sha256(f"{app}|{p}|{run}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def _wave(t: np.ndarray, start: float, dur: float, level: float,
          ramp: float, burst: float, freq: float, phase: float) -> np.ndarray:
    """A trapezoidal task wave with within-wave oscillation."""
    up = np.clip((t - start) / max(ramp, 1e-6), 0.0, 1.0)
    down = np.clip((start + dur - t) / max(ramp, 1e-6), 0.0, 1.0)
    env = np.minimum(up, down)
    osc = 1.0 + burst * np.sin(2 * np.pi * freq * (t - start) + phase)
    return level * env * osc


def simulate_cpu_series(app: str, params: JobParams, *, run: int = 0,
                        dt: float = 1.0, noise: float = 0.03) -> np.ndarray:
    """1 Hz CPU-utilization series for one job execution (values in [0,1])."""
    prof = APPS[app]
    rng = np.random.default_rng(_seed_for(app, params, run))

    tasks = max(1, int(np.ceil(params.input_mb / params.split_mb)))
    waves = max(1, int(np.ceil(tasks / params.mappers)))
    slots_last = tasks - (waves - 1) * params.mappers
    wave_dur = max(6.0, prof.map_cost * params.split_mb
                   * min(tasks, params.mappers) / max(params.mappers, 1)
                   + 2.0 * prof.ramp)
    gap = 0.25 * prof.ramp

    inter_mb = prof.shuffle_ratio * params.input_mb
    shuffle_dur = max(4.0, 0.15 * inter_mb + 0.2 * params.reducers)
    reduce_dur = max(6.0, prof.reduce_cost * inter_mb / max(params.reducers, 1)
                     + 2.0 * prof.ramp)

    total = waves * (wave_dur + gap) + shuffle_dur + reduce_dur + 10.0
    t = np.arange(0.0, total, dt)
    u = np.full_like(t, 0.04)                      # daemon background load

    # map waves
    cursor = 2.0
    for w in range(waves):
        frac = 1.0 if w < waves - 1 else slots_last / min(tasks, params.mappers)
        level = prof.map_cpu * (0.55 + 0.45 * frac)
        u += _wave(t, cursor, wave_dur, level, prof.ramp, prof.burstiness,
                   freq=0.08 + 0.01 * (w % 3), phase=rng.uniform(0, 2 * np.pi))
        cursor += wave_dur + gap

    # shuffle valley (network/disk bound)
    u += _wave(t, cursor, shuffle_dur, prof.shuffle_cpu, prof.ramp,
               0.5 * prof.burstiness, freq=0.05, phase=rng.uniform(0, 2 * np.pi))
    cursor += shuffle_dur

    # reduce phase
    u += _wave(t, cursor, reduce_dur, prof.reduce_cpu, prof.ramp,
               prof.burstiness, freq=0.06, phase=rng.uniform(0, 2 * np.pi))

    # measurement noise + occasional scheduler spikes
    u += rng.normal(0.0, noise, size=u.shape)
    spikes = rng.random(u.shape) < 0.01
    u = np.where(spikes, u + rng.uniform(0.1, 0.3, size=u.shape), u)
    return np.clip(u, 0.0, 1.0).astype(np.float32)


def simulate_cpu_series_uncertain(app: str, params: JobParams, *,
                                  run: int = 0, dt: float = 1.0,
                                  noise: float = 0.03
                                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Heteroscedastic-noise twin of :func:`simulate_cpu_series` ->
    ``(series, variance)``, both float32 [N].

    The per-sample noise standard deviation is not constant: a slow
    seeded envelope modulates it between ``0.25 * noise`` (a quiet
    monitoring agent) and ``~1.75 * noise`` (a contended one), the shape
    real SysStat pollers show when the node they share is loaded.  The
    returned ``variance`` is the TRUE per-sample noise variance (the
    envelope squared) — what an uncertain-series matcher should be fed —
    so golden tests can compare probability-gated decisions against
    point decisions under honest uncertainty.  A separate entry point
    with its own RNG stream (seed namespace ``"het|"``), so existing
    :func:`simulate_cpu_series` golden traces are untouched.
    """
    clean = simulate_cpu_series(app, params, run=run, dt=dt, noise=0.0)
    n = clean.shape[0]
    h = hashlib.sha256(f"het|{app}|{params}|{run}".encode()).digest()
    rng = np.random.default_rng(int.from_bytes(h[:4], "little"))
    # slow envelope: a few random-phase sinusoids, normalized to
    # [0.25, ~1.75] x noise.
    t = np.arange(n, dtype=np.float64)
    env = np.zeros(n)
    for _ in range(3):
        f = rng.uniform(0.002, 0.02)
        env += rng.uniform(0.2, 1.0) * np.sin(2 * np.pi * f * t
                                              + rng.uniform(0, 2 * np.pi))
    env = 0.25 + 1.5 * (env - env.min()) / max(float(np.ptp(env)), 1e-9)
    std = noise * env
    u = clean.astype(np.float64) + rng.normal(0.0, 1.0, size=n) * std
    var = (std * std).astype(np.float32)
    return np.clip(u, 0.0, 1.0).astype(np.float32), var


def iter_cpu_series(app: str, params: JobParams, *, run: int = 0,
                    chunk: int = 16, dt: float = 1.0, noise: float = 0.03):
    """Stream one job's CPU series in arrival order, ``chunk`` samples at a
    time (the last chunk may be shorter).

    This is the monitoring-agent view of :func:`simulate_cpu_series` — what
    a SysStat poller hands the online matching service tick by tick while
    the job executes.  Identical values and determinism: concatenating the
    chunks reproduces ``simulate_cpu_series(...)`` exactly.
    """
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    s = simulate_cpu_series(app, params, run=run, dt=dt, noise=noise)
    for lo in range(0, s.shape[0], chunk):
        yield s[lo: lo + chunk]
