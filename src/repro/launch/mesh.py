"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls these.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over the real local device(s) — smoke tests / examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
