"""Single-host training driver (the runnable end-to-end path).

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --smoke \
        --steps 50
    PYTHONPATH=src python -m repro.launch.train --d-model 768 --layers 12 \
        --steps 300 --seq 256 --batch 8        # ~100M-param run

Features exercised: deterministic data pipeline, AdamW + cosine schedule,
grad accumulation, checkpoint/restart (atomic; resumes exactly),
heartbeat/straggler bookkeeping, and the paper's AutoTuner hook — the run
records its utilization signature + chosen exec config into the reference
DB so later runs can inherit tuned settings via DTW matching.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs as cfglib
from ..core.database import ReferenceDB
from ..core.signatures import signature_of
from ..core.tuner import AutoTuner
from ..data import DataPipeline, SyntheticCorpus
from ..checkpoint import CheckpointManager
from ..models import model as model_lib
from ..models.config import ModelConfig
from ..runtime import HeartbeatTracker, StragglerDetector
from ..train.optim import AdamWConfig, adamw_init, cosine_schedule
from ..train.step import make_train_step
from ..sharding.rules import ExecConfig


def build_config(args) -> ModelConfig:
    if args.arch:
        cfg = (cfglib.smoke_config(args.arch) if args.smoke
               else cfglib.get(args.arch))
        return dataclasses.replace(cfg, param_dtype="float32", dtype="float32")
    return ModelConfig(
        name=f"lm-{args.d_model}x{args.layers}",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab_size=args.vocab,
        param_dtype="float32", dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tuner-db", default=None,
                    help="reference DB dir: record this run's signature")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = build_config(args)
    print(f"[train] config {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    key = jax.random.PRNGKey(0)
    params = model_lib.init(key, cfg)
    n_params = model_lib.param_count(params)
    print(f"[train] {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, opt_cfg)
    ex = ExecConfig(microbatch=args.microbatch)
    sched = lambda s: cosine_schedule(s, peak_lr=args.lr, warmup=20,
                                      total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ex, opt_cfg, lr_schedule=sched),
                      donate_argnums=(0, 1))

    corpus = SyntheticCorpus(cfg.vocab_size,
                             num_codebooks=max(cfg.num_codebooks, 1))
    pipe = DataPipeline(corpus, seq_len=args.seq, global_batch=args.batch)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start_step = manifest["metadata"]["next_step"]
        print(f"[train] resumed from step {start_step}")

    hb = HeartbeatTracker(timeout=600.0)
    sd = StragglerDetector()

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        hb.beat(0, step, time.time())
        sd.record(0, dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms "
                  f"({tok_s:.0f} tok/s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state),
                     {"next_step": step + 1, "loss": loss})

    if mgr:
        mgr.save(args.steps, (params, opt_state),
                 {"next_step": args.steps, "loss": losses[-1]})

    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t_start:.0f}s")
    assert losses[-1] < losses[0], "loss did not improve"

    if args.tuner_db:
        db = (ReferenceDB.load(args.tuner_db)
              if os.path.exists(os.path.join(args.tuner_db, "index.json"))
              else ReferenceDB())
        tuner = AutoTuner(db)
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in pipe.batch_at(0).items()}
        sig = signature_of(
            lambda p, b: model_lib.loss_fn(p, b, cfg)[0], params, batch)
        workload = f"{cfg.name}/train_{args.seq}x{args.batch}"
        tuner.record(workload, ex.as_dict(),
                     score=float(-losses[-1]), series=sig)
        db.save(args.tuner_db)
        print(f"[train] recorded signature + exec config for {workload} "
              f"in {args.tuner_db}")


if __name__ == "__main__":
    main()
