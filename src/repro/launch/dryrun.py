import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), record
memory/cost analyses and the three roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-15b \
        --shape train_4k [--multi-pod] [--force]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline tables in EXPERIMENTS.md are generated from them.
"""

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ARCHS, SHAPES, canonical, cells, exec_default, get,
                       input_specs)
from ..core import hloparse
from ..core.hlocost import parse_module
from ..core.signatures import TPU_V5E
from ..models import model as model_lib
from ..models.config import ModelConfig
from ..sharding import rules
from ..train.optim import AdamWConfig, adamw_init
from ..train.step import make_train_step
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _ns(specs_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree (for out_shardings)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sds_with(specs_tree, shape_tree, mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        shape_tree, specs_tree)


def _apply_exec(cfg: ModelConfig, ex: rules.ExecConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, remat=ex.remat, attn_block_q=ex.attn_block_q,
        attn_block_kv=ex.attn_block_kv,
        blockwise_attn_threshold=getattr(ex, "blockwise_threshold", 4096),
        moe_expert_tp=getattr(ex, "moe_expert_tp", False))


def build_cell(arch: str, shape: str, mesh, ex: Optional[rules.ExecConfig] = None):
    """-> (jitted fn, arg ShapeDtypeStructs, meta dict)"""
    arch = canonical(arch)
    ex = ex or exec_default(arch, shape)
    cfg = _apply_exec(get(arch), ex)
    spec = SHAPES[shape]
    daxes = rules.logical_batch_axes(mesh)
    shard = rules.make_shard_fn(mesh, ex, spec.global_batch)

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: model_lib.init(k, cfg), key)
    pspecs = rules.param_specs(params_shape, cfg, mesh, ex)
    params_sds = _sds_with(pspecs, params_shape, mesh)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shape))

    meta = {"arch": arch, "shape": shape, "exec": ex.as_dict(),
            "n_params": n_params, "mesh": dict(mesh.shape)}

    if spec.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=ex.optim_dtype)
        opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shape)
        ospecs_mv = rules.opt_state_specs(params_shape, pspecs, mesh, ex)
        ospecs = type(opt_shape)(count=P(), m=ospecs_mv, v=ospecs_mv)
        opt_sds = _sds_with(ospecs, opt_shape, mesh)

        batch_shape = input_specs(arch, shape, reduced=cfg)
        bspecs = rules.batch_specs(batch_shape, mesh)
        batch_sds = _sds_with(bspecs, batch_shape, mesh)

        step = make_train_step(cfg, ex, opt_cfg, mesh=mesh, data_axes=daxes,
                               shard=shard)
        fn = jax.jit(step, out_shardings=(_ns(pspecs, mesh), _ns(ospecs, mesh), None),
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
        meta["step"] = "train_step"
        return fn, args, meta

    # serving cells
    cache_shape = model_lib.make_cache(cfg, spec.global_batch, spec.seq_len)
    cspecs = rules.cache_specs(cache_shape, cfg, mesh, spec.global_batch)
    cache_sds = _sds_with(cspecs, cache_shape, mesh)
    io = input_specs(arch, shape, reduced=cfg)
    io_specs = rules.batch_specs(io, mesh)
    io_sds = _sds_with(io_specs, io, mesh)

    if spec.kind == "prefill":
        def prefill_step(params, tokens, cache, extra_embeds, positions):
            return model_lib.prefill(params, tokens, cache, cfg,
                                     extra_embeds=extra_embeds,
                                     positions=positions, mesh=mesh,
                                     data_axes=daxes, shard=shard)
        fn = jax.jit(prefill_step, donate_argnums=(2,),
                     out_shardings=(None, _ns(cspecs, mesh)))
        args = (params_sds, io_sds["tokens"], cache_sds,
                io_sds.get("extra_embeds"), io_sds.get("positions"))
        meta["step"] = "prefill_step"
        return fn, args, meta

    def serve_step(params, token, cache, pos):
        return model_lib.decode_step(params, token, cache, pos, cfg,
                                     mesh=mesh, data_axes=daxes, shard=shard)
    fn = jax.jit(serve_step, donate_argnums=(2,), out_shardings=(None, _ns(cspecs, mesh)))
    args = (params_sds, io_sds["token"], cache_sds, io_sds["pos"])
    meta["step"] = "serve_step"
    return fn, args, meta


def _cost_scalar(ca: Dict[str, Any], key: str) -> float:
    if not ca:
        return 0.0
    total = 0.0
    for k, v in ca.items():
        if k == key or k.startswith(key):
            try:
                total += float(v)
            except (TypeError, ValueError):
                pass
    return total


def _kernel_io_estimate(cfg: ModelConfig, shape: str, chips: int,
                        spec_kind: str) -> float:
    """Analytic HBM IO per chip of the Pallas flash-attention / GLA kernels
    replacing the tagged XLA interior traffic: each kernel invocation reads
    q,k,v(+gates) and writes o once; backward re-reads them and writes
    dq,dk,dv (~2.5x forward IO with recompute)."""
    spec = SHAPES[shape]
    if spec_kind == "decode":
        tokens = spec.global_batch
    else:
        tokens = spec.global_batch * spec.seq_len
    mult = 3.5 if spec_kind == "train" else 1.0     # fwd + bwd(re-read+grads)
    per_layer = 0.0
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if "attn" in k or k == "shared_attn")
    n_gla = sum(1 for k in kinds if k in ("mamba2", "mlstm"))
    if cfg.attn_kind == "mla":
        width = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                                 + cfg.v_head_dim)
    else:
        width = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
    per_layer += n_attn * 4.0 * tokens * (width / 3.0) * 2  # q+k+v+o bf16
    d_inner = cfg.ssm_expand * cfg.d_model
    per_layer += n_gla * 4.0 * tokens * d_inner * 2
    return mult * per_layer / chips


def roofline(meta: Dict, cost: Dict, coll: Dict[str, float],
             spec_kind: str) -> Dict[str, Any]:
    chips = 1
    for v in meta["mesh"].values():
        chips *= v
    flops = _cost_scalar(cost, "flops")          # per-chip (partitioned HLO)
    nbytes = _cost_scalar(cost, "bytes accessed")
    coll_bytes = sum(coll.values())
    t_compute = flops / TPU_V5E.peak_flops
    t_memory = nbytes / TPU_V5E.hbm_bw
    t_coll = coll_bytes / TPU_V5E.ici_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n = meta["n_params"]
    spec = SHAPES[meta["shape"]]
    tokens = spec.global_batch * (spec.seq_len if spec_kind != "decode" else 1)
    mult = 6.0 if spec_kind == "train" else 2.0
    n_active = meta.get("n_active_params", n)
    model_flops_global = mult * n_active * tokens
    model_flops_chip = model_flops_global / chips
    return {
        "chips": chips, "per_chip": {"flops": flops, "bytes": nbytes,
                                     "collective_bytes": coll_bytes},
        "terms_seconds": terms, "dominant": dominant,
        "model_flops_global": model_flops_global,
        "useful_compute_ratio": (model_flops_chip / flops) if flops else 0.0,
        "roofline_fraction": (model_flops_chip / TPU_V5E.peak_flops
                              / max(terms.values())) if max(terms.values()) else 0.0,
        "collective_breakdown": coll,
    }


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             ex: Optional[rules.ExecConfig] = None, out_dir: str = OUT_DIR,
             force: bool = False, tag: str = "") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{canonical(arch)}__{shape}__{mesh_name}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, meta = build_cell(arch, shape, mesh, ex)

    # active params for MoE useful-FLOPs accounting
    cfg = get(arch)
    if cfg.is_moe:
        key = jax.random.PRNGKey(0)
        pshape = jax.eval_shape(lambda k: model_lib.init(k, cfg), key)
        meta["n_active_params"] = _active_params_abstract(pshape, cfg)

    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_info[attr] = int(v)

    # jax < 0.5 returns a one-element list of dicts (one per program);
    # newer jax returns the dict directly.
    raw_cost = compiled.cost_analysis() or {}
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0] if raw_cost else {}
    cost = dict(raw_cost)
    cost = {k: (float(v) if np.isscalar(v) else float(np.sum(v)))
            for k, v in cost.items() if not isinstance(v, (dict, list))}

    hlo = compiled.as_text()
    mc = parse_module(hlo)          # trip-count-aware per-device cost model
    coll = mc.collective_bytes
    coll_counts = mc.collective_counts
    spec_kind = SHAPES[shape].kind
    rf = roofline(meta, {"flops": mc.flops, "bytes accessed": mc.bytes},
                  coll, spec_kind)
    rf["xla_cost_analysis_flops"] = cost.get("flops", 0.0)

    # kernel-adjusted memory term: the tagged flash_tile / gla_chunk
    # interior traffic is an XLA-CPU fusion-boundary artifact — on TPU the
    # Pallas kernels keep those tiles in VMEM; replace it with the
    # analytic kernel IO.
    interior = (mc.tag_bytes.get("flash_tile", 0.0)
                + mc.tag_bytes.get("gla_chunk", 0.0))
    cfg_full = _apply_exec(get(arch), ex or exec_default(arch, shape))
    kio = _kernel_io_estimate(cfg_full, shape, rf["chips"], spec_kind)
    adj_bytes = max(mc.bytes - interior, 0.0) + kio
    t_adj = adj_bytes / TPU_V5E.hbm_bw
    terms_adj = dict(rf["terms_seconds"], memory=t_adj)
    model_flops_chip = rf["model_flops_global"] / rf["chips"]
    rf["kernel_adjusted"] = {
        "interior_bytes_removed": interior,
        "kernel_io_bytes": kio,
        "memory_term_s": t_adj,
        "dominant": max(terms_adj, key=terms_adj.get),
        "roofline_fraction": (model_flops_chip / TPU_V5E.peak_flops
                              / max(terms_adj.values()))
        if max(terms_adj.values()) else 0.0,
    }

    record = {
        **meta, "mesh_name": mesh_name,
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "memory_analysis": mem_info,
        "cost_analysis": {k: cost[k] for k in sorted(cost)[:20]},
        "collective_counts": coll_counts,
        "tag_flops": mc.tag_flops,
        "tag_bytes": mc.tag_bytes,
        "roofline": rf,
        "hlo_bytes": len(hlo),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"[dryrun] {arch} x {shape} x {mesh_name}: "
          f"dominant={rf['dominant']} "
          f"terms={ {k: f'{v:.3e}' for k, v in rf['terms_seconds'].items()} } "
          f"roofline_frac={rf['roofline_fraction']:.3f} "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return record


def _active_params_abstract(pshape, cfg: ModelConfig) -> int:
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pshape))
    routed = 0
    for seg in pshape["segments"]:
        for name, blk in seg.items():
            if "moe" in blk:
                routed += sum(int(np.prod(x.shape))
                              for x in jax.tree.leaves(blk["moe"]["experts"]))
    return int(total - routed + routed * cfg.top_k / cfg.num_experts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all cells on both meshes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--exec-json", default=None,
                    help="JSON dict of ExecConfig overrides")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    ex = None
    if args.exec_json:
        base = exec_default(args.arch, args.shape).as_dict() \
            if args.arch else {}
        base.update(json.loads(args.exec_json))
        ex = rules.ExecConfig.from_dict(base)

    if args.all:
        failures = []
        for arch, shape, _skip in cells():
            for mp in (False, True):
                try:
                    run_cell(arch, shape, multi_pod=mp, force=args.force,
                             tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"[dryrun] FAIL {arch} x {shape} mp={mp}: {e!r}")
        if failures:
            raise SystemExit(f"{len(failures)} cells failed: {failures}")
        print("[dryrun] all cells OK")
        return

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod, ex=ex,
             force=args.force, tag=args.tag)


if __name__ == "__main__":
    main()
