import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf diagnostics for a dry-run cell: top collectives and top
byte-traffic instructions, with while-loop trip multipliers.

    PYTHONPATH=src python -m repro.launch.diagnose --arch X --shape Y \
        [--multi-pod] [--top 15] [--bytes]
"""

import argparse
import re

from ..core import hlocost


def walk_costs(hlo: str):
    comps, entry = hlocost._parse_computations(hlo)
    an = hlocost._Analyzer(comps)
    coll_rows, byte_rows = [], []

    def walk(name, mult):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = an._trip_count(mc.group(1)) if mc else 1.0
                if mb:
                    walk(mb.group(1), mult * trips)
            elif ins.opcode in ("call", "conditional"):
                for c in ins.callees:
                    walk(c, mult)
            else:
                c = an._instr_cost(comp, ins, False)
                m = re.search(r'op_name="([^"]*)"', ins.line)
                op_name = m.group(1)[-100:] if m else "?"
                base = ins.opcode.replace("-start", "").replace("-done", "")
                if c.collective_bytes:
                    coll_rows.append((sum(c.collective_bytes.values()) * mult,
                                      mult, base, op_name))
                elif c.bytes > 0:
                    byte_rows.append((c.bytes * mult, mult, ins.opcode,
                                      op_name))
    walk(entry, 1.0)
    coll_rows.sort(reverse=True)
    byte_rows.sort(reverse=True)
    return coll_rows, byte_rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--bytes", action="store_true")
    ap.add_argument("--exec-json", default=None)
    args = ap.parse_args()

    import json as _json
    from ..configs import exec_default
    from ..sharding import rules
    from .dryrun import build_cell
    from .mesh import make_production_mesh

    ex = None
    if args.exec_json:
        base = exec_default(args.arch, args.shape).as_dict()
        base.update(_json.loads(args.exec_json))
        ex = rules.ExecConfig.from_dict(base)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, cell_args, meta = build_cell(args.arch, args.shape, mesh, ex)
    hlo = fn.lower(*cell_args).compile().as_text()
    coll_rows, byte_rows = walk_costs(hlo)

    print(f"== collectives (total {sum(r[0] for r in coll_rows):.3e} B/chip)")
    for b, mult, op, name in coll_rows[:args.top]:
        print(f"  {b:.2e} x{mult:5.0f} {op:18s} {name}")
    if args.bytes:
        print(f"== HBM traffic (total {sum(r[0] for r in byte_rows):.3e} B/chip)")
        for b, mult, op, name in byte_rows[:args.top]:
            print(f"  {b:.2e} x{mult:5.0f} {op:18s} {name}")


if __name__ == "__main__":
    main()
