"""Single-host serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b --smoke \
        --batch 4 --prompt-len 64 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from .. import configs as cfglib
from ..models import model as model_lib
from ..serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = cfglib.smoke_config(args.arch) if args.smoke else cfglib.get(args.arch)
    cfg = dataclasses.replace(cfg, param_dtype="float32", dtype="float32")
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    print(f"[serve] {cfg.name}: {model_lib.param_count(params)/1e6:.1f}M params")

    engine = ServeEngine(params, cfg,
                         max_len=args.prompt_len + args.max_new,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape = shape + (cfg.num_codebooks,)
    prompts = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)

    t0 = time.time()
    out = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = out.shape[0] * out.shape[1]
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.0f} tok/s incl. prefill+compile)")
    print(f"[serve] sample continuation: {out[0].reshape(out.shape[1], -1)[:8, 0]}")


if __name__ == "__main__":
    main()
