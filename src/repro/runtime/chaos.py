"""Chaos-injection harness for the crash-safe serving stack.

The recovery guarantees of ``serve.recovery`` (snapshot + WAL replay ==
never crashed, bit-identical) and the dispatch-resilience guarantees of
``serve.tuning`` (retry-then-fallback == fault-free) are only as good as
the faults they were demonstrated against.  This module is the fault
*generator*: a seeded, fully deterministic :class:`FaultPlan` that the
service consults at its hook points, so every chaos scenario in the test
suite replays exactly from its seed.

Fault classes covered (mirroring what a real deployment sees):

* **dispatch failures** — :meth:`FaultPlan.on_dispatch` raises
  :class:`InjectedDispatchError` on seeded ticks (with configurable
  burst length, so a burst longer than the retry budget exercises the
  kernel -> jnp fallback path);
* **sample corruption** — :meth:`FaultPlan.corrupt` flips seeded samples
  of a pushed chunk to NaN/Inf (the ingest layer must quarantine the
  job, not poison the shared slab);
* **clock skew** — :meth:`FaultPlan.skew` perturbs heartbeat ``now``
  values, including *backwards* jumps (the ``HeartbeatTracker`` guard);
* **process kill** — :meth:`FaultPlan.should_kill` marks seeded command
  indices; the subprocess scenario in ``tests/test_crash_recovery.py``
  SIGKILLs itself at the marked point and the parent asserts the
  restored service matches an uninterrupted golden run;
* **torn WAL tails** — :func:`truncate_file` chops bytes off a trace
  segment, the crash case ``serve.ingest.TraceLog`` must tolerate.

Overload fault classes (PR 9), driving ``serve.overload``:

* **submission spikes** — :meth:`FaultPlan.spike_multiplier` scales a
  scenario's nominal arrival rate by ``spike_factor`` during seeded
  windows (the 10x Poisson burst of the golden overload test);
* **slow dispatch** — :meth:`FaultPlan.slow_dispatch` returns seeded
  *extra latency seconds* to add to a tick's observed latency (never
  sleeps — the latency is reported, not paid, so overload tests run at
  full speed while the degradation ladder sees a saturated device);
* **queue-pressure bursts** — :meth:`FaultPlan.queue_burst` marks seeded
  windows during which a scenario withholds drains/ticks so ingest
  queues fill toward their bounds (admission-control backpressure).

Nothing here sleeps or consults a real clock: determinism is the point.
"""

from __future__ import annotations

import os
from typing import Optional

import numpy as np

__all__ = ["InjectedDispatchError", "FaultPlan", "truncate_file"]


class InjectedDispatchError(RuntimeError):
    """A dispatch failure injected by a :class:`FaultPlan` (stands in
    for a transient device/runtime error)."""


class FaultPlan:
    """Deterministic fault schedule, seeded once and consumed statefully.

    ``dispatch_fail_rate`` is the per-dispatch probability of starting a
    failure burst; ``dispatch_fail_burst`` is how many consecutive
    attempts of that dispatch fail (a burst longer than the service's
    retry budget forces the fallback path).  ``corrupt_rate`` is the
    per-push probability of poisoning one sample; ``skew_rate`` is the
    per-stamp probability of perturbing a heartbeat clock by up to
    ``±max_skew`` (backwards jumps included).  ``kill_every`` marks
    every N-th command index as a kill point for subprocess scenarios.
    """

    def __init__(self, seed: int = 0, *,
                 dispatch_fail_rate: float = 0.0,
                 dispatch_fail_burst: int = 1,
                 corrupt_rate: float = 0.0,
                 skew_rate: float = 0.0,
                 max_skew: float = 100.0,
                 kill_every: Optional[int] = None,
                 spike_rate: float = 0.0,
                 spike_factor: float = 10.0,
                 spike_len: int = 4,
                 slow_rate: float = 0.0,
                 slow_extra: float = 0.1,
                 queue_burst_rate: float = 0.0,
                 queue_burst_len: int = 2) -> None:
        if dispatch_fail_burst < 1:
            raise ValueError("dispatch_fail_burst must be >= 1")
        if kill_every is not None and kill_every < 1:
            raise ValueError("kill_every must be >= 1 (or None)")
        if spike_len < 1 or queue_burst_len < 1:
            raise ValueError("spike_len/queue_burst_len must be >= 1")
        self.seed = seed
        self.dispatch_fail_rate = float(dispatch_fail_rate)
        self.dispatch_fail_burst = int(dispatch_fail_burst)
        self.corrupt_rate = float(corrupt_rate)
        self.skew_rate = float(skew_rate)
        self.max_skew = float(max_skew)
        self.kill_every = kill_every
        self.spike_rate = float(spike_rate)
        self.spike_factor = float(spike_factor)
        self.spike_len = int(spike_len)
        self.slow_rate = float(slow_rate)
        self.slow_extra = float(slow_extra)
        self.queue_burst_rate = float(queue_burst_rate)
        self.queue_burst_len = int(queue_burst_len)
        # independent streams per fault class so e.g. enabling skew does
        # not shift which dispatches fail under the same seed.
        self._rng_dispatch = np.random.default_rng((seed, 1))
        self._rng_corrupt = np.random.default_rng((seed, 2))
        self._rng_skew = np.random.default_rng((seed, 3))
        self._rng_spike = np.random.default_rng((seed, 4))
        self._rng_slow = np.random.default_rng((seed, 5))
        self._rng_qburst = np.random.default_rng((seed, 6))
        self._burst_left = 0
        self._spike_left = 0
        self._qburst_left = 0
        #: dispatch attempts failed so far (diagnostics for tests).
        self.injected_failures = 0
        self.corrupted_pushes = 0
        self.slowed_dispatches = 0
        self.spiked_beats = 0
        self.queue_bursts = 0

    # -- dispatch failures ---------------------------------------------------
    def on_dispatch(self, kind: str = "tick") -> None:
        """Consulted once per dispatch *attempt* (retries re-consult):
        raises :class:`InjectedDispatchError` while a failure burst is
        active, and rolls the dice to start a new burst otherwise."""
        if self._burst_left > 0:
            self._burst_left -= 1
            self.injected_failures += 1
            raise InjectedDispatchError(
                f"injected {kind} failure (seed={self.seed})")
        if self.dispatch_fail_rate > 0.0 and \
                self._rng_dispatch.random() < self.dispatch_fail_rate:
            self._burst_left = self.dispatch_fail_burst - 1
            self.injected_failures += 1
            raise InjectedDispatchError(
                f"injected {kind} failure (seed={self.seed})")

    # -- sample corruption ---------------------------------------------------
    def corrupt(self, samples: np.ndarray) -> np.ndarray:
        """Return ``samples`` with (per plan) one seeded element replaced
        by NaN or ±Inf; the original array is never mutated."""
        s = np.asarray(samples, np.float32).reshape(-1)
        if not s.shape[0] or self.corrupt_rate <= 0.0 or \
                self._rng_corrupt.random() >= self.corrupt_rate:
            return samples
        out = np.array(s, np.float32)
        i = int(self._rng_corrupt.integers(s.shape[0]))
        out[i] = [np.nan, np.inf, -np.inf][
            int(self._rng_corrupt.integers(3))]
        self.corrupted_pushes += 1
        return out

    # -- clock skew ----------------------------------------------------------
    def skew(self, now: Optional[float]) -> Optional[float]:
        """Perturb a heartbeat timestamp (None passes through): uniform
        in ``[-max_skew, +max_skew]`` on seeded stamps — a negative draw
        is exactly the backwards jump the heartbeat guard absorbs."""
        if now is None or self.skew_rate <= 0.0 or \
                self._rng_skew.random() >= self.skew_rate:
            return now
        return now + float(self._rng_skew.uniform(-self.max_skew,
                                                  self.max_skew))

    # -- overload faults -----------------------------------------------------
    def spike_multiplier(self) -> float:
        """Consulted once per arrival beat: returns ``spike_factor``
        while a seeded submission spike is active (``spike_len``
        consecutive beats), else 1.0.  Scenarios multiply their nominal
        Poisson arrival rate by this."""
        if self._spike_left > 0:
            self._spike_left -= 1
            self.spiked_beats += 1
            return self.spike_factor
        if self.spike_rate > 0.0 and \
                self._rng_spike.random() < self.spike_rate:
            self._spike_left = self.spike_len - 1
            self.spiked_beats += 1
            return self.spike_factor
        return 1.0

    def slow_dispatch(self, kind: str = "tick") -> float:
        """Consulted once per completed dispatch: returns seeded extra
        latency *seconds* to fold into the observed tick latency (a
        saturated-device simulator).  Never sleeps — overload is
        reported to the degradation ladder, not actually paid."""
        if self.slow_rate > 0.0 and \
                self._rng_slow.random() < self.slow_rate:
            self.slowed_dispatches += 1
            return self.slow_extra
        return 0.0

    def queue_burst(self) -> bool:
        """Consulted once per beat: True while a seeded queue-pressure
        burst is active — the scenario withholds drains/ticks so
        bounded ingest queues fill toward their limits."""
        if self._qburst_left > 0:
            self._qburst_left -= 1
            return True
        if self.queue_burst_rate > 0.0 and \
                self._rng_qburst.random() < self.queue_burst_rate:
            self._qburst_left = self.queue_burst_len - 1
            self.queue_bursts += 1
            return True
        return False

    # -- process kill points -------------------------------------------------
    def should_kill(self, command_index: int) -> bool:
        """True when the scripted workload should SIGKILL itself after
        command ``command_index`` (0-based) — a modular schedule, so one
        plan yields a kill point however long the run is."""
        return (self.kill_every is not None and command_index >= 0
                and (command_index + 1) % self.kill_every == 0)


def truncate_file(path: str, drop_bytes: int) -> int:
    """Chop ``drop_bytes`` off the end of ``path`` (a torn-write
    simulator for WAL segments); returns the new size."""
    size = os.path.getsize(path)
    new = max(0, size - int(drop_bytes))
    with open(path, "rb+") as f:
        f.truncate(new)
    return new
