"""Retry/backoff for transient dispatch failures.

A device dispatch in the serving hot path can fail transiently —
preempted accelerator, a driver hiccup, an injected fault from
``runtime.chaos`` — and the service must degrade one tick, not die.
:func:`call_with_retry` wraps any callable with seeded exponential
backoff + jitter and an optional *fallback* callable tried once after
the retry budget is exhausted (the serving use: the Pallas kernel path
falls back to the jnp wavefront twin, which is pinned bit-identical, so
a degraded tick changes latency but never decisions).

The policy is deterministic per seed (jitter comes from a private
``random.Random``) and the sleeper is injectable, so fault-injection
tests run at full speed with a no-op clock.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type

__all__ = ["DispatchFailure", "RetryPolicy", "call_with_retry"]


class DispatchFailure(RuntimeError):
    """A dispatch failed on every retry AND on the fallback (or there
    was no fallback).  ``__cause__`` carries the last underlying
    error."""


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff + jitter: attempt ``i`` (0-based retry) sleeps
    ``base_delay * 2**i * (1 + jitter * u)``, ``u ~ U[0, 1)`` from a
    seeded private stream — deterministic schedules for tests, decorrelated
    retries across a fleet in production."""

    max_retries: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_delay < 0 or self.max_delay < 0 or self.jitter < 0:
            raise ValueError("delays/jitter must be >= 0")
        self._rng = random.Random(self.seed)

    def delay(self, attempt: int) -> float:
        d = min(self.max_delay, self.base_delay * (2.0 ** attempt))
        return d * (1.0 + self.jitter * self._rng.random())


def call_with_retry(fn: Callable, *, policy: RetryPolicy,
                    transient: Tuple[Type[BaseException], ...],
                    fallback: Optional[Callable] = None,
                    on_retry: Optional[Callable[[int, BaseException],
                                                None]] = None):
    """Run ``fn()``; on a ``transient`` error retry up to
    ``policy.max_retries`` times with backoff, then try ``fallback()``
    once.  Returns ``(result, report)`` where ``report`` is a dict with
    ``retries`` (extra attempts consumed) and ``degraded`` (True when
    the fallback produced the result).  Non-transient errors propagate
    immediately; exhausting both paths raises :class:`DispatchFailure`.
    """
    last: Optional[BaseException] = None
    for attempt in range(policy.max_retries + 1):
        try:
            return fn(), {"retries": attempt, "degraded": False}
        except transient as e:        # noqa: PERF203 - retry loop
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt < policy.max_retries:
                policy.sleep(policy.delay(attempt))
    if fallback is not None:
        try:
            return fallback(), {"retries": policy.max_retries + 1,
                                "degraded": True}
        except transient as e:
            last = e
    raise DispatchFailure(
        f"dispatch failed after {policy.max_retries + 1} attempts"
        + ("" if fallback is None else " + fallback")) from last
