"""Retry/backoff for transient dispatch failures.

A device dispatch in the serving hot path can fail transiently —
preempted accelerator, a driver hiccup, an injected fault from
``runtime.chaos`` — and the service must degrade one tick, not die.
:func:`call_with_retry` wraps any callable with seeded exponential
backoff + jitter and an optional *fallback* callable tried once after
the retry budget is exhausted (the serving use: the Pallas kernel path
falls back to the jnp wavefront twin, which is pinned bit-identical, so
a degraded tick changes latency but never decisions).

The policy is deterministic per seed (jitter comes from a private
``random.Random``) and the sleeper is injectable, so fault-injection
tests run at full speed with a no-op clock.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type

__all__ = ["CircuitBreaker", "DispatchFailure", "RetryPolicy",
           "call_with_retry"]


class DispatchFailure(RuntimeError):
    """A dispatch failed on every retry AND on the fallback (or there
    was no fallback).  ``__cause__`` carries the last underlying
    error."""


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff + jitter: attempt ``i`` (0-based retry) sleeps
    ``base_delay * 2**i * (1 + jitter * u)``, ``u ~ U[0, 1)`` from a
    seeded private stream — deterministic schedules for tests, decorrelated
    retries across a fleet in production."""

    max_retries: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_delay < 0 or self.max_delay < 0 or self.jitter < 0:
            raise ValueError("delays/jitter must be >= 0")
        self._rng = random.Random(self.seed)

    def delay(self, attempt: int) -> float:
        d = min(self.max_delay, self.base_delay * (2.0 ** attempt))
        return d * (1.0 + self.jitter * self._rng.random())


def call_with_retry(fn: Callable, *, policy: RetryPolicy,
                    transient: Tuple[Type[BaseException], ...],
                    fallback: Optional[Callable] = None,
                    on_retry: Optional[Callable[[int, BaseException],
                                                None]] = None,
                    max_elapsed: Optional[float] = None,
                    clock: Callable[[], float] = time.monotonic):
    """Run ``fn()``; on a ``transient`` error retry up to
    ``policy.max_retries`` times with backoff, then try ``fallback()``
    once.  Returns ``(result, report)`` where ``report`` is a dict with
    ``retries`` (extra attempts consumed) and ``degraded`` (True when
    the fallback produced the result).  Non-transient errors propagate
    immediately; exhausting both paths raises :class:`DispatchFailure`.

    ``max_elapsed`` adds a total wall-clock deadline on top of the
    attempt budget: before sleeping for the next backoff, if
    ``clock() - start + delay`` would exceed the deadline, remaining
    retries are abandoned and the fallback is tried immediately.  The
    jitter stream is drawn exactly as without a deadline (the delay is
    computed, then discarded), so seeded schedules are unchanged
    whenever the deadline is not hit.
    """
    last: Optional[BaseException] = None
    start = clock() if max_elapsed is not None else 0.0
    retries = 0
    for attempt in range(policy.max_retries + 1):
        try:
            return fn(), {"retries": attempt, "degraded": False}
        except transient as e:        # noqa: PERF203 - retry loop
            last = e
            retries = attempt
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt < policy.max_retries:
                d = policy.delay(attempt)
                if (max_elapsed is not None
                        and clock() - start + d > max_elapsed):
                    break
                policy.sleep(d)
    else:
        retries = policy.max_retries
    if fallback is not None:
        try:
            return fallback(), {"retries": retries + 1,
                                "degraded": True}
        except transient as e:
            last = e
    raise DispatchFailure(
        f"dispatch failed after {retries + 1} attempts"
        + ("" if fallback is None else " + fallback")) from last


class CircuitBreaker:
    """Closed/open/half-open breaker around a primary (kernel) dispatch
    path with a pinned-equivalent fallback.

    PR 8's one-shot Pallas->jnp fallback degrades a single dispatch;
    under a *persistent* kernel fault every tick still pays the full
    retry ladder before falling back.  The breaker remembers: after
    ``fail_threshold`` consecutive primary failures it OPENS and serves
    the fallback directly (no primary attempt, no retry ladder).  After
    ``cooldown`` fallback-served dispatches it goes HALF-OPEN and
    probes the primary at seeded intervals — one un-retried attempt per
    probe.  A successful probe re-closes the breaker (kernel path
    re-promoted); a failed probe re-opens it.  Because primary and
    fallback are bit-identical by construction, the breaker changes
    latency and counters, never decisions.

    State is JSON-serialisable via :meth:`state_dict` /
    :meth:`load_state` so a snapshot of a degraded service restores
    with the breaker still tripped.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, fail_threshold: int = 3, cooldown: int = 8,
                 probe_interval: int = 4, seed: int = 0):
        if fail_threshold < 1 or cooldown < 1 or probe_interval < 1:
            raise ValueError("breaker thresholds must be >= 1")
        self.fail_threshold = int(fail_threshold)
        self.cooldown = int(cooldown)
        self.probe_interval = int(probe_interval)
        self.seed = int(seed)
        self._rng = random.Random(seed)
        self.state = self.CLOSED
        self._fails = 0            # consecutive primary failures (closed)
        self._since_open = 0       # fallback dispatches since opening
        self._until_probe = 0      # half-open: dispatches until next probe
        self.opened_count = 0      # times the breaker tripped
        self.reclosed_count = 0    # times a probe re-promoted the kernel

    # -- decision -------------------------------------------------------
    def before_dispatch(self) -> str:
        """Route the next dispatch: ``"primary"`` (normal path, retries
        apply), ``"fallback"`` (skip the primary entirely) or
        ``"probe"`` (single un-retried primary attempt)."""
        if self.state == self.CLOSED:
            return "primary"
        if self.state == self.OPEN:
            self._since_open += 1
            if self._since_open >= self.cooldown:
                self.state = self.HALF_OPEN
                self._until_probe = self._rng.randint(1, self.probe_interval)
            return "fallback"
        # HALF_OPEN: count down to the next seeded probe slot.
        self._until_probe -= 1
        if self._until_probe <= 0:
            return "probe"
        return "fallback"

    # -- outcomes -------------------------------------------------------
    def record_success(self) -> None:
        """Primary (or probe) dispatch succeeded."""
        if self.state == self.HALF_OPEN:
            self.reclosed_count += 1
        self.state = self.CLOSED
        self._fails = 0
        self._since_open = 0
        self._until_probe = 0

    def record_failure(self) -> None:
        """Primary (or probe) dispatch exhausted its attempts."""
        if self.state == self.HALF_OPEN:
            self.state = self.OPEN
            self.opened_count += 1
            self._since_open = 0
            return
        self._fails += 1
        if self._fails >= self.fail_threshold:
            self.state = self.OPEN
            self.opened_count += 1
            self._fails = 0
            self._since_open = 0

    @property
    def engaged(self) -> bool:
        """True while the kernel path is demoted (open or half-open)."""
        return self.state != self.CLOSED

    # -- persistence ----------------------------------------------------
    def state_dict(self) -> dict:
        st = self._rng.getstate()
        return {"state": self.state, "fails": self._fails,
                "since_open": self._since_open,
                "until_probe": self._until_probe,
                "opened_count": self.opened_count,
                "reclosed_count": self.reclosed_count,
                "rng": [st[0], list(st[1]), st[2]]}

    def load_state(self, st: dict) -> None:
        self.state = str(st["state"])
        self._fails = int(st["fails"])
        self._since_open = int(st["since_open"])
        self._until_probe = int(st["until_probe"])
        self.opened_count = int(st["opened_count"])
        self.reclosed_count = int(st["reclosed_count"])
        r = st["rng"]
        self._rng.setstate((r[0], tuple(r[1]), r[2]))
