from .chaos import FaultPlan, InjectedDispatchError, truncate_file
from .fault import (HeartbeatTracker, StragglerDetector, ElasticController,
                    RescaleDecision, WorkerState)
from .retry import DispatchFailure, RetryPolicy, call_with_retry

__all__ = ["HeartbeatTracker", "StragglerDetector", "ElasticController",
           "RescaleDecision", "WorkerState",
           "FaultPlan", "InjectedDispatchError", "truncate_file",
           "DispatchFailure", "RetryPolicy", "call_with_retry"]
