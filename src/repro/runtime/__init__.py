from .fault import (HeartbeatTracker, StragglerDetector, ElasticController,
                    RescaleDecision, WorkerState)

__all__ = ["HeartbeatTracker", "StragglerDetector", "ElasticController",
           "RescaleDecision", "WorkerState"]
