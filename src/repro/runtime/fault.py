"""Fault-tolerance runtime: heartbeats, straggler detection, elastic
rescale decisions.

The control plane is deterministic and clock-injected so every policy is
unit-testable without real failures:

* :class:`HeartbeatTracker` — workers report (worker_id, step, t); a worker
  whose last heartbeat is older than ``timeout`` is declared dead.  Ids
  are any hashable: host ints in the training runtime, job-id strings in
  the streaming tuning service (``serve.ingest`` beats per push and the
  slot scheduler evicts swept jobs).
* :class:`StragglerDetector` — per-step durations; a worker consistently
  slower than ``factor`` x the median over a sliding window is flagged
  (the mitigation at the training-loop level is to drop it from the mesh
  at the next rescale point, since TPU SPMD steps are synchronous — the
  MapReduce-style "speculative re-execution" maps to re-sharding, see
  DESIGN.md).
* :class:`ElasticController` — given alive workers, picks the largest
  usable mesh (keeps the ``model`` axis fixed, shrinks/grows ``data`` to
  the largest power-of-two of alive hosts) and emits a
  :class:`RescaleDecision`; the train loop then checkpoints, rebuilds the
  mesh, and restores — restore-onto-new-mesh is native to
  ``repro.checkpoint``.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Deque, Dict, Hashable, List, Optional, Sequence

__all__ = ["WorkerState", "HeartbeatTracker", "StragglerDetector",
           "RescaleDecision", "ElasticController"]


@dataclasses.dataclass
class WorkerState:
    worker_id: Hashable
    last_step: int = -1
    last_time: float = 0.0
    alive: bool = True


class HeartbeatTracker:
    """Clock-injected liveness tracking, hardened against skewed clocks.

    Timestamps come from the callers (monitoring agents beat, the
    service sweeps), and on a real fleet those clocks jump — NTP steps,
    VM migrations, the chaos plan's skew injection.  Two monotonicity
    guards keep a skewed stamp from mass-evicting healthy workers:

    * a beat carrying a *backwards* ``now`` can never rewind
      ``last_time`` (the worker just proved it is alive; an older stamp
      adds no information), so a later honest sweep cannot time it out
      on the strength of a skewed beat;
    * a sweep carrying a backwards ``now`` is clamped to the sweep
      high-water mark, so the sweep clock is monotone too and
      ``sweep(t); sweep(t - skew)`` decides exactly what ``sweep(t)``
      alone would.
    """

    def __init__(self, timeout: float = 60.0):
        self.timeout = timeout
        self.workers: Dict[Hashable, WorkerState] = {}
        self._sweep_high_water = -float("inf")

    def beat(self, worker_id: Hashable, step: int, now: float) -> None:
        w = self.workers.setdefault(worker_id, WorkerState(worker_id))
        w.last_step = max(w.last_step, step)
        w.last_time = max(w.last_time, now)
        w.alive = True

    def sweep(self, now: float) -> List[Hashable]:
        """Mark timed-out workers dead; return newly-dead ids."""
        self._sweep_high_water = max(self._sweep_high_water, now)
        now = self._sweep_high_water
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_time > self.timeout:
                w.alive = False
                dead.append(w.worker_id)
        return sorted(dead)

    def alive_workers(self) -> List[Hashable]:
        return sorted(w.worker_id for w in self.workers.values() if w.alive)

    def forget(self, worker_id: Hashable) -> None:
        """Drop a worker that left cleanly (a finished/evicted serving
        job, a decommissioned host) so it can never be swept as newly
        dead after the fact — worker ids are reusable."""
        self.workers.pop(worker_id, None)


class StragglerDetector:
    def __init__(self, window: int = 16, factor: float = 1.5,
                 min_samples: int = 4):
        self.window = window
        self.factor = factor
        self.min_samples = min_samples
        self._durations: Dict[Hashable, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, worker_id: Hashable, step_duration: float) -> None:
        self._durations[worker_id].append(step_duration)

    def _median_of_medians(self) -> Optional[float]:
        meds = []
        for d in self._durations.values():
            if len(d) >= self.min_samples:
                s = sorted(d)
                meds.append(s[len(s) // 2])
        if not meds:
            return None
        meds.sort()
        return meds[len(meds) // 2]

    def stragglers(self) -> List[Hashable]:
        base = self._median_of_medians()
        if base is None:
            return []
        out = []
        for wid, d in self._durations.items():
            if len(d) < self.min_samples:
                continue
            s = sorted(d)
            if s[len(s) // 2] > self.factor * base:
                out.append(wid)
        return sorted(out)


@dataclasses.dataclass(frozen=True)
class RescaleDecision:
    should_rescale: bool
    new_data_parallel: int
    dropped_workers: Sequence[int]
    reason: str


class ElasticController:
    """Chooses the data-parallel degree from the alive/non-straggler set.

    ``model_parallel`` stays fixed (changing TP degree means re-sharding
    every weight — only worth it on large permanent shrinkage); the data
    axis snaps to the largest power of two <= usable hosts, matching the
    divisibility guards in ``repro.sharding.rules``.
    """

    def __init__(self, model_parallel: int, min_data_parallel: int = 1):
        self.model_parallel = model_parallel
        self.min_data_parallel = min_data_parallel

    @staticmethod
    def _pow2_floor(n: int) -> int:
        p = 1
        while p * 2 <= n:
            p *= 2
        return p

    def decide(self, current_data_parallel: int, alive: Sequence[int],
               stragglers: Sequence[int] = ()) -> RescaleDecision:
        usable = [w for w in alive if w not in set(stragglers)]
        target = max(self.min_data_parallel, self._pow2_floor(len(usable)))
        if target == current_data_parallel:
            return RescaleDecision(False, current_data_parallel, (),
                                   "stable")
        dropped = tuple(sorted(set(alive) - set(usable)))
        reason = ("shrink: dead/straggler workers" if
                  target < current_data_parallel else "grow: workers joined")
        return RescaleDecision(True, target, dropped, reason)

    def decide_ahead(self, current_data_parallel: int,
                     alive: Sequence[int],
                     stragglers: Sequence[int] = (), *,
                     overload_pressure: float = 0.0,
                     grow_threshold: float = 0.75,
                     shrink_threshold: float = 0.25) -> RescaleDecision:
        """Rescale-AHEAD: :meth:`decide` reacts to workers dying; this
        variant also reacts to the serving stack's measured overload
        (``TuningService.overload_pressure()`` — the degradation
        ladder's latency pressure and queue fill) BEFORE jobs are shed.

        Pressure at or above ``grow_threshold`` doubles the data axis
        (capped at the pow2 floor of the usable worker count — growing
        past the hardware is not a plan); pressure at or below
        ``shrink_threshold`` halves it (floored at
        ``min_data_parallel``), reclaiming hosts an earlier spike
        grabbed.  In between, defer to the reactive :meth:`decide`."""
        if not 0.0 <= shrink_threshold < grow_threshold <= 1.0:
            raise ValueError("need 0 <= shrink_threshold < "
                             "grow_threshold <= 1")
        usable = [w for w in alive if w not in set(stragglers)]
        ceil = max(self.min_data_parallel, self._pow2_floor(len(usable)))
        if overload_pressure >= grow_threshold \
                and current_data_parallel < ceil:
            target = min(ceil, current_data_parallel * 2)
            return RescaleDecision(
                True, target, (),
                f"grow-ahead: overload pressure {overload_pressure:.2f}")
        if overload_pressure <= shrink_threshold:
            if self.min_data_parallel < current_data_parallel <= ceil:
                target = max(self.min_data_parallel,
                             current_data_parallel // 2)
                return RescaleDecision(
                    True, target, (),
                    "shrink-ahead: overload pressure "
                    f"{overload_pressure:.2f}")
            # idle: reactive shrink (dead/straggler hosts) still applies,
            # but never grow an idle service onto newly-joined workers.
            d = self.decide(current_data_parallel, alive, stragglers)
            if d.new_data_parallel > current_data_parallel:
                return RescaleDecision(False, current_data_parallel, (),
                                       "stable: idle")
            return d
        return self.decide(current_data_parallel, alive, stragglers)
