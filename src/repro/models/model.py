"""Decoder-only LM assembly: scan-over-layers segments, heterogeneous block
patterns, KV/SSM caches, train loss, prefill and decode.

Params layout::

    {"embed": {...}, "final_norm": {...},
     "shared_attn": {...}?                      # zamba2 shared block
     "segments": [ {kind_name: stacked-params [repeats, ...], ...}, ... ]}

Caches mirror segments: ``cache["segments"][i][kind_name]`` is a pytree
stacked on the leading ``repeats`` axis, scanned alongside the params.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig, Segment, segments
from .layers import (Dtypes, cross_entropy, embed, embed_init, mlp, mlp_init,
                     rmsnorm, rmsnorm_init, unembed)

__all__ = ["init", "make_cache", "forward", "loss_fn", "prefill",
           "decode_step", "param_count", "active_param_count"]

ShardFn = Callable[[jax.Array, str], jax.Array]
_id_shard: ShardFn = lambda x, kind: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, kind: str, cfg: ModelConfig) -> Dict:
    if kind in ("attn", "attn_dense", "attn_moe"):
        ks = jax.random.split(key, 4)
        p = {"ln1": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
             "ln2": rmsnorm_init(cfg.d_model, Dtypes.param(cfg))}
        if cfg.attn_kind == "mla":
            p["attn"] = attn_mod.mla_init(ks[0], cfg)
        else:
            p["attn"] = attn_mod.gqa_init(ks[0], cfg)
        if kind == "attn_moe":
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg)
        return p
    if kind == "mamba2":
        return {"ln": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
                "mix": ssm_mod.mamba2_init(key, cfg)}
    if kind == "mlstm":
        return {"ln": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
                "mix": ssm_mod.mlstm_init(key, cfg)}
    if kind == "slstm":
        return {"ln": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
                "mix": ssm_mod.slstm_init(key, cfg)}
    if kind == "shared_attn":
        return {}  # weights live in params["shared_attn"]
    raise ValueError(f"unknown block kind {kind}")


def _shared_attn_init(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {"ln1": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
            "ln2": rmsnorm_init(cfg.d_model, Dtypes.param(cfg)),
            "attn": attn_mod.gqa_init(ks[0], cfg),
            "mlp": mlp_init(ks[1], cfg)}


def init(key, cfg: ModelConfig) -> Dict:
    keys = jax.random.split(key, 3 + len(segments(cfg)))
    params: Dict[str, Any] = {"embed": embed_init(keys[0], cfg),
                              "final_norm": rmsnorm_init(cfg.d_model,
                                                         Dtypes.param(cfg))}
    if any("shared_attn" in s.kinds for s in segments(cfg)):
        params["shared_attn"] = _shared_attn_init(keys[1], cfg)

    segs = []
    for si, seg in enumerate(segments(cfg)):
        kseg = jax.random.split(keys[2 + si], seg.repeats * len(seg.kinds))
        kseg = kseg.reshape(seg.repeats, len(seg.kinds), 2)
        seg_params = {}
        for ki, kind in enumerate(seg.kinds):
            name = f"{ki}_{kind}"
            stacked = jax.vmap(lambda k, kind=kind: _block_init(k, kind, cfg)
                               )(kseg[:, ki])
            seg_params[name] = stacked
        segs.append(seg_params)
    params["segments"] = segs
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _block_cache_spec(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    if kind in ("attn", "attn_dense", "attn_moe"):
        if cfg.attn_kind == "mla":
            return attn_mod.mla_cache_spec(cfg, batch, max_len)
        return attn_mod.gqa_cache_spec(cfg, batch, max_len)
    if kind == "shared_attn":
        return attn_mod.gqa_cache_spec(cfg, batch, max_len)
    if kind == "mamba2":
        return ssm_mod.mamba2_state_spec(cfg, batch)
    if kind == "mlstm":
        return ssm_mod.mlstm_state_spec(cfg, batch)
    if kind == "slstm":
        return ssm_mod.slstm_state_spec(cfg, batch)
    raise ValueError(kind)


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               concrete: bool = False) -> Dict:
    """Cache pytree of ShapeDtypeStructs (``concrete=False``) or zeros."""
    def stack(spec, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

    segs = []
    for seg in segments(cfg):
        seg_cache = {}
        for ki, kind in enumerate(seg.kinds):
            spec = _block_cache_spec(kind, cfg, batch, max_len)
            seg_cache[f"{ki}_{kind}"] = stack(spec, seg.repeats)
        segs.append(seg_cache)
    cache = {"segments": segs}
    if concrete:
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(kind: str, p, x, cfg: ModelConfig, positions, cache,
                 cache_pos, shared_params, mesh, data_axes, shard: ShardFn):
    """-> (x, new_cache, aux)"""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_dense", "attn_moe"):
        apply_attn = (attn_mod.mla_apply if cfg.attn_kind == "mla"
                      else attn_mod.gqa_apply)
        h, new_attn_cache = apply_attn(p["attn"],
                                       rmsnorm(p["ln1"], x, cfg.norm_eps),
                                       cfg, positions, cache, cache_pos,
                                       shard=shard)
        x = shard(x + h, "resid")
        h2_in = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "attn_moe":
            h2, aux = moe_mod.moe_apply(p["moe"], h2_in, cfg, mesh=mesh,
                                        data_axes=data_axes,
                                        expert_tp=cfg.moe_expert_tp)
        else:
            h2 = mlp(p["mlp"], h2_in, cfg, shard=shard)
        x = shard(x + h2, "resid")
        return x, new_attn_cache, aux
    if kind == "shared_attn":
        sp = shared_params
        h, new_cache = attn_mod.gqa_apply(sp["attn"],
                                          rmsnorm(sp["ln1"], x, cfg.norm_eps),
                                          cfg, positions, cache, cache_pos,
                                          shard=shard)
        x = shard(x + h, "resid")
        x = shard(x + mlp(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps), cfg,
                          shard=shard), "resid")
        return x, new_cache, aux
    if kind == "slstm":
        h, new_cache = ssm_mod.slstm_apply(
            p["mix"], rmsnorm(p["ln"], x, cfg.norm_eps), cfg, cache)
    else:
        mix = {"mamba2": ssm_mod.mamba2_apply,
               "mlstm": ssm_mod.mlstm_apply}[kind]
        h, new_cache = mix(p["mix"], rmsnorm(p["ln"], x, cfg.norm_eps), cfg,
                           cache, shard=shard)
    x = shard(x + h, "resid")
    return x, new_cache, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _run_segments(params, x, cfg: ModelConfig, positions, caches, cache_pos,
                  mesh, data_axes, shard: ShardFn):
    """Scan every segment.  ``caches`` None for training."""
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: List[Any] = []

    for si, seg in enumerate(segments(cfg)):
        seg_params = params["segments"][si]
        seg_cache = None if caches is None else caches["segments"][si]

        def body(x, layer_inputs, seg=seg):
            lp, lc = layer_inputs
            # re-assert the carry sharding at body entry: under remat the
            # saved per-layer residual is the body *input*, and without a
            # constraint XLA stores it replicated (measured 56 GB of the
            # kimi train_4k temp footprint)
            x = shard(x, "resid")
            aux_sum = jnp.zeros((), jnp.float32)
            new_lc = {} if lc is not None else None
            for ki, kind in enumerate(seg.kinds):
                name = f"{ki}_{kind}"
                blk_cache = None if lc is None else lc[name]
                x, nc, aux = _apply_block(kind, lp[name], x, cfg, positions,
                                          blk_cache, cache_pos, shared, mesh,
                                          data_axes, shard)
                aux_sum = aux_sum + aux
                if new_lc is not None:
                    new_lc[name] = nc
            return x, (new_lc, aux_sum)

        body = _remat_wrap(body, cfg)

        if seg.repeats == 1 or not cfg.scan_layers:
            # unrolled path
            outs = []
            for r in range(seg.repeats):
                lp = jax.tree.map(lambda a: a[r], seg_params)
                lc = (None if seg_cache is None
                      else jax.tree.map(lambda a: a[r], seg_cache))
                x, (nlc, aux) = body(x, (lp, lc))
                aux_total = aux_total + aux
                outs.append(nlc)
            if seg_cache is not None:
                new_caches.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *outs))
            else:
                new_caches.append(None)
        else:
            def scan_body(x, layer_inputs):
                x, (nlc, aux) = body(x, layer_inputs)
                return x, (nlc, aux)

            x, (nlc_stacked, auxs) = jax.lax.scan(
                scan_body, x, (seg_params, seg_cache))
            aux_total = aux_total + auxs.sum()
            new_caches.append(nlc_stacked)
    return x, new_caches, aux_total


def _default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def forward(params, tokens: jax.Array, cfg: ModelConfig, *,
            positions: Optional[jax.Array] = None,
            extra_embeds: Optional[jax.Array] = None,
            mesh=None, data_axes=("data",), shard: ShardFn = _id_shard
            ) -> Tuple[jax.Array, jax.Array]:
    """Training/scoring forward pass -> (logits [B,S,V*nb], aux_loss)."""
    B, S = tokens.shape[:2]
    x = embed(params["embed"], tokens, cfg)
    if extra_embeds is not None:     # modality stub: precomputed embeddings
        x = x + extra_embeds.astype(x.dtype)
    x = shard(x, "resid")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    x, _, aux = _run_segments(params, x, cfg, positions, None, None, mesh,
                              data_axes, shard)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = shard(unembed(params["embed"], x, cfg), "logits")
    return logits, aux


def loss_fn(params, batch: Dict, cfg: ModelConfig, *, mesh=None,
            data_axes=("data",), shard: ShardFn = _id_shard) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg,
                          positions=batch.get("positions"),
                          extra_embeds=batch.get("extra_embeds"),
                          mesh=mesh, data_axes=data_axes, shard=shard)
    labels = batch["labels"]
    if labels.ndim == 3:             # musicgen: [B,S,nb] codebook targets
        nb = labels.shape[-1]
        logits = logits.reshape(logits.shape[:2] + (nb, cfg.vocab_size))
    ce = cross_entropy(logits, labels, batch.get("mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params, tokens: jax.Array, cache: Dict, cfg: ModelConfig, *,
            positions=None, extra_embeds=None, mesh=None,
            data_axes=("data",), shard: ShardFn = _id_shard):
    """Process the prompt, fill the cache.  Returns (last_logits, cache)."""
    B, S = tokens.shape[:2]
    x = embed(params["embed"], tokens, cfg)
    if extra_embeds is not None:
        x = x + extra_embeds.astype(x.dtype)
    x = shard(x, "resid")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    x, new_caches, _ = _run_segments(params, x, cfg, positions, cache,
                                     jnp.int32(0), mesh, data_axes, shard)
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"segments": new_caches}


def decode_step(params, token: jax.Array, cache: Dict, pos: jax.Array,
                cfg: ModelConfig, *, mesh=None, data_axes=("data",),
                shard: ShardFn = _id_shard):
    """One decode step.  token: [B] (or [B, nb]); pos: scalar int32.
    Returns (logits [B, V*nb], new_cache)."""
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    B = tok.shape[0]
    x = embed(params["embed"], tok, cfg)
    x = shard(x, "resid")
    positions = _default_positions(cfg, B, 1, offset=pos)
    x, new_caches, _ = _run_segments(params, x, cfg, positions, cache, pos,
                                     mesh, data_axes, shard)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"segments": new_caches}


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    total = param_count(params)
    if not cfg.is_moe:
        return total
    def expert_size(tree):
        return sum(int(x.size) for x in jax.tree.leaves(tree))
    routed = 0
    for seg in params["segments"]:
        for name, blk in seg.items():
            if "moe" in blk:
                routed += expert_size(blk["moe"]["experts"])
    active_frac = cfg.top_k / cfg.num_experts
    return int(total - routed + routed * active_frac)
