"""Mixture-of-Experts FFN with expert parallelism.

Design (production path): experts are sharded over the ``model`` mesh axis
(EP composes with the Megatron-TP layout because activations are
replicated across ``model`` between blocks).  Inside a ``shard_map`` region
each device:

  1. computes router probabilities for its local tokens (router weights are
     replicated — redundant routing, no all-to-all for the gate);
  2. builds a capacity-bounded dispatch index for **its own experts only**
     (one-hot + cumsum position-in-expert, tokens over capacity drop);
  3. gathers tokens into a dense [E_local, C, D] buffer, runs the expert
     GEMMs, and scatters weighted outputs back to token order;
  4. ``psum`` over ``model`` combines contributions from all expert shards
     (same collective pattern as the TP row-parallel matmul it replaces).

This avoids the O(T*E*C) dispatch einsum entirely — at 384 experts that
tensor would be ~10^2 GB/device — while keeping every op a static-shape
gather/scatter that GSPMD lowers on any backend.  The identical local
function runs unmapped when no mesh is given (smoke tests / 1 device).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import Dtypes, dense_init
# version-tolerant shard_map shim, shared with the sharded streaming
# matcher tick (serve.tuning)
from ..sharding.compat import shard_map as _shard_map

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": dense_init(ks[0], D, E, pd, scale=0.02),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(pd),
            "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(pd),
            "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                       * (1.0 / math.sqrt(F))).astype(pd),
        },
    }
    if cfg.num_shared_experts:
        Fs = cfg.d_ff_expert * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kss[0], D, Fs, pd),
                       "w_up": dense_init(kss[1], D, Fs, pd),
                       "w_down": dense_init(kss[2], Fs, D, pd)}
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(4, c)


@jax.named_scope("moe_local")
def _moe_local(x2d, router_w, wg, wu, wd, cfg: ModelConfig,
               e_offset: jax.Array, axis: Optional[str]):
    """Per-device MoE over local experts.  x2d: [T, D] (local tokens);
    wg/wu/wd: local expert slices [E_loc, ...]; ``e_offset`` = first global
    expert id owned here."""
    T, D = x2d.shape
    E, K = cfg.num_experts, cfg.top_k
    E_loc = wg.shape[0]
    C = _capacity(T, cfg)

    logits = jnp.einsum("td,de->te", x2d, router_w.astype(x2d.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (computed on global stats; identical on all
    # model shards since routing is redundant)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # local expert ids in [0, E_loc); invalid -> E_loc (sentinel)
    le = top_e - e_offset                                        # [T, K]
    valid = (le >= 0) & (le < E_loc)
    le = jnp.where(valid, le, E_loc)

    # position of each (t, k) within its expert, counted in flat (t*K+k) order
    onehot = jax.nn.one_hot(le.reshape(-1), E_loc + 1, dtype=jnp.int32)  # [T*K, E+1]
    pos = jnp.cumsum(onehot, axis=0) - onehot                    # exclusive
    pos = (pos * onehot).sum(-1)                                 # [T*K]
    flat_le = le.reshape(-1)
    keep = (flat_le < E_loc) & (pos < C)

    # dispatch: slot -> token index (sentinel T => zero row)
    slot = jnp.where(keep, flat_le * C + pos, E_loc * C)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    slot_to_tok = jnp.full((E_loc * C + 1,), T, jnp.int32).at[slot].set(
        tok_idx.astype(jnp.int32), mode="drop")
    xpad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xe = xpad[slot_to_tok[:-1]].reshape(E_loc, C, D)

    # expert GEMMs
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype)))
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))      # [E_loc, C, D]

    # combine: scatter-add weighted expert outputs back to token order.
    # This stays capacity-sized ([E_loc*C, D]) — the gather formulation
    # materializes [T*K, D] (15 GB f32 per layer on kimi train_4k).
    w_slot = jnp.zeros((E_loc * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, top_p.reshape(-1), 0.0), mode="drop")[:-1]
    contrib = ye.reshape(E_loc * C, D) * w_slot[:, None].astype(ye.dtype)
    out = jnp.zeros((T + 1, D), x2d.dtype).at[slot_to_tok[:-1]].add(
        contrib.astype(x2d.dtype), mode="drop")[:T]

    if axis is not None:
        out = jax.lax.psum(out, axis)
    return out, aux


def moe_apply(p, x: jax.Array, cfg: ModelConfig,
              mesh: Optional[jax.sharding.Mesh] = None,
              data_axes: Tuple[str, ...] = ("data",),
              model_axis: str = "model",
              expert_tp: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    router_w = p["router"]["w"]
    ex = p["experts"]

    def run(x_loc, rw, wg, wu, wd, e_offset, axis):
        out, aux = _moe_local(x_loc.reshape(-1, D), rw, wg, wu, wd, cfg,
                              e_offset, axis)
        return out.reshape(x_loc.shape), aux

    if mesh is None or mesh.shape.get(model_axis, 1) == 1 or \
            cfg.num_experts % max(mesh.shape.get(model_axis, 1), 1) != 0:
        out, aux = run(x, router_w, ex["w_gate"], ex["w_up"], ex["w_down"],
                       jnp.int32(0), None)
    elif expert_tp:
        # Serving mode: experts over "model", expert FFN dim over the data
        # axes, tokens REPLICATED over the mesh (decode batches are tiny).
        # No weight collectives at all; one psum of [T, D] combines both
        # the F-partials (data) and non-local experts (model).
        ep = mesh.shape[model_axis]
        all_axes = tuple(data_axes) + (model_axis,)

        dp = 1
        for a in data_axes:
            dp *= mesh.shape[a]

        def mapped_tp(x_loc, rw, wg, wu, wd):
            idx = jax.lax.axis_index(model_axis)
            e_off = idx * (cfg.num_experts // ep)
            out, aux = run(x_loc, rw, wg, wu, wd, e_off, all_axes)
            # return only this device's batch slice so the residual stream
            # stays batch-sharded (a replicated output forces the next
            # layer's attention to all-gather the KV cache — measured
            # 3.2e10 B/chip/layer on deepseek decode_32k)
            if B % dp == 0:
                di = jax.lax.axis_index(data_axes)
                out = jax.lax.dynamic_slice_in_dim(out, di * (B // dp),
                                                   B // dp, axis=0)
            return out, jax.lax.pmean(aux, all_axes)

        out_spec = P(data_axes, None, None) if B % dp == 0 \
            else P(None, None, None)
        out, aux = _shard_map(
            mapped_tp, mesh=mesh,
            in_specs=(P(None, None, None), P(None, None),
                      P(model_axis, None, data_axes),
                      P(model_axis, None, data_axes),
                      P(model_axis, data_axes, None)),
            out_specs=(out_spec, P()),
        )(x, router_w, ex["w_gate"], ex["w_up"], ex["w_down"])
        aux = aux.mean() if aux.ndim else aux
    else:
        ep = mesh.shape[model_axis]

        all_axes = tuple(data_axes) + (model_axis,)

        def mapped(x_loc, rw, wg, wu, wd):
            idx = jax.lax.axis_index(model_axis)
            e_off = idx * (cfg.num_experts // ep)
            out, aux = run(x_loc, rw, wg, wu, wd, e_off, model_axis)
            return out, jax.lax.pmean(aux, all_axes)

        out, aux = _shard_map(
            mapped, mesh=mesh,
            in_specs=(P(data_axes, None, None), P(None, None),
                      P(model_axis, None, None), P(model_axis, None, None),
                      P(model_axis, None, None)),
            out_specs=(P(data_axes, None, None), P()),
        )(x, router_w, ex["w_gate"], ex["w_up"], ex["w_down"])
        aux = aux.mean() if aux.ndim else aux

    if cfg.num_shared_experts:
        sh = p["shared"]
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["w_gate"]["w"].astype(x.dtype)))
        h = h * jnp.einsum("bsd,df->bsf", x, sh["w_up"]["w"].astype(x.dtype))
        out = out + jnp.einsum("bsf,fd->bsd", h, sh["w_down"]["w"].astype(x.dtype))
    return out, aux
