"""Shared layer primitives: norms, projections, rotary embeddings, MLPs,
embeddings and the loss. Parameters are plain nested dicts of jnp arrays so
everything composes with pjit/shard_map and ``jax.eval_shape``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["dense_init", "dense", "rmsnorm_init", "rmsnorm", "rope",
           "mrope", "mlp_init", "mlp", "embed_init", "embed", "unembed",
           "cross_entropy", "Dtypes"]


class Dtypes:
    @staticmethod
    def param(cfg: ModelConfig):
        return jnp.dtype(cfg.param_dtype)

    @staticmethod
    def compute(cfg: ModelConfig):
        return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# dense / norm
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
                  ).astype(dtype)}


def dense(p, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S] int."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)                  # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin,
                            x2f * cos + x1f * sin], axis=-1).astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array, sections: Tuple[int, ...],
          theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): ``positions`` is [3, ..., S] for the
    (temporal, height, width) ids; the head_dim/2 frequency channels are
    split into ``sections`` (summing to head_dim//2), each section rotated
    by its own position stream."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(x.shape[-1], theta)                  # [half]
    parts = []
    start = 0
    for s, sec in zip(positions, sections):
        parts.append(s[..., None].astype(jnp.float32) * freqs[start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                    # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin,
                            x2f * cos + x1f * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    pd = Dtypes.param(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], cfg.d_model, d_ff, pd),
         "w_down": dense_init(ks[1], d_ff, cfg.d_model, pd)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[2], cfg.d_model, d_ff, pd)
    return p


def mlp(p, x: jax.Array, cfg: ModelConfig, shard=lambda x, k: x) -> jax.Array:
    up = shard(dense(p["w_up"], x), "ffn")
    if cfg.act == "swiglu":
        h = jax.nn.silu(shard(dense(p["w_gate"], x), "ffn")) * up
    else:
        h = jax.nn.gelu(up)
    return dense(p["w_down"], h)


# ---------------------------------------------------------------------------
# embeddings / loss
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    nb = max(cfg.num_codebooks, 1)
    ks = jax.random.split(key, 2)
    p = {"table": (jax.random.normal(ks[0], (nb * cfg.vocab_size, cfg.d_model),
                                     jnp.float32) * 0.02).astype(pd)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model,
                                  nb * cfg.vocab_size, pd, scale=0.02)
    return p


def embed(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: [B, S] or [B, S, num_codebooks] -> [B, S, d] (codebooks sum)."""
    table = p["table"].astype(Dtypes.compute(cfg))
    if tokens.ndim == 3:                      # musicgen: per-codebook offset
        nb = tokens.shape[-1]
        offs = jnp.arange(nb, dtype=tokens.dtype) * cfg.vocab_size
        return jnp.take(table, tokens + offs, axis=0).sum(axis=2)
    return jnp.take(table, tokens, axis=0)


@jax.named_scope("unembed")
def unembed(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """-> [B, S, (nb*)vocab] logits."""
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
    return dense(p["unembed"], x)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] (any leading dims)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
