from .config import ModelConfig, segments
from . import layers, attention, moe, ssm, model
from .model import (init, make_cache, forward, loss_fn, prefill, decode_step,
                    param_count, active_param_count)

__all__ = ["ModelConfig", "segments", "layers", "attention", "moe", "ssm",
           "model", "init", "make_cache", "forward", "loss_fn", "prefill",
           "decode_step", "param_count", "active_param_count"]
