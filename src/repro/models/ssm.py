"""Recurrent / state-space blocks: a shared chunked gated-linear-attention
(GLA) core, Mamba2 (SSD), mLSTM (xLSTM matrix memory) and sLSTM blocks.

Both Mamba2 and mLSTM are instances of the same per-head recurrence::

    S_t = a_t * S_{t-1} + k_t^T v_t          (state  [d_k, d_v])
    o_t = q_t @ S_t

with per-step scalar decay ``a_t = exp(log_a_t) <= 1``:
  * Mamba2 (SSD): q=C, k=B, v=dt*x, log_a = -dt*exp(A_log)   (d_k=N, d_v=P)
  * mLSTM:        q,k,v projections, log_a = log sigmoid(f~), v scaled by
                  the input gate; a normalizer channel is appended to v so
                  h = (q S)/max(|q n|, 1) comes out of the same scan.

:func:`gla_chunked` evaluates the recurrence chunk-parallel (intra-chunk
attention-like matmuls + inter-chunk state carry), which is the MXU-
friendly form; ``repro.kernels.gla`` is the Pallas TPU kernel of the same
math and ``repro/kernels/gla/ref.py`` the step-by-step oracle.

Faithfulness notes (DESIGN.md §8): mLSTM uses sigmoid (not exponential)
input gating — the normalized-GLA simplification — so the chunked form is
exact; sLSTM keeps the paper's exponential gating with the m_t stabilizer
state and runs as a true sequential scan.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Dtypes, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["gla_chunked", "gla_step", "mamba2_init", "mamba2_apply",
           "mlstm_init", "mlstm_apply", "slstm_init", "slstm_apply"]


# ---------------------------------------------------------------------------
# chunked GLA core
# ---------------------------------------------------------------------------

def gla_chunked(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                chunk: int, initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """q,k: [B,H,S,dk]; v: [B,H,S,dv]; log_a: [B,H,S] (<= 0).

    Returns (o [B,H,S,dv], final_state [B,H,dk,dv] float32).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))

    qc = q.reshape(B, H, nc, L, dk)
    kc = k.reshape(B, H, nc, L, dk)
    vc = v.reshape(B, H, nc, L, dv)
    g = jnp.cumsum(log_a.reshape(B, H, nc, L).astype(jnp.float32), axis=-1)

    S0 = (jnp.zeros((B, H, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    ii = jnp.arange(L)
    causal = ii[:, None] >= ii[None, :]

    @jax.checkpoint
    @jax.named_scope("gla_chunk")
    def chunk_step(state, inputs):
        qb, kb, vb, gb = inputs                        # [B,H,L,*], gb [B,H,L]
        # intra-chunk
        scores = jnp.einsum("bhid,bhjd->bhij", qb, kb).astype(jnp.float32)
        decay = jnp.exp(gb[..., :, None] - gb[..., None, :])
        scores = jnp.where(causal, scores * decay, 0.0)
        o = jnp.einsum("bhij,bhjd->bhid", scores.astype(vb.dtype), vb)
        # inter-chunk
        o = o + (jnp.exp(gb)[..., None]
                 * jnp.einsum("bhid,bhdv->bhiv", qb.astype(jnp.float32),
                              state)).astype(o.dtype)
        # state update
        w = jnp.exp(gb[..., -1:] - gb)                 # [B,H,L]
        ks = kb.astype(jnp.float32) * w[..., None]
        state = (jnp.exp(gb[..., -1])[..., None, None] * state
                 + jnp.einsum("bhld,bhlv->bhdv", ks, vb.astype(jnp.float32)))
        return state, o

    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(g, 2, 0))
    final, oc = jax.lax.scan(chunk_step, S0, xs)
    o = jnp.moveaxis(oc, 0, 2).reshape(B, H, nc * L, dv)[:, :, :S]
    return o, final


def gla_step(q, k, v, log_a, state):
    """One decode step.  q,k: [B,H,dk]; v: [B,H,dv]; log_a: [B,H];
    state: [B,H,dk,dv] -> (o [B,H,dv], new state)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    o = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return o.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba2_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    D = cfg.d_model
    d_inner, H, N, P_ = _mamba_dims(cfg)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], D, 2 * d_inner + 2 * N + H, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   * 0.1).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_inner, pd),
        "out_proj": dense_init(ks[2], d_inner, D, pd),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """x: [B,S,C]; w: [K,C] depthwise causal conv.  Returns (y, new_state)
    where state is the trailing K-1 inputs."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(x[:, :0])
    return y + b.astype(x.dtype), new_state


def mamba2_apply(p, x: jax.Array, cfg: ModelConfig,
                 state: Optional[Dict] = None, shard=lambda x, k: x
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B,S,D].  ``state`` = {"conv": [B,K-1,C], "ssm": [B,H,N,P]}."""
    B, S, D = x.shape
    d_inner, H, N, P_ = _mamba_dims(cfg)
    zxbcdt = dense(p["in_proj"], x)
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        None if state is None else state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # [B,S,H]
    A = -jnp.exp(p["A_log"])                                        # [H]
    log_a = (dt * A).transpose(0, 2, 1)                              # [B,H,S]

    xh = shard(xin.reshape(B, S, H, P_).transpose(0, 2, 1, 3),
               "heads_bhs")                                          # [B,H,S,P]
    v = xh * dt.transpose(0, 2, 1)[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(Bc[:, None], (B, H, S, N)).astype(xh.dtype)
    q = jnp.broadcast_to(Cc[:, None], (B, H, S, N)).astype(xh.dtype)

    if state is None:
        o, final = gla_chunked(q, k, v, log_a, cfg.gla_chunk)
        new_state = None
    elif S == 1:
        o, final = gla_step(q[:, :, 0], k[:, :, 0], v[:, :, 0], log_a[..., 0],
                            state["ssm"])
        o = o[:, :, None]
        new_state = {"conv": conv_state, "ssm": final}
    else:
        o, final = gla_chunked(q, k, v, log_a, cfg.gla_chunk,
                               initial_state=state["ssm"])
        new_state = {"conv": conv_state, "ssm": final}

    o = o + p["D"].astype(o.dtype)[None, :, None, None] * xh
    y = o.transpose(0, 2, 1, 3).reshape(B, S, d_inner)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, new_state


def mamba2_state_spec(cfg: ModelConfig, batch: int):
    d_inner, H, N, P_ = _mamba_dims(cfg)
    conv_ch = d_inner + 2 * N
    dt = Dtypes.compute(cfg)
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch), dt),
            "ssm": jax.ShapeDtypeStruct((batch, H, N, P_), jnp.float32)}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = cfg.num_heads
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], D, 2 * d_inner, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32)
                   * 0.1).astype(pd),
        "conv_b": jnp.zeros((d_inner,), pd),
        "wq": dense_init(ks[2], d_inner, d_inner, pd),
        "wk": dense_init(ks[3], d_inner, d_inner, pd),
        "wv": dense_init(ks[4], d_inner, d_inner, pd),
        "w_gates": dense_init(ks[5], d_inner, 2 * H, pd),   # i~, f~ per head
        "norm": rmsnorm_init(d_inner, pd),
        "down_proj": dense_init(ks[6], d_inner, D, pd),
    }


def mlstm_apply(p, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None, shard=lambda x, k: x
                ) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, D = x.shape
    d_inner = cfg.ssm_expand * D
    H = cfg.num_heads
    dh = d_inner // H
    u, z = jnp.split(dense(p["up_proj"], x), 2, axis=-1)
    c, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"],
                                 None if state is None else state["conv"])
    c = jax.nn.silu(c)

    def heads(t):
        return shard(t.reshape(B, S, H, dh).transpose(0, 2, 1, 3),
                     "heads_bhs")

    q = heads(dense(p["wq"], c)) * (dh ** -0.5)
    k = heads(dense(p["wk"], c)) * (dh ** -0.5)
    v = heads(dense(p["wv"], u))
    gates = dense(p["w_gates"], u).astype(jnp.float32)       # [B,S,2H]
    i_g = jax.nn.sigmoid(gates[..., :H]).transpose(0, 2, 1)  # [B,H,S]
    log_f = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    # normalizer as a separate dv=1 scan: keeping it as a concatenated
    # channel makes dv = dh+1, which breaks model-axis divisibility of
    # every value/state/output tensor (measured +20 GB temp on xlstm
    # train_4k from the resulting SPMD full-remat copies)
    v_num = shard(v * i_g[..., None].astype(v.dtype), "heads_bhs")
    v_den = i_g[..., None].astype(v.dtype)

    if state is None:
        o_num, fin_n = gla_chunked(q, k, v_num, log_f, cfg.gla_chunk)
        o_den, fin_d = gla_chunked(q, k, v_den, log_f, cfg.gla_chunk)
        new_state = None
    elif S == 1:
        o_num, fin_n = gla_step(q[:, :, 0], k[:, :, 0], v_num[:, :, 0],
                                log_f[..., 0], state["ssm"][..., :dh])
        o_den, fin_d = gla_step(q[:, :, 0], k[:, :, 0], v_den[:, :, 0],
                                log_f[..., 0], state["ssm"][..., dh:])
        o_num, o_den = o_num[:, :, None], o_den[:, :, None]
        new_state = {"conv": conv_state,
                     "ssm": jnp.concatenate([fin_n, fin_d], axis=-1)}
    else:
        o_num, fin_n = gla_chunked(q, k, v_num, log_f, cfg.gla_chunk,
                                   initial_state=state["ssm"][..., :dh])
        o_den, fin_d = gla_chunked(q, k, v_den, log_f, cfg.gla_chunk,
                                   initial_state=state["ssm"][..., dh:])
        new_state = {"conv": conv_state,
                     "ssm": jnp.concatenate([fin_n, fin_d], axis=-1)}

    num, den = o_num, o_den[..., 0]
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None].astype(num.dtype)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d_inner)
    h = rmsnorm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return dense(p["down_proj"], h), new_state


def mlstm_state_spec(cfg: ModelConfig, batch: int):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    dh = d_inner // H
    dt = Dtypes.compute(cfg)
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, d_inner), dt),
            "ssm": jax.ShapeDtypeStruct((batch, H, dh, dh + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (scalar LSTM with exponential gating + stabilizer)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 2)
    return {"w_in": dense_init(ks[0], D, 4 * D, pd),
            "r": (jax.random.normal(ks[1], (4, D), jnp.float32) * 0.02).astype(pd),
            "out_norm": rmsnorm_init(D, pd)}


def _slstm_cell(p, zifo, h_prev, c_prev, n_prev, m_prev):
    """One step.  zifo: [B, 4D] pre-activations (input part)."""
    D = h_prev.shape[-1]
    r = p["r"].astype(jnp.float32)
    hp = h_prev.astype(jnp.float32)
    z_, i_, f_, o_ = jnp.split(zifo.astype(jnp.float32), 4, axis=-1)
    z_ = z_ + r[0] * hp
    i_ = i_ + r[1] * hp
    f_ = f_ + r[2] * hp
    o_ = o_ + r[3] * hp
    m = jnp.maximum(f_ + m_prev, i_)
    i_g = jnp.exp(i_ - m)
    f_g = jnp.exp(f_ + m_prev - m)
    c = f_g * c_prev + i_g * jnp.tanh(z_)
    n = f_g * n_prev + i_g
    h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1.0)
    return h, c, n, m


def slstm_apply(p, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, D = x.shape
    zifo = dense(p["w_in"], x)                                # [B,S,4D]
    if state is None:
        zero = jnp.zeros((B, D), jnp.float32)
        carry0 = (zero, zero, zero, zero)
    else:
        carry0 = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, zt):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(p, zt, h, c, n, m)
        return (h, c, n, m), h

    # time chunking: the inner scan is checkpointed so AD stores residuals
    # per *chunk*, not per step (S x [B,4D] f32 residuals otherwise)
    CH = 128
    if S % CH == 0 and S > CH:
        zc = jnp.moveaxis(zifo, 1, 0).reshape(S // CH, CH, B, 4 * D)

        @jax.checkpoint
        def chunk(carry, zch):
            return jax.lax.scan(step, carry, zch)

        (h, c, n, m), hs = jax.lax.scan(chunk, carry0, zc)
        hs = hs.reshape(S, B, D)
    else:
        (h, c, n, m), hs = jax.lax.scan(step, carry0,
                                        jnp.moveaxis(zifo, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    new_state = None if state is None else {"h": h, "c": c, "n": n, "m": m}
    return y, new_state


def slstm_state_spec(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    s = jax.ShapeDtypeStruct((batch, D), jnp.float32)
    return {"h": s, "c": s, "n": s, "m": s}
