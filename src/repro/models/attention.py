"""Attention blocks: GQA (with RoPE / M-RoPE) and MLA (DeepSeek-V2/V3,
Kimi-K2 family), each with a training/prefill path and a KV-cache decode
path.

Long-sequence prefill uses a blockwise online-softmax attention
(``flash``-style double ``lax.scan``) so the full [S, S] score matrix is
never materialised — required for the 32k-prefill dry-run cells to fit
HBM.  The Pallas kernel in ``repro.kernels.attention`` implements the same
math for TPU; this file's jnp path is the oracle and the GSPMD lowering
used by the dry-run.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Dtypes, dense, dense_init, mrope, rmsnorm, rmsnorm_init, rope

__all__ = ["gqa_init", "gqa_apply", "mla_init", "mla_apply", "attention"]

_NEG = -1e30


def _apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope_kind == "rope":
        return rope(x, positions, cfg.rope_theta)
    if cfg.rope_kind == "mrope":
        return mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return x


# ---------------------------------------------------------------------------
# core attention math (q: [B, S, H, dh]; k/v: [B, T, KV, dh])
# ---------------------------------------------------------------------------

def _plain_attention(q, k, v, *, causal: bool, q_offset, scale: float,
                     kv_len: Optional[jax.Array] = None) -> jax.Array:
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    qg = q.reshape(B, S, KV, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    ti = jnp.arange(T)
    if causal:
        si = jnp.arange(S) + q_offset
        scores = jnp.where(ti[None, :] <= si[:, None], scores, _NEG)
    if kv_len is not None:
        scores = jnp.where(ti < kv_len, scores, _NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, dv)


def _blockwise_attention(q, k, v, *, causal: bool, q_offset, scale: float,
                         block_q: int, block_kv: int,
                         kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention; O(block_q x block_kv) live scores."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    bq, bk = min(block_q, S), min(block_kv, T)
    nq, nk = -(-S // bq), -(-T // bk)
    Sp, Tp = nq * bq, nk * bk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, bq, KV, G, dh)
    kb = kp.reshape(B, nk, bk, KV, dh)
    vb = vp.reshape(B, nk, bk, KV, dv)

    tvalid = jnp.arange(Tp).reshape(nk, bk) < (T if kv_len is None else kv_len)

    # Each (q-block x kv-block) tile is checkpointed: its backward
    # recomputes scores/probabilities from (q, k) instead of stacking
    # per-step residuals across both scans — without this the saved
    # masks/probs are O(S*T/blocks) per layer and dominate HBM.
    @jax.checkpoint
    @jax.named_scope("flash_tile")
    def kv_tile(acc, qblk, kblk, vblk, si, ki):
        m, l, o = acc
        s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk)
        s = s.astype(jnp.float32) * scale                    # [B,KV,G,bq,bk]
        mask = tvalid[ki][None, :]
        if causal:
            ti = ki * bk + jnp.arange(bk)
            mask = mask & (ti[None, :] <= si[:, None])
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        return m_new, l_new, o_new

    def q_block(carry, qi):
        qblk = qb[:, qi]                                     # [B,bq,KV,G,dh]
        si = qi * bq + jnp.arange(bq) + q_offset

        def kv_block(acc, ki):
            return kv_tile(acc, qblk, kb[:, ki], vb[:, ki], si, ki), None

        init = (jnp.full((B, KV, G, bq), _NEG, jnp.float32),
                jnp.zeros((B, KV, G, bq), jnp.float32),
                jnp.zeros((B, KV, G, bq, dv), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, o.astype(q.dtype)                      # [B,KV,G,bq,dv]

    _, outs = jax.lax.scan(q_block, (), jnp.arange(nq))      # [nq,B,KV,G,bq,dv]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)  # B,nq,bq,KV,G,dv
    return out.reshape(B, Sp, H, dv)[:, :S]


def attention(q, k, v, cfg: ModelConfig, *, causal: bool = True, q_offset=0,
              scale: Optional[float] = None,
              kv_len: Optional[jax.Array] = None) -> jax.Array:
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    use_blockwise = (q.shape[1] >= cfg.blockwise_attn_threshold
                     or k.shape[1] >= cfg.blockwise_attn_threshold)
    if use_blockwise and q.shape[1] > 1:
        return _blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                                    scale=scale, block_q=cfg.attn_block_q,
                                    block_kv=cfg.attn_block_kv, kv_len=kv_len)
    return _plain_attention(q, k, v, causal=causal, q_offset=q_offset,
                            scale=scale, kv_len=kv_len)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    H, KV, dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], D, H * dh, pd),
            "wk": dense_init(ks[1], D, KV * dh, pd),
            "wv": dense_init(ks[2], D, KV * dh, pd),
            "wo": dense_init(ks[3], H * dh, D, pd)}


def gqa_apply(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              cache: Optional[Dict] = None, cache_pos=None,
              shard=lambda x, k: x) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B, S, D].  With a cache: append K/V at ``cache_pos`` and attend
    over the filled prefix (decode/prefill-with-cache)."""
    B, S, D = x.shape
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = shard(dense(p["wq"], x).reshape(B, S, H, dh), "heads")
    k = shard(dense(p["wk"], x).reshape(B, S, KV, dh), "heads")
    v = shard(dense(p["wv"], x).reshape(B, S, KV, dh), "heads")
    q = _apply_rope(cfg, q, positions)
    k = _apply_rope(cfg, k, positions)

    if cache is None:
        out = attention(q, k, v, cfg, causal=True, q_offset=0)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_pos, 0, 0))
        kv_len = cache_pos + S
        out = attention(q, ck.astype(q.dtype), cv.astype(q.dtype), cfg,
                        causal=True, q_offset=cache_pos, kv_len=kv_len)
        new_cache = {"k": ck, "v": cv}
    return dense(p["wo"], out.reshape(B, S, H * dh)), new_cache


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    dt = Dtypes.compute(cfg)
    return {"k": jax.ShapeDtypeStruct(shape, dt),
            "v": jax.ShapeDtypeStruct(shape, dt)}


# ---------------------------------------------------------------------------
# MLA block (multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Dict:
    pd = Dtypes.param(cfg)
    D, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p: Dict = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], D, cfg.q_lora_rank, pd)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, pd)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, H * (dn + dr), pd)
    else:
        p["wq"] = dense_init(ks[0], D, H * (dn + dr), pd)
    p["wkv_a"] = dense_init(ks[2], D, kl + dr, pd)      # -> [c_kv | k_rope]
    p["kv_norm"] = rmsnorm_init(kl, pd)
    p["wk_b"] = dense_init(ks[3], kl, H * dn, pd)
    p["wv_b"] = dense_init(ks[4], kl, H * dv, pd)
    p["wo"] = dense_init(ks[5], H * dv, D, pd)
    return p


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x),
                                     cfg.norm_eps))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = dense(p["wkv_a"], x)
    c_kv = rmsnorm(p["kv_norm"], kv[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv[..., cfg.kv_lora_rank:].reshape(B, S, 1, dr), positions,
                  cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              cache: Optional[Dict] = None, cache_pos=None,
              shard=lambda x, k: x) -> Tuple[jax.Array, Optional[Dict]]:
    """MLA with compressed-latent cache.

    Train/prefill: decompress K/V per head and run blockwise attention.
    Decode (S small): *absorbed* form — queries are pulled into the latent
    space (q~ = q_nope @ W_kb) so attention runs against the [T, kv_lora]
    latent cache directly; this is MLA's serving advantage and is what the
    decode dry-run cells measure.
    """
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    q_nope = shard(q_nope, "heads")

    if cache is not None:
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, cache_pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        kv_len = cache_pos + S
    else:
        cc, cr, new_cache, kv_len = c_kv, k_rope, None, None

    wk_b = p["wk_b"]["w"].astype(x.dtype).reshape(kl, H, dn)
    wv_b = p["wv_b"]["w"].astype(x.dtype).reshape(kl, H, dv)

    if S == 1 and cache is not None:
        # absorbed decode: scores over the latent cache, no K/V expansion
        q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, wk_b)        # [B,1,H,kl]
        s_lat = jnp.einsum("bshl,btl->bhst", q_lat, cc.astype(x.dtype))
        s_pe = jnp.einsum("bshd,btd->bhst", q_rope, cr.astype(x.dtype))
        s = (s_lat + s_pe).astype(jnp.float32) * scale
        ti = jnp.arange(cc.shape[1])
        s = jnp.where(ti[None, None, None, :] < kv_len, s, _NEG)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btl->bshl", w, cc.astype(x.dtype))
        out = jnp.einsum("bshl,lhd->bshd", o_lat, wv_b)           # [B,1,H,dv]
    else:
        T = cc.shape[1]
        k_nope = shard(jnp.einsum("btl,lhd->bthd", cc.astype(x.dtype), wk_b),
                       "heads")
        v = shard(jnp.einsum("btl,lhd->bthd", cc.astype(x.dtype), wv_b),
                  "heads")
        k_pe = jnp.broadcast_to(cr.astype(x.dtype)[:, :, None, :], (B, T, H, dr))
        k = jnp.concatenate([k_nope, k_pe], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention(q, k, v, cfg, causal=True,
                        q_offset=0 if cache is None else cache_pos,
                        scale=scale, kv_len=kv_len)
    return dense(p["wo"], out.reshape(B, S, H * dv)), new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dt = Dtypes.compute(cfg)
    return {"c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_head_dim), dt)}
