"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense GQA transformers, MLA+MoE (DeepSeek/Kimi),
SSM (xLSTM), hybrid Mamba2+shared-attention (Zamba2), audio (MusicGen) and
VLM (Qwen2-VL) backbones.  Per-layer heterogeneity is expressed as a
*periodic block pattern* so the layer stack lowers to a small number of
``lax.scan`` segments (compile time O(1) in depth).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

__all__ = ["ModelConfig", "segments"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                   # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "swiglu"                 # "swiglu" | "gelu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- attention ---
    attn_kind: str = "gqa"              # "gqa" | "mla"
    rope_kind: str = "rope"             # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # qwen2-vl (half-dims)
    # MLA dims (DeepSeek-V2/V3, Kimi-K2)
    q_lora_rank: int = 0                # 0 -> no q compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- MoE ---
    num_experts: int = 0                # 0 -> dense FFN
    num_shared_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    first_dense_layers: int = 0         # leading layers use dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # --- SSM / recurrent blocks ---
    ssm_state: int = 64                 # mamba2 state size N
    ssm_expand: int = 2
    ssm_head_dim: int = 64              # mamba2 P (per-head channel dim)
    ssm_conv: int = 4
    gla_chunk: int = 256                # chunk length for the GLA/SSD scan

    # --- layer pattern ---
    #   "attn"        uniform attention+FFN stack
    #   custom periodic pattern: tuple of block kinds, tiled over depth.
    #   kinds: "attn", "mlstm", "slstm", "mamba2", "shared_attn"
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- modality frontends (stubs per assignment) ---
    frontend: str = "none"              # "none" | "audio" | "vision"
    num_codebooks: int = 1              # musicgen EnCodec codebooks

    # --- numerics ---
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"

    # --- execution knobs (the self-tuned configuration parameters) ---
    attn_block_q: int = 512             # blockwise-attention tile sizes
    attn_block_kv: int = 1024
    blockwise_attn_threshold: int = 8192  # use online-softmax attn if S >=
    remat: str = "none"                 # "none" | "full" | "dots"
    scan_layers: bool = True
    moe_expert_tp: bool = False         # serving expert-TP (see moe.py)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and not self.d_ff_expert:
            object.__setattr__(self, "d_ff_expert", self.d_ff)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kinds(self) -> List[str]:
        """Block kind for every layer index (pattern tiled over depth)."""
        pat = self.block_pattern
        kinds = [pat[i % len(pat)] for i in range(self.num_layers)]
        return kinds


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of layers lowered as one ``lax.scan`` over identical blocks."""
    kinds: Tuple[str, ...]   # block kinds inside one super-block
    repeats: int             # scan length
    start_layer: int         # absolute index of first layer (for MoE gating)


def segments(cfg: ModelConfig) -> List[Segment]:
    """Split the depth into scannable segments.

    * MoE models: ``first_dense_layers`` leading attention layers form one
      segment, the remaining MoE layers another.
    * patterned models: the pattern repeats ``num_layers // len(pattern)``
      times; a non-multiple tail becomes a trailing segment.
    """
    segs: List[Segment] = []
    kinds = cfg.layer_kinds()
    if cfg.is_moe and cfg.first_dense_layers > 0:
        fd = cfg.first_dense_layers
        segs.append(Segment(kinds=("attn_dense",), repeats=fd, start_layer=0))
        segs.append(Segment(kinds=("attn_moe",), repeats=cfg.num_layers - fd,
                            start_layer=fd))
        return segs
    if cfg.is_moe:
        return [Segment(kinds=("attn_moe",), repeats=cfg.num_layers, start_layer=0)]

    pat = tuple(cfg.block_pattern)
    full = cfg.num_layers // len(pat)
    tail = cfg.num_layers - full * len(pat)
    if full > 0:
        segs.append(Segment(kinds=pat, repeats=full, start_layer=0))
    if tail:
        segs.append(Segment(kinds=pat[:tail], repeats=1,
                            start_layer=full * len(pat)))
    return segs
