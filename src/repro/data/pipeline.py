"""Deterministic, shardable, checkpointable data pipeline.

``batch_at(step)`` is a pure function of (corpus seed/file, step, dp_rank,
dp_size), so (1) every data-parallel worker reads only its shard, (2)
restart after preemption is exact — the training loop checkpoint only
needs the step counter, and (3) elastic rescale (dp_size change) re-shards
the stream deterministically from the next step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticCorpus", "FileCorpus", "DataPipeline"]


class SyntheticCorpus:
    """Zipfian token stream with local structure (bigram-ish repeats) so a
    ~100M-param model shows a real learning curve on it."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 num_codebooks: int = 1):
        self.vocab_size = vocab_size
        self.seed = seed
        self.num_codebooks = num_codebooks

    def tokens_at(self, index: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, index))
        shape = (length,) if self.num_codebooks == 1 else (length,
                                                           self.num_codebooks)
        ranks = rng.zipf(1.3, size=shape)
        toks = np.minimum(ranks, self.vocab_size - 1).astype(np.int32)
        # inject repeated spans: next-token becomes predictable locally
        n_rep = max(1, length // 64)
        for r in range(n_rep):
            start = int(rng.integers(0, max(length - 16, 1)))
            span = toks[start:start + 8]
            end = min(start + 16, length)
            toks[start + 8:end] = span[:end - start - 8]
        return toks


class FileCorpus:
    """Flat binary token file (np.memmap) — the production path."""

    def __init__(self, path: str, vocab_size: int, dtype=np.int32):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size
        self.num_codebooks = 1

    def tokens_at(self, index: int, length: int) -> np.ndarray:
        n = len(self.tokens)
        start = (index * length) % max(n - length - 1, 1)
        return np.asarray(self.tokens[start:start + length], np.int32)


@dataclasses.dataclass
class DataPipeline:
    corpus: object
    seq_len: int
    global_batch: int
    dp_rank: int = 0
    dp_size: int = 1

    def __post_init__(self):
        if self.global_batch % self.dp_size:
            raise ValueError("global_batch must divide by dp_size")
        self.local_batch = self.global_batch // self.dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step: the worker's local shard of the global
        batch, with next-token labels."""
        seqs = []
        for b in range(self.local_batch):
            global_idx = (step * self.global_batch
                          + self.dp_rank * self.local_batch + b)
            seqs.append(self.corpus.tokens_at(global_idx, self.seq_len + 1))
        arr = np.stack(seqs)                          # [B, S+1(, nb)]
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # -- checkpointing --------------------------------------------------------
    def state_dict(self, step: int) -> Dict[str, int]:
        return {"step": step, "dp_rank": self.dp_rank, "dp_size": self.dp_size}

    @staticmethod
    def resume_step(state: Dict[str, int]) -> int:
        return int(state["step"])
