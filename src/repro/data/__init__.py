from .pipeline import SyntheticCorpus, FileCorpus, DataPipeline

__all__ = ["SyntheticCorpus", "FileCorpus", "DataPipeline"]
