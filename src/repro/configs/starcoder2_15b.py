"""StarCoder2-15B [dense] — GQA + RoPE code model (arXiv:2402.19173).

40L, d_model=6144, 48 heads (GQA kv=4), d_ff=24576, vocab 49152.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152, act="gelu", rope_kind="rope",
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=384, act="gelu",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
