"""Qwen2-VL-2B [vlm] — M-RoPE, dynamic-resolution ViT frontend (stubbed)
(arXiv:2409.12191).

28L, d_model=1536, 12 heads (GQA kv=2, head_dim 128), d_ff=8960,
vocab 151936.  ``input_specs`` supplies precomputed patch embeddings and
(t, h, w) position ids; M-RoPE sections (16, 24, 24) over head_dim/2.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, act="swiglu",
    rope_kind="mrope", mrope_sections=(16, 24, 24),
    frontend="vision",
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=256, act="swiglu",
    rope_kind="mrope", mrope_sections=(2, 3, 3), frontend="vision",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
