"""MusicGen-large [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284).  Backbone only: the EnCodec frontend is a stub; the
model consumes 4 codebook token streams ([B, S, 4]) summed at the
embedding, with 4 factored logit heads.

48L, d_model=2048, 32 heads (kv=32 MHA), d_ff=8192, vocab 2048/codebook.
Adaptation note: sinusoidal positions replaced by RoPE (DESIGN.md §8).
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, act="gelu", rope_kind="rope",
    frontend="audio", num_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=64, act="gelu", num_codebooks=4, frontend="audio",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
