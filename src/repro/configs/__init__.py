"""Architecture registry: one module per assigned architecture, plus the
input-shape suite and ``input_specs`` (ShapeDtypeStruct stand-ins, no
allocation) used by the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

ARCHS = (
    "xlstm-1p3b", "minitron-4b", "starcoder2-15b", "phi3-mini-3p8b",
    "granite-20b", "musicgen-large", "deepseek-v2-236b", "kimi-k2-1t-a32b",
    "qwen2-vl-2b", "zamba2-7b",
)

#: canonical ids from the assignment -> module names
_ALIASES = {
    "xlstm-1.3b": "xlstm-1p3b",
    "phi3-mini-3.8b": "phi3-mini-3p8b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: long_500k is designated sub-quadratic-only (SSM / hybrid archs).
LONG_CTX_ARCHS = ("xlstm-1p3b", "zamba2-7b")


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch).replace('-', '_')}",
                                  __package__)
    return mod.CONFIG


def exec_default(arch: str, shape: str) -> ExecConfig:
    mod = importlib.import_module(f".{canonical(arch).replace('-', '_')}",
                                  __package__)
    table = getattr(mod, "EXEC", {})
    return table.get(shape, table.get("default", ExecConfig()))


def smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch).replace('-', '_')}",
                                  __package__)
    return mod.SMOKE


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; full-attention archs skip long_500k."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            skip = shape == "long_500k" and arch not in LONG_CTX_ARCHS
            if skip and not include_skipped:
                continue
            out.append((arch, shape, skip))
    return out


def input_specs(arch: str, shape: str,
                reduced: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for (arch x shape): the dry-run stand-ins.

    train  -> {"tokens", "labels" (+"extra_embeds"/"positions" for stubs)}
    prefill-> {"tokens", ...}
    decode -> {"token", "pos"}
    (caches are built separately via models.make_cache).
    """
    cfg = reduced if reduced is not None else get(arch)
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    tok_shape: Tuple[int, ...] = (B, S)
    if cfg.num_codebooks > 1:
        tok_shape = (B, S, cfg.num_codebooks)

    out: Dict[str, Any] = {}
    if spec.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
        if spec.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct(tok_shape, i32)
        if cfg.frontend == "vision":
            # patch-embedding stub (precomputed by the frozen vision tower)
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16)
            out["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    else:  # decode
        tshape = (B,) if cfg.num_codebooks == 1 else (B, cfg.num_codebooks)
        out["token"] = jax.ShapeDtypeStruct(tshape, i32)
        out["pos"] = jax.ShapeDtypeStruct((), i32)
    return out
