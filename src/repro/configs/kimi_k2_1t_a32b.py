"""Kimi-K2-1T-A32B [moe] — trillion-param MoE, MLA attention
(arXiv:2501.kimi2; DeepSeek-V3-family dims).

61L, d_model=7168, 64 heads (MLA kv_lora=512), 384 routed experts top-8 +
1 shared, expert d_ff=2048, dense-layer d_ff=18432, vocab 163840, first
layer dense.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432, vocab_size=163840, act="swiglu",
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=384, top_k=8, num_shared_experts=1, d_ff_expert=2048,
    first_dense_layers=1, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="kimi-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=256, act="swiglu",
    attn_kind="mla", q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    num_experts=16, top_k=4, num_shared_experts=1, d_ff_expert=32,
    first_dense_layers=1,
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots", fsdp=True, optim_dtype="bfloat16"),
    "decode_32k": ExecConfig(remat="none", fsdp=False, moe_expert_tp=True),
    "long_500k": ExecConfig(remat="none", fsdp=False, moe_expert_tp=True),
    "train_4k": ExecConfig(remat="full", fsdp=True, optim_dtype="bfloat16",
                           seq_shard_activations=True),
}
