"""Granite-20B-code [dense] — MQA (kv=1) llama-arch (arXiv:2405.04324).

52L, d_model=6144, 48 heads (GQA kv=1 -> MQA), d_ff=24576, vocab 49152.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="granite-20b",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, act="gelu", rope_kind="rope",
)

SMOKE = ModelConfig(
    name="granite-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=1,
    d_ff=512, vocab_size=384, act="gelu",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
