"""Minitron-4B [dense] — pruned Nemotron (arXiv:2407.14679).

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab 256000.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000, act="swiglu", rope_kind="rope",
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=288, vocab_size=512, act="swiglu",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
