"""Zamba2-7B [hybrid] — Mamba2 backbone + shared attention block
(arXiv:2411.15242).

81L, d_model=3584, ssm_state=64 (d_inner 7168, 112 SSD heads), shared
attention block (32 heads, kv=32) + MLP (d_ff=14336) applied every 6th
layer with shared weights (per-occurrence LoRA omitted; DESIGN.md §8).
81 = 13 x (5 mamba2 + shared_attn) + 3 trailing mamba2 layers.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, act="swiglu",
    block_pattern=("mamba2",) * 5 + ("shared_attn",),
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, gla_chunk=256,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    num_layers=13, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=256, act="swiglu",
    block_pattern=("mamba2",) * 5 + ("shared_attn",),
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, gla_chunk=16,
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="full"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
