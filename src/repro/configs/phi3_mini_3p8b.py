"""Phi-3-mini-3.8B [dense] — RoPE + SwiGLU, MHA (kv=32) (arXiv:2404.14219).

32L, d_model=3072, 32 heads (kv=32 -> MHA), d_ff=8192, vocab 32064.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="phi3-mini-3p8b",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, act="swiglu", rope_kind="rope",
)

SMOKE = ModelConfig(
    name="phi3-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=256, act="swiglu",
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
