"""DeepSeek-V2-236B [moe] — MLA (kv_lora=512) + 2 shared / 160 routed
top-6 experts (arXiv:2405.04434).

60L, d_model=5120, 128 heads, expert d_ff=1536, dense-layer d_ff=12288,
vocab 102400, first layer dense.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400, act="swiglu",
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=160, top_k=6, num_shared_experts=2, d_ff_expert=1536,
    first_dense_layers=1, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, act="swiglu",
    attn_kind="mla", q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    num_experts=8, top_k=2, num_shared_experts=2, d_ff_expert=32,
    first_dense_layers=1,
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="dots", fsdp=True),
    "decode_32k": ExecConfig(remat="none", fsdp=False, moe_expert_tp=True),
    "long_500k": ExecConfig(remat="none", fsdp=False, moe_expert_tp=True),
    "train_4k": ExecConfig(remat="full", fsdp=True,
                           seq_shard_activations=True),
}
