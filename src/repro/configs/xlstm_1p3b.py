"""xLSTM-1.3B [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

48L, d_model=2048, 4 heads, d_ff=0 (projections live inside the xLSTM
blocks), vocab 50304.  Block ratio 7:1 mLSTM:sLSTM (xLSTM[7:1]), tiled
periodically.  Pure recurrent state -> long_500k decode is natural.
"""
from ..models.config import ModelConfig
from ..sharding.rules import ExecConfig

CONFIG = ModelConfig(
    name="xlstm-1p3b",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_expand=2, ssm_conv=4, gla_chunk=256,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=128,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_expand=2, gla_chunk=16,
    param_dtype="float32", dtype="float32",
)

EXEC = {
    "default": ExecConfig(remat="full"),
    "train_4k": ExecConfig(remat="full", seq_shard_activations=True),
}
