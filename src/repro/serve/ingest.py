"""Ingest front end of the streaming tuning service.

This is the first layer of the serving stack (``ingest -> scheduler ->
tick engine -> verdicts``, see ``serve.tuning``): everything that happens
to a job's samples BEFORE they reach the device-resident matcher lives
here, so the tick engine only ever sees clean, causally-filtered chunks.

* :class:`BoundedBuffer` — the per-job sample queue.  Monitoring agents
  push at their own cadence while the service drains at tick rate; an
  unbounded queue would let one stalled tick loop (or one runaway agent)
  grow host memory without limit.  ``policy="reject"`` raises
  :class:`BackpressureError` at the producer (the MapReduce-side agent
  retries next beat), ``policy="drop_oldest"`` sheds the oldest buffered
  samples instead (the matcher tolerates a gapped prefix far better than
  the cluster tolerates a blocked agent).  Dropped samples are counted.
* :class:`TraceLog` — append-only on-disk capture of every ingested
  chunk, rotated by segment size and segment count.  The paper's offline
  pipeline profiles jobs and stores their series in the reference DB;
  the trace log is how a *serving* deployment gets those series — replay
  yesterday's accepted traces into ``AutoTuner.profile`` instead of
  re-running instrumented jobs.  Persistence reuses the reference DB's
  atomic tmp+rename writers (``core.database``), so a crashed service
  never leaves a torn segment.
* :class:`IngestFront` — per-job composition of the above plus the
  causal streaming Chebyshev filter (``denoise=True``) and heartbeat
  stamping: every push beats a ``runtime.fault.HeartbeatTracker`` and
  feeds a ``runtime.fault.StragglerDetector`` with the observed
  inter-push gaps, which is what lets the scheduler layer evict a
  stalled job's slot (``TuningService.sweep_stalled``) and flag jobs
  whose monitoring agent has degraded.

The filter is applied at *drain* time on the concatenated chunk — the
same call structure the monolithic service used — so layering changes
no numerics: a drained chunk is bit-identical to what the old
``tick()`` computed inline.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional

import numpy as np

from ..core.database import atomic_write_json, atomic_write_npz
from ..core.filters import StreamingFilter
from ..runtime.fault import HeartbeatTracker, StragglerDetector

__all__ = ["BackpressureError", "BoundedBuffer", "TraceLog", "IngestFront"]


class BackpressureError(RuntimeError):
    """Raised by a full ``policy="reject"`` :class:`BoundedBuffer`."""


class BoundedBuffer:
    """Bounded per-job sample queue between the push side and the tick.

    ``limit`` bounds the number of *samples* (not chunks) buffered;
    ``None`` means unbounded (the pre-refactor behavior).  On overflow
    ``policy="reject"`` refuses the whole push with
    :class:`BackpressureError` — nothing is partially enqueued, so the
    producer can retry the identical chunk — while ``"drop_oldest"``
    sheds buffered samples from the front until the new chunk fits
    (``dropped`` counts every sample lost this way).

    Counter invariant (conservation): ``total_in`` counts every sample
    *accepted* into the buffer (pre-shed size, including the samples a
    ``drop_oldest`` shed immediately discards), so at any quiescent point
    ``total_in == drained-so-far + len(buffer) + dropped``.
    """

    def __init__(self, limit: Optional[int] = None,
                 policy: str = "reject") -> None:
        if policy not in ("reject", "drop_oldest"):
            raise ValueError(f"unknown backpressure policy {policy!r}")
        if limit is not None and limit < 1:
            raise ValueError("queue limit must be >= 1 (or None)")
        self.limit = limit
        self.policy = policy
        self.dropped = 0
        self.total_in = 0
        self._chunks: Deque[np.ndarray] = collections.deque()
        self._pending = 0

    def __len__(self) -> int:
        return self._pending

    def append(self, samples: np.ndarray) -> None:
        s = np.asarray(samples, np.float32).reshape(-1)
        if not s.shape[0]:
            return
        # Count the ORIGINAL push size before any overflow truncation
        # below rebinds ``s`` — counting after the `s = s[-limit:]` shed
        # undercounted total_in and broke the conservation invariant.
        pushed = s.shape[0]
        if self.limit is not None and self._pending + s.shape[0] > self.limit:
            if self.policy == "reject":
                raise BackpressureError(
                    f"buffer full ({self._pending}/{self.limit} samples "
                    f"pending); tick() the service or slow the producer")
            if s.shape[0] >= self.limit:      # chunk alone overflows
                self.dropped += self._pending + s.shape[0] - self.limit
                self._chunks.clear()
                self._pending = 0
                s = s[-self.limit:]
            else:
                while self._pending + s.shape[0] > self.limit:
                    head = self._chunks[0]
                    need = self._pending + s.shape[0] - self.limit
                    if head.shape[0] <= need:
                        self._chunks.popleft()
                        self._pending -= head.shape[0]
                        self.dropped += head.shape[0]
                    else:
                        self._chunks[0] = head[need:]
                        self._pending -= need
                        self.dropped += need
        self._chunks.append(s)
        self._pending += s.shape[0]
        self.total_in += pushed

    def drain(self) -> Optional[np.ndarray]:
        """All buffered samples as one chunk (None when empty)."""
        if not self._pending:
            return None
        out = self._chunks.popleft() if len(self._chunks) == 1 \
            else np.concatenate(self._chunks)
        self._chunks.clear()
        self._pending = 0
        return out


class TraceLog:
    """Size-rotated on-disk capture of ingested chunks.

    Chunks accumulate in memory and flush to ``seg-<n>.npz`` once
    ``max_segment_bytes`` of float32 samples are pending (or on an
    explicit :meth:`flush`); only the newest ``max_segments`` segment
    files are kept.  A ``trace_index.json`` manifest records the live
    segment names.  Writes are atomic (tmp+rename via
    ``core.database``), so readers — and a service restarted mid-write —
    never observe a torn file.
    """

    def __init__(self, path: str, *, max_segment_bytes: int = 1 << 20,
                 max_segments: int = 8) -> None:
        import os
        if max_segment_bytes < 4 or max_segments < 1:
            raise ValueError("rotation limits must be positive")
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.max_segment_bytes = max_segment_bytes
        self.max_segments = max_segments
        self._pending: List[tuple] = []        # (seq, job_id, chunk)
        self._pending_bytes = 0
        self._seq = 0
        self._segments: List[str] = []

    def append(self, job_id: str, samples: np.ndarray) -> None:
        s = np.asarray(samples, np.float32).reshape(-1)
        if not s.shape[0]:
            return
        self._pending.append((self._seq, job_id, s))
        self._seq += 1
        self._pending_bytes += 4 * s.shape[0]
        if self._pending_bytes >= self.max_segment_bytes:
            self.flush()

    def flush(self) -> None:
        import os
        if not self._pending:
            return
        name = f"seg-{self._pending[0][0]:08d}.npz"
        arrays = {f"c{seq:08d}__{job_id}": chunk
                  for seq, job_id, chunk in self._pending}
        atomic_write_npz(self.path, name, arrays)
        self._pending = []
        self._pending_bytes = 0
        self._segments.append(name)
        while len(self._segments) > self.max_segments:     # rotate
            old = self._segments.pop(0)
            try:
                os.unlink(os.path.join(self.path, old))
            except FileNotFoundError:
                pass
        atomic_write_json(self.path, "trace_index.json",
                          {"version": 1, "segments": self._segments})

    def segments(self) -> List[str]:
        return list(self._segments)

    def read_job(self, job_id: str) -> np.ndarray:
        """Concatenated retained samples of one job, ingest order (the
        replay path into ``AutoTuner.profile``).  Pending un-flushed
        chunks are included."""
        import os
        parts: List[tuple] = []
        for seg in self._segments:
            with np.load(os.path.join(self.path, seg)) as z:
                for key in z.files:
                    seq, _, jid = key.partition("__")
                    if jid == job_id:
                        parts.append((int(seq[1:]), z[key]))
        for seq, jid, chunk in self._pending:
            if jid == job_id:
                parts.append((seq, chunk))
        if not parts:
            return np.zeros((0,), np.float32)
        return np.concatenate([c for _, c in sorted(parts,
                                                    key=lambda p: p[0])])


class _JobIngest:
    """Per-job ingest state: queue (+ optional variance queue) + causal
    filter."""

    __slots__ = ("buffer", "vbuffer", "filt", "pushed")

    def __init__(self, buffer: BoundedBuffer,
                 filt: Optional[StreamingFilter],
                 vbuffer: Optional[BoundedBuffer] = None) -> None:
        self.buffer = buffer
        self.vbuffer = vbuffer
        self.filt = filt
        self.pushed = 0


class IngestFront:
    """Routes pushes into per-job bounded queues, stamps heartbeats, and
    hands the tick engine causally-filtered chunks on drain.

    ``track_variance=True`` adds a per-job *variance* queue riding in
    lockstep with the sample queue (same limit/policy, identical chunk
    sizes, so ``drop_oldest`` sheds both by the same counts and
    ``reject`` raises before either mutates): :meth:`push` then accepts
    optional per-sample measurement variances and
    ``drain(with_variance=True)`` returns an aligned ``(chunk, vchunk)``
    pair.  Samples pushed *without* an explicit variance get a default at
    drain time: the squared causal-filter residual ``(raw - filtered)^2``
    when ``denoise=True`` (the filter's own estimate of per-sample
    measurement noise), else 0.0 — so exact pushes stay exact.
    """

    def __init__(self, *, denoise: bool = False,
                 queue_limit: Optional[int] = None,
                 queue_policy: str = "reject",
                 trace: Optional[TraceLog] = None,
                 heartbeat_timeout: Optional[float] = None,
                 straggler_factor: float = 2.0,
                 track_variance: bool = False) -> None:
        BoundedBuffer(queue_limit, queue_policy)   # validate eagerly
        self.denoise = denoise
        self.queue_limit = queue_limit
        self.queue_policy = queue_policy
        self.trace = trace
        self.track_variance = track_variance
        self.heartbeats = HeartbeatTracker(timeout=heartbeat_timeout) \
            if heartbeat_timeout is not None else None
        self.stragglers = StragglerDetector(factor=straggler_factor)
        self._jobs: Dict[str, _JobIngest] = {}
        self._last_push: Dict[str, float] = {}

    def register(self, job_id: str) -> None:
        self._jobs[job_id] = _JobIngest(
            BoundedBuffer(self.queue_limit, self.queue_policy),
            StreamingFilter() if self.denoise else None,
            BoundedBuffer(self.queue_limit, self.queue_policy)
            if self.track_variance else None)

    def push(self, job_id: str, samples: np.ndarray,
             variance: Optional[np.ndarray] = None,
             now: Optional[float] = None) -> None:
        ji = self._jobs[job_id]
        s = np.asarray(samples, np.float32).reshape(-1)
        if variance is not None and ji.vbuffer is None:
            raise ValueError("per-sample variance requires "
                             "track_variance=True on the IngestFront")
        if ji.vbuffer is not None:
            # NaN marks "no variance supplied" — resolved to the causal
            # filter residual (or 0.0) at drain time, when the filtered
            # values exist.
            v = np.full((s.shape[0],), np.nan, np.float32) \
                if variance is None \
                else np.asarray(variance, np.float32).reshape(-1)
            if v.shape[0] != s.shape[0]:
                raise ValueError(f"{s.shape[0]} samples but "
                                 f"{v.shape[0]} variances")
            if np.any(v[~np.isnan(v)] < 0.0):
                raise ValueError("variances must be >= 0")
        ji.buffer.append(s)                      # may raise Backpressure
        if ji.vbuffer is not None and s.shape[0]:
            # Same pre-push pending count and same chunk length as the
            # sample buffer, so this cannot raise after buffer accepted.
            ji.vbuffer.append(v)
        ji.pushed += s.shape[0]
        if self.trace is not None and s.shape[0]:
            self.trace.append(job_id, s)
        if now is not None:
            if self.heartbeats is not None:
                self.heartbeats.beat(job_id, ji.pushed, now)
            prev = self._last_push.get(job_id)
            if prev is not None and now > prev:
                self.stragglers.record(job_id, now - prev)
            self._last_push[job_id] = now

    def has_data(self, job_id: str) -> bool:
        return len(self._jobs[job_id].buffer) > 0

    def drain(self, job_id: str, with_variance: bool = False):
        """Buffered samples as ONE causally-filtered chunk (None when
        the queue is empty) — bit-identical to filtering the same
        samples in any other push/drain grouping (the streaming filter
        is stateful and causal).

        ``with_variance=True`` (requires ``track_variance=True``)
        returns an aligned ``(chunk, vchunk)`` pair instead, with
        unsupplied variances defaulted from the filter residual."""
        ji = self._jobs[job_id]
        if with_variance and ji.vbuffer is None:
            raise ValueError("drain(with_variance=True) requires "
                             "track_variance=True on the IngestFront")
        raw = ji.buffer.drain()
        if raw is None:
            return (None, None) if with_variance else None
        chunk = ji.filt(raw) if ji.filt is not None else raw
        if ji.vbuffer is not None:
            vchunk = ji.vbuffer.drain()
            if not with_variance:
                return chunk
            resid = (raw - chunk) ** 2 if ji.filt is not None \
                else np.zeros_like(raw)
            vchunk = np.where(np.isnan(vchunk), resid, vchunk) \
                .astype(np.float32)
            return chunk, vchunk
        return (chunk, None) if with_variance else chunk

    def dropped(self, job_id: str) -> int:
        return self._jobs[job_id].buffer.dropped

    def stalled(self, now: float) -> List[str]:
        """Job ids newly declared dead by the heartbeat tracker."""
        if self.heartbeats is None:
            return []
        return [j for j in self.heartbeats.sweep(now) if j in self._jobs]

    def retire(self, job_id: str) -> None:
        self._jobs.pop(job_id)
        self._last_push.pop(job_id, None)
        if self.heartbeats is not None:
            self.heartbeats.forget(job_id)
