"""Ingest front end of the streaming tuning service.

This is the first layer of the serving stack (``ingest -> scheduler ->
tick engine -> verdicts``, see ``serve.tuning``): everything that happens
to a job's samples BEFORE they reach the device-resident matcher lives
here, so the tick engine only ever sees clean, causally-filtered chunks.

* :class:`BoundedBuffer` — the per-job sample queue.  Monitoring agents
  push at their own cadence while the service drains at tick rate; an
  unbounded queue would let one stalled tick loop (or one runaway agent)
  grow host memory without limit.  ``policy="reject"`` raises
  :class:`BackpressureError` at the producer (the MapReduce-side agent
  retries next beat), ``policy="drop_oldest"`` sheds the oldest buffered
  samples instead (the matcher tolerates a gapped prefix far better than
  the cluster tolerates a blocked agent).  Dropped samples are counted.
* :class:`TraceLog` — append-only on-disk capture of every ingested
  chunk, rotated by segment size and segment count.  The paper's offline
  pipeline profiles jobs and stores their series in the reference DB;
  the trace log is how a *serving* deployment gets those series — replay
  yesterday's accepted traces into ``AutoTuner.profile`` instead of
  re-running instrumented jobs.  Persistence reuses the reference DB's
  atomic tmp+rename writers (``core.database``), so a crashed service
  never leaves a torn segment.
* :class:`IngestFront` — per-job composition of the above plus the
  causal streaming Chebyshev filter (``denoise=True``) and heartbeat
  stamping: every push beats a ``runtime.fault.HeartbeatTracker`` and
  feeds a ``runtime.fault.StragglerDetector`` with the observed
  inter-push gaps, which is what lets the scheduler layer evict a
  stalled job's slot (``TuningService.sweep_stalled``) and flag jobs
  whose monitoring agent has degraded.

The filter is applied at *drain* time on the concatenated chunk — the
same call structure the monolithic service used — so layering changes
no numerics: a drained chunk is bit-identical to what the old
``tick()`` computed inline.
"""

from __future__ import annotations

import collections
import json
import warnings
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core.database import atomic_write_json, atomic_write_npz
from ..core.filters import StreamingFilter
from ..runtime.fault import HeartbeatTracker, StragglerDetector

__all__ = ["BackpressureError", "PoisonedSampleError", "BoundedBuffer",
           "TraceLog", "IngestFront"]


class BackpressureError(RuntimeError):
    """Raised by a full ``policy="reject"`` :class:`BoundedBuffer`."""


class PoisonedSampleError(ValueError):
    """A push carried values the matcher must never see: NaN/Inf samples
    or negative/non-finite variances.  Raised BEFORE anything is
    enqueued (the push is atomic), so the serving layer can quarantine
    the offending job while every other job's state stays untouched.
    Subclasses ``ValueError`` for callers of the pre-quarantine API."""

    def __init__(self, job_id: str, reason: str) -> None:
        super().__init__(f"job {job_id!r}: {reason}")
        self.job_id = job_id
        self.reason = reason


class BoundedBuffer:
    """Bounded per-job sample queue between the push side and the tick.

    ``limit`` bounds the number of *samples* (not chunks) buffered;
    ``None`` means unbounded (the pre-refactor behavior).  On overflow
    ``policy="reject"`` refuses the whole push with
    :class:`BackpressureError` — nothing is partially enqueued, so the
    producer can retry the identical chunk — while ``"drop_oldest"``
    sheds buffered samples from the front until the new chunk fits
    (``dropped`` counts every sample lost this way).

    Counter invariant (conservation): ``total_in`` counts every sample
    *accepted* into the buffer (pre-shed size, including the samples a
    ``drop_oldest`` shed immediately discards), so at any quiescent point
    ``total_in == drained-so-far + len(buffer) + dropped``.
    """

    def __init__(self, limit: Optional[int] = None,
                 policy: str = "reject") -> None:
        if policy not in ("reject", "drop_oldest"):
            raise ValueError(f"unknown backpressure policy {policy!r}")
        if limit is not None and limit < 1:
            raise ValueError("queue limit must be >= 1 (or None)")
        self.limit = limit
        self.policy = policy
        self.dropped = 0
        self.total_in = 0
        self._chunks: Deque[np.ndarray] = collections.deque()
        self._pending = 0

    def __len__(self) -> int:
        return self._pending

    def append(self, samples: np.ndarray) -> None:
        s = np.asarray(samples, np.float32).reshape(-1)
        if not s.shape[0]:
            return
        # Count the ORIGINAL push size before any overflow truncation
        # below rebinds ``s`` — counting after the `s = s[-limit:]` shed
        # undercounted total_in and broke the conservation invariant.
        pushed = s.shape[0]
        if self.limit is not None and self._pending + s.shape[0] > self.limit:
            if self.policy == "reject":
                raise BackpressureError(
                    f"buffer full ({self._pending}/{self.limit} samples "
                    f"pending); tick() the service or slow the producer")
            if s.shape[0] >= self.limit:      # chunk alone overflows
                self.dropped += self._pending + s.shape[0] - self.limit
                self._chunks.clear()
                self._pending = 0
                s = s[-self.limit:]
            else:
                while self._pending + s.shape[0] > self.limit:
                    head = self._chunks[0]
                    need = self._pending + s.shape[0] - self.limit
                    if head.shape[0] <= need:
                        self._chunks.popleft()
                        self._pending -= head.shape[0]
                        self.dropped += head.shape[0]
                    else:
                        self._chunks[0] = head[need:]
                        self._pending -= need
                        self.dropped += need
        self._chunks.append(s)
        self._pending += s.shape[0]
        self.total_in += pushed

    def drain(self) -> Optional[np.ndarray]:
        """All buffered samples as one chunk (None when empty)."""
        if not self._pending:
            return None
        out = self._chunks.popleft() if len(self._chunks) == 1 \
            else np.concatenate(self._chunks)
        self._chunks.clear()
        self._pending = 0
        return out


class TraceLog:
    """Size-rotated on-disk capture of ingested chunks — and the serving
    stack's write-ahead log.

    Chunks accumulate in memory and flush to ``seg-<n>.npz`` once
    ``max_segment_bytes`` of float32 samples are pending (or on an
    explicit :meth:`flush`); only the newest ``max_segments`` segment
    files are kept.  A ``trace_index.json`` manifest records the live
    segment names and the next record sequence number.  Writes are
    atomic (tmp+rename via ``core.database``), so readers — and a
    service restarted mid-write — never observe a torn file.

    WAL duties (``serve.recovery``):

    * **records carry replay context** — a chunk record can ride with
      the push's per-sample variances and heartbeat timestamp (aux
      ``v``/``t`` entries under the same sequence number), and
      :meth:`append_event` journals non-push commands (submit / tick /
      finish / evict ...) as JSON payloads, all in ONE total order.
    * **durable across restart** — a TraceLog reopened on an existing
      directory adopts the on-disk index and resumes the sequence
      counter, so a recovering process appends after the crashed
      process's last durable record instead of clobbering the journal.
    * **torn tails are data, not errors** — a segment truncated by the
      crash (or corrupted on disk) is skipped with a warning and
      counted in ``corrupt_segments``; everything before it replays.
    * **write failures degrade, never raise mid-push** — a flush that
      hits ``OSError`` (disk full, permissions yanked) keeps every
      record pending in memory, sets ``journal_degraded`` and counts
      ``journal_write_errors``; the next flush retries the identical
      segment (atomic overwrite, so a half-landed attempt is
      harmless).  ``durable_seq`` reports how far the journal is
      actually on disk — ``serve.recovery`` refuses to advance a
      checkpoint watermark past it, because records that exist only in
      this process would otherwise be double-applied or lost.
    * :meth:`prune` drops segments wholly below a snapshot watermark
      once a snapshot has made them redundant.
    """

    def __init__(self, path: str, *, max_segment_bytes: int = 1 << 20,
                 max_segments: int = 8) -> None:
        import os
        if max_segment_bytes < 4 or max_segments < 1:
            raise ValueError("rotation limits must be positive")
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.max_segment_bytes = max_segment_bytes
        self.max_segments = max_segments
        #: segments found unreadable (truncated/corrupt) — each bad file
        #: is counted once, at first encounter.
        self.corrupt_segments = 0
        #: True while flushed-but-unwritable records are held in memory
        #: only (disk write failed); clears when a flush lands.
        self.journal_degraded = False
        #: flush attempts that failed with OSError.
        self.journal_write_errors = 0
        self._bad: set = set()
        # (seq, {full_key: array}) per un-flushed record
        self._pending: List[Tuple[int, Dict[str, np.ndarray]]] = []
        self._pending_bytes = 0
        self._seq = 0
        self._segments: List[str] = []
        self._adopt_existing()

    def _adopt_existing(self) -> None:
        """Resume from an on-disk journal: adopt the indexed segments
        that still exist and continue the sequence counter past every
        durable record (legacy indexes without ``next_seq`` derive it
        from the newest readable segment's keys)."""
        import os
        idx_path = os.path.join(self.path, "trace_index.json")
        if not os.path.isfile(idx_path):
            return
        try:
            with open(idx_path) as f:
                idx = json.load(f)
            segs = [s for s in idx.get("segments", [])
                    if os.path.isfile(os.path.join(self.path, s))]
        except (OSError, ValueError):
            warnings.warn(f"unreadable trace_index.json under "
                          f"{self.path}; starting a fresh journal",
                          RuntimeWarning)
            return
        self._segments = segs
        next_seq = idx.get("next_seq")
        if next_seq is None:
            next_seq = 0
            for seg in reversed(segs):
                arrs = self._segment_arrays(seg)
                if arrs:
                    next_seq = 1 + max(int(k[1:9]) for k in arrs)
                    break
                # even an unreadable tail pins the floor via its name
                next_seq = max(next_seq, int(seg[4:12]))
        self._seq = int(next_seq)

    def _record(self, seq: int, arrays: Dict[str, np.ndarray]) -> None:
        self._pending.append((seq, arrays))
        self._pending_bytes += sum(a.nbytes for a in arrays.values())
        if self._pending_bytes >= self.max_segment_bytes:
            self.flush()

    def append(self, job_id: str, samples: np.ndarray,
               variance: Optional[np.ndarray] = None,
               now: Optional[float] = None) -> Optional[int]:
        """Journal one accepted push.  ``variance``/``now`` ride as aux
        entries under the same sequence number so a replay can re-issue
        the push exactly (probabilistic mode, heartbeat stamps).
        Returns the record's sequence number (None for empty pushes)."""
        s = np.asarray(samples, np.float32).reshape(-1)
        if not s.shape[0]:
            return None
        seq, self._seq = self._seq, self._seq + 1
        arrays = {f"c{seq:08d}__{job_id}": s}
        if variance is not None:
            arrays[f"v{seq:08d}__{job_id}"] = \
                np.asarray(variance, np.float32).reshape(-1)
        if now is not None:
            arrays[f"t{seq:08d}__{job_id}"] = \
                np.asarray([now], np.float64)
        self._record(seq, arrays)
        return seq

    def append_event(self, kind: str, payload: Dict[str, Any]) -> int:
        """Journal a non-push command (JSON payload) into the same total
        order as the chunk records — the WAL entries replay recovery
        re-executes after the snapshot watermark."""
        if "__" in kind:
            raise ValueError("event kind must not contain '__'")
        seq, self._seq = self._seq, self._seq + 1
        blob = np.frombuffer(
            json.dumps(payload, sort_keys=True).encode(), np.uint8)
        self._record(seq, {f"e{seq:08d}__{kind}": blob})
        return seq

    @property
    def next_seq(self) -> int:
        """Sequence number the NEXT record will get (== the snapshot
        watermark when taken between commands)."""
        return self._seq

    @property
    def durable_seq(self) -> int:
        """First sequence number NOT yet durable on disk.  Equals
        ``next_seq`` when everything pending has flushed; lags behind it
        while records are held in memory (including the
        ``journal_degraded`` disk-failure mode)."""
        return self._pending[0][0] if self._pending else self._seq

    def flush(self) -> None:
        import os
        if not self._pending:
            return
        name = f"seg-{self._pending[0][0]:08d}.npz"
        arrays: Dict[str, np.ndarray] = {}
        for _, recs in self._pending:
            arrays.update(recs)
        old_segments = self._segments
        try:
            atomic_write_npz(self.path, name, arrays)
            self._segments = self._segments + [name]
            drop = self._segments[:max(0, len(self._segments)
                                       - self.max_segments)]
            self._segments = self._segments[len(drop):]
            try:
                self._write_index()
            except OSError:
                self._segments = old_segments
                raise
        except OSError as e:
            # Disk refused the write: degrade to in-memory-only — the
            # records stay pending (still replayable from this process,
            # still visible to ``records()``) and the NEXT flush retries
            # the same segment name, so a half-landed attempt overwrites
            # cleanly.  Never raise mid-push.
            self.journal_write_errors += 1
            if not self.journal_degraded:
                warnings.warn(
                    f"trace journal write failed under {self.path} "
                    f"({type(e).__name__}: {e}); holding records in "
                    f"memory (journal_degraded)", RuntimeWarning)
            self.journal_degraded = True
            return
        self._pending = []
        self._pending_bytes = 0
        for old in drop:                                   # rotate
            try:
                os.unlink(os.path.join(self.path, old))
            except OSError:
                pass
        self.journal_degraded = False

    def _write_index(self) -> None:
        atomic_write_json(self.path, "trace_index.json",
                          {"version": 2, "segments": self._segments,
                           "next_seq": self._seq})

    def segments(self) -> List[str]:
        return list(self._segments)

    def _segment_arrays(self, seg: str) -> Optional[Dict[str, np.ndarray]]:
        """All entries of one segment, or None when the file is
        truncated/corrupt (counted + warned once per file) — the crash
        case the WAL must shrug off, not die on."""
        import os
        if seg in self._bad:
            return None
        try:
            with np.load(os.path.join(self.path, seg)) as z:
                return {k: np.array(z[k]) for k in z.files}
        except Exception as e:          # torn zip: BadZipFile/OSError/...
            self._bad.add(seg)
            self.corrupt_segments += 1
            warnings.warn(f"trace segment {seg} is truncated or corrupt "
                          f"({type(e).__name__}: {e}); skipping",
                          RuntimeWarning)
            return None

    def prune(self, before_seq: int) -> int:
        """Delete segments whose every record precedes ``before_seq``
        (they are covered by a snapshot); returns segments dropped."""
        import os
        keep: List[str] = []
        dropped = 0
        for i, seg in enumerate(self._segments):
            # a segment's records span [its name seq, next segment's)
            nxt = int(self._segments[i + 1][4:12]) \
                if i + 1 < len(self._segments) else self._seq
            if nxt <= before_seq:
                dropped += 1
                try:
                    os.unlink(os.path.join(self.path, seg))
                except FileNotFoundError:
                    pass
            else:
                keep.append(seg)
        if dropped:
            self._segments = keep
            self._write_index()
        return dropped

    def records(self, since: int = 0) -> List[Tuple[int, str,
                                                    Dict[str, Any]]]:
        """Every durable + pending record with ``seq >= since``, in
        sequence order: ``(seq, kind, payload)`` where pushes have kind
        ``"push"`` and payload ``{job_id, samples, variance, now}``, and
        events carry their JSON payloads under their own kind.  Corrupt
        segments are skipped (see ``corrupt_segments``)."""
        by_seq: Dict[int, Dict[str, Any]] = {}
        for seg in self._segments:
            arrs = self._segment_arrays(seg)
            if arrs:
                self._parse_into(by_seq, arrs)
        for _, recs in self._pending:
            self._parse_into(by_seq, recs)
        return [(seq, *by_seq[seq]["_rec"]) for seq in sorted(by_seq)
                if seq >= since]

    @staticmethod
    def _parse_into(by_seq: Dict[int, Dict[str, Any]],
                    arrays: Dict[str, np.ndarray]) -> None:
        for key, arr in arrays.items():
            tag, seq, rest = key[0], int(key[1:9]), key[11:]
            slot = by_seq.setdefault(seq, {})
            if tag == "e":
                slot["_rec"] = (rest, json.loads(bytes(arr).decode()))
                continue
            if "_rec" not in slot:
                slot["_rec"] = ("push", {"job_id": rest, "samples": None,
                                         "variance": None, "now": None})
            payload = slot["_rec"][1]
            if tag == "c":
                payload["samples"] = arr
            elif tag == "v":
                payload["variance"] = arr
            elif tag == "t":
                payload["now"] = float(arr[0])

    def read_job(self, job_id: str) -> np.ndarray:
        """Concatenated retained samples of one job, ingest order (the
        replay path into ``AutoTuner.profile``).  Pending un-flushed
        chunks are included; truncated/corrupt segments are skipped."""
        parts: List[tuple] = []
        for seg in self._segments:
            arrs = self._segment_arrays(seg)
            if arrs is None:
                continue
            for key, arr in arrs.items():
                seq, _, jid = key.partition("__")
                if key[0] == "c" and jid == job_id:
                    parts.append((int(seq[1:]), arr))
        for seq, recs in self._pending:
            for key, arr in recs.items():
                if key[0] == "c" and key.partition("__")[2] == job_id:
                    parts.append((seq, arr))
        if not parts:
            return np.zeros((0,), np.float32)
        return np.concatenate([c for _, c in sorted(parts,
                                                    key=lambda p: p[0])])


class _JobIngest:
    """Per-job ingest state: queue (+ optional variance queue) + causal
    filter."""

    __slots__ = ("buffer", "vbuffer", "filt", "pushed")

    def __init__(self, buffer: BoundedBuffer,
                 filt: Optional[StreamingFilter],
                 vbuffer: Optional[BoundedBuffer] = None) -> None:
        self.buffer = buffer
        self.vbuffer = vbuffer
        self.filt = filt
        self.pushed = 0


class IngestFront:
    """Routes pushes into per-job bounded queues, stamps heartbeats, and
    hands the tick engine causally-filtered chunks on drain.

    ``track_variance=True`` adds a per-job *variance* queue riding in
    lockstep with the sample queue (same limit/policy, identical chunk
    sizes, so ``drop_oldest`` sheds both by the same counts and
    ``reject`` raises before either mutates): :meth:`push` then accepts
    optional per-sample measurement variances and
    ``drain(with_variance=True)`` returns an aligned ``(chunk, vchunk)``
    pair.  Samples pushed *without* an explicit variance get a default at
    drain time: the squared causal-filter residual ``(raw - filtered)^2``
    when ``denoise=True`` (the filter's own estimate of per-sample
    measurement noise), else 0.0 — so exact pushes stay exact.
    """

    def __init__(self, *, denoise: bool = False,
                 queue_limit: Optional[int] = None,
                 queue_policy: str = "reject",
                 trace: Optional[TraceLog] = None,
                 heartbeat_timeout: Optional[float] = None,
                 straggler_factor: float = 2.0,
                 track_variance: bool = False) -> None:
        BoundedBuffer(queue_limit, queue_policy)   # validate eagerly
        self.denoise = denoise
        self.queue_limit = queue_limit
        self.queue_policy = queue_policy
        self.trace = trace
        self.track_variance = track_variance
        self.heartbeats = HeartbeatTracker(timeout=heartbeat_timeout) \
            if heartbeat_timeout is not None else None
        self.stragglers = StragglerDetector(factor=straggler_factor)
        self._jobs: Dict[str, _JobIngest] = {}
        self._last_push: Dict[str, float] = {}

    def register(self, job_id: str) -> None:
        self._jobs[job_id] = _JobIngest(
            BoundedBuffer(self.queue_limit, self.queue_policy),
            StreamingFilter() if self.denoise else None,
            BoundedBuffer(self.queue_limit, self.queue_policy)
            if self.track_variance else None)

    def push(self, job_id: str, samples: np.ndarray,
             variance: Optional[np.ndarray] = None,
             now: Optional[float] = None) -> None:
        ji = self._jobs[job_id]
        s = np.asarray(samples, np.float32).reshape(-1)
        if variance is not None and ji.vbuffer is None:
            raise ValueError("per-sample variance requires "
                             "track_variance=True on the IngestFront")
        # Poison checks run BEFORE anything is enqueued or journaled:
        # a poisoned push is atomic (nothing partially accepted), so the
        # serving layer can quarantine the job while survivors — and the
        # WAL a recovery will replay — never see the bad values.
        if not np.all(np.isfinite(s)):
            raise PoisonedSampleError(job_id, "non-finite sample (NaN/Inf)")
        if ji.vbuffer is not None:
            # NaN marks "no variance supplied" — resolved to the causal
            # filter residual (or 0.0) at drain time, when the filtered
            # values exist.
            v = np.full((s.shape[0],), np.nan, np.float32) \
                if variance is None \
                else np.asarray(variance, np.float32).reshape(-1)
            if v.shape[0] != s.shape[0]:
                raise ValueError(f"{s.shape[0]} samples but "
                                 f"{v.shape[0]} variances")
            supplied = v[~np.isnan(v)]
            if np.any(supplied < 0.0):
                raise PoisonedSampleError(
                    job_id, "variances must be >= 0")
            if not np.all(np.isfinite(supplied)):
                raise PoisonedSampleError(job_id, "non-finite variance")
        ji.buffer.append(s)                      # may raise Backpressure
        if ji.vbuffer is not None and s.shape[0]:
            # Same pre-push pending count and same chunk length as the
            # sample buffer, so this cannot raise after buffer accepted.
            ji.vbuffer.append(v)
        ji.pushed += s.shape[0]
        if self.trace is not None and s.shape[0]:
            # journal with full replay context: the variance row (when
            # tracked) and the heartbeat stamp ride the chunk record.
            self.trace.append(
                job_id, s,
                variance=v if ji.vbuffer is not None else None, now=now)
        if now is not None:
            if self.heartbeats is not None:
                self.heartbeats.beat(job_id, ji.pushed, now)
            prev = self._last_push.get(job_id)
            if prev is not None and now > prev:
                self.stragglers.record(job_id, now - prev)
            self._last_push[job_id] = now

    def has_data(self, job_id: str) -> bool:
        return len(self._jobs[job_id].buffer) > 0

    def drain(self, job_id: str, with_variance: bool = False):
        """Buffered samples as ONE causally-filtered chunk (None when
        the queue is empty) — bit-identical to filtering the same
        samples in any other push/drain grouping (the streaming filter
        is stateful and causal).

        ``with_variance=True`` (requires ``track_variance=True``)
        returns an aligned ``(chunk, vchunk)`` pair instead, with
        unsupplied variances defaulted from the filter residual."""
        ji = self._jobs[job_id]
        if with_variance and ji.vbuffer is None:
            raise ValueError("drain(with_variance=True) requires "
                             "track_variance=True on the IngestFront")
        raw = ji.buffer.drain()
        if raw is None:
            return (None, None) if with_variance else None
        chunk = ji.filt(raw) if ji.filt is not None else raw
        if ji.vbuffer is not None:
            vchunk = ji.vbuffer.drain()
            if not with_variance:
                return chunk
            resid = (raw - chunk) ** 2 if ji.filt is not None \
                else np.zeros_like(raw)
            vchunk = np.where(np.isnan(vchunk), resid, vchunk) \
                .astype(np.float32)
            return chunk, vchunk
        return (chunk, None) if with_variance else chunk

    def dropped(self, job_id: str) -> int:
        return self._jobs[job_id].buffer.dropped

    def queue_fill(self) -> float:
        """Worst-case bounded-buffer occupancy across registered jobs in
        [0, 1] — the queue-depth signal the admission controller
        consumes.  0.0 when queues are unbounded (no limit to fill)."""
        if self.queue_limit is None or not self._jobs:
            return 0.0
        worst = max(len(ji.buffer) for ji in self._jobs.values())
        return min(1.0, worst / float(self.queue_limit))

    def stalled(self, now: float) -> List[str]:
        """Job ids newly declared dead by the heartbeat tracker."""
        if self.heartbeats is None:
            return []
        return [j for j in self.heartbeats.sweep(now) if j in self._jobs]

    def retire(self, job_id: str) -> None:
        self._jobs.pop(job_id)
        self._last_push.pop(job_id, None)
        if self.heartbeats is not None:
            self.heartbeats.forget(job_id)
