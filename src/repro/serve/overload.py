"""Overload control plane: admission + graded degradation ladder.

OPERATIONS RUNBOOK
==================

What this plane does
--------------------
A burst of submissions (or a slow device) must degrade the tuning
service *predictably*: shed precision and latency headroom in a fixed,
graded order instead of blowing queue limits or stalling ticks.  Two
cooperating controllers implement that:

* :class:`OverloadController` — watches measured tick latency and walks
  a **degradation ladder**; the tick engine consults the current rung
  before every dispatch.
* :class:`AdmissionController` — gates ``TuningService.submit`` with
  per-job cost estimates and QoS classes, raising
  :class:`AdmissionShedError` (a ``BackpressureError``) when the service
  should not take the job.

The ladder (rungs, in escalation order)
---------------------------------------
====  ===============  ====================================================
rung  name             effect on the tick engine
====  ===============  ====================================================
0     normal           full prob-scored tick (6 moment channels + vstats)
1     approx_prob      approximate probability tick — 4 moment channels
                       (one carried variance channel, the remaining tail
                       reconstructed at the score tail, ~1.3x a scored
                       tick instead of ~2x).  The ladder sheds probability
                       *precision* here before it sheds probabilities
                       entirely: probabilities keep flowing but early
                       decisions are suppressed for exact-mode services
                       (``degraded_level=1`` on jobs ticked here).
                       Services configured with ``prob_mode="approx"``
                       already run this tick as their base mode and are
                       unaffected by this rung.
2     exact_score      exact scored tick only — variance channels go stale,
                       probability-gated early decisions suppressed
                       (``degraded_level=1`` on jobs ticked here)
3     distance_only    distance-only tick — all moment channels stale, no
                       early decisions for jobs ticked here
                       (``degraded_level=2``); final verdicts recomputed
                       offline from the full query, bitwise unchanged
4     deep_prune       ``prefilter_top`` halved — fewer live references
                       per tick (DTW veto still applies)
5     slow_cohorts     ``TickCohorts`` re-arm intervals stretched by
                       ``cohort_scale`` — jobs tick less often
6     reject           admission pressure pinned to 1.0 — every submit
                       sheds regardless of QoS
====  ===============  ====================================================

Every rung may *delay* decisions; none may change them.  The invariant
(pinned by the golden tests) is that the DP warp path is identical in
all tick modes, so a downgraded tick computes the same rows — only the
side channels used for *early* (pre-finish) decisions go stale, and a
stale channel suppresses the early decision rather than risking a wrong
one.  The final verdict is always recomputed from the full accumulated
query at finish time and is bit-identical to an unloaded run.

Signals
-------
* **EWMA p99 tick latency** vs ``OverloadConfig.target_p99`` — the
  escalation signal.  Latency is measured per top-level tick (plus any
  chaos-injected slowdown), journaled by the recovery layer so replay
  reproduces the rung trajectory bit-identically.
* **queue fill** — ``IngestFront.queue_fill()``, worst-case bounded
  buffer occupancy across jobs; an admission signal.
* **cost fill** — expected job length over the reference-bank mean
  length (the cumulative-CPU cost proxy of arXiv:1203.4054); an
  admission signal.
* **rung fraction** — ``rung / (len(RUNGS) - 1)``; couples the ladder
  into admission so a degraded service also sheds harder.

How to read ``rung_history``
----------------------------
``OverloadController.rung_history`` is a list of
``(observation_index, from_rung, to_rung)`` transitions, e.g.
``[(6, 0, 1), (8, 1, 2), (31, 2, 1), (34, 1, 0)]`` reads: escalated to
``exact_score`` at the 6th observed tick, on to ``distance_only`` two
ticks later, then de-escalated back to normal once the burst passed.
A non-trivial history under load plus an empty tail (back at rung 0)
after the burst is the healthy signature.  A history pinned at high
rungs means the target is simply unachievable — rescale instead (see
``runtime.fault.ElasticController.decide_ahead``, which consumes
``TuningService.overload_pressure()`` as the rescale-ahead signal).

Counters (on ``TuningService``)
-------------------------------
* ``shed_count`` / ``shed_by_class`` — admissions refused, total and per
  QoS class (monitoring only: shed submits are *not* journaled, the job
  never existed as far as recovery is concerned).
* ``overload_ticks`` — ticks dispatched at rung >= 1.
* ``worst_rung`` — high-water rung reached.
* breaker counters (``CircuitBreaker.opened_count`` /
  ``reclosed_count``) — kernel-path demotions; ``TuningService.degraded``
  is True while the breaker is engaged OR the ladder is above rung 0.

All controller state is JSON-serialisable (``state_dict`` /
``load_state``) and rides service snapshots, so recovery of an
overloaded service resumes mid-ladder, bit-identically.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, List, Optional, Tuple

from .ingest import BackpressureError

__all__ = ["RUNGS", "AdmissionController", "AdmissionPolicy",
           "AdmissionShedError", "OverloadConfig", "OverloadController"]

#: Ladder rungs in escalation order (see the runbook table above).
RUNGS: Tuple[str, ...] = ("normal", "approx_prob", "exact_score",
                          "distance_only", "deep_prune", "slow_cohorts",
                          "reject")


class AdmissionShedError(BackpressureError):
    """Submit refused by admission control.  Subclasses
    ``BackpressureError`` so callers already handling ingest
    backpressure handle shedding the same way."""

    def __init__(self, job_id: str, qos: str, pressure: float,
                 threshold: float) -> None:
        super().__init__(
            f"job {job_id!r} (qos={qos}) shed: pressure {pressure:.3f} "
            f">= threshold {threshold:.3f}")
        self.job_id = job_id
        self.qos = qos
        self.pressure = pressure
        self.threshold = threshold


@dataclasses.dataclass
class OverloadConfig:
    """Knobs for the degradation ladder (JSON-able; rides snapshots).

    ``target_p99`` is the tick-latency SLO in seconds; the ladder
    escalates after ``patience`` consecutive observations whose EWMA'd
    window-p99 exceeds it, and de-escalates after ``cooldown``
    consecutive calm observations.  ``cohort_scale`` is the tick-rate
    stretch applied at rung >= 5."""

    target_p99: float = 0.25
    window: int = 32
    ewma_alpha: float = 0.3
    patience: int = 2
    cooldown: int = 3
    max_rung: int = len(RUNGS) - 1
    cohort_scale: float = 4.0

    def __post_init__(self) -> None:
        if self.target_p99 <= 0.0:
            raise ValueError("target_p99 must be > 0")
        if self.window < 1 or self.patience < 1 or self.cooldown < 1:
            raise ValueError("window/patience/cooldown must be >= 1")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0 <= self.max_rung <= len(RUNGS) - 1:
            raise ValueError(f"max_rung must be in [0, {len(RUNGS) - 1}]")
        if self.cohort_scale < 1.0:
            raise ValueError("cohort_scale must be >= 1.0")


class OverloadController:
    """Walks the degradation ladder from observed tick latencies.

    Fully deterministic given the observation sequence: the recovery
    layer journals each top-level tick's measured latency and replays it
    through :meth:`observe`, so a restored service reproduces the exact
    rung trajectory (hence the exact tick modes and staleness markers)
    of the original run.
    """

    def __init__(self, config: Optional[OverloadConfig] = None) -> None:
        self.config = config or OverloadConfig()
        self.rung: int = 0
        #: ``(observation_index, from_rung, to_rung)`` transitions.
        self.rung_history: List[Tuple[int, int, int]] = []
        self._window: Deque[float] = deque(maxlen=self.config.window)
        self._ewma: Optional[float] = None
        self._hot = 0
        self._calm = 0
        self._observed = 0

    # -- signal ---------------------------------------------------------
    def observe(self, latency: float) -> int:
        """Feed one top-level tick's measured latency (seconds); returns
        the rung in force for the *next* tick."""
        self._window.append(float(latency))
        n = len(self._window)
        p99 = sorted(self._window)[min(n - 1,
                                       max(0, math.ceil(0.99 * n) - 1))]
        a = self.config.ewma_alpha
        self._ewma = p99 if self._ewma is None else \
            a * p99 + (1.0 - a) * self._ewma
        self._observed += 1
        if self._ewma > self.config.target_p99:
            self._hot += 1
            self._calm = 0
            if self._hot >= self.config.patience:
                self._hot = 0
                self._move(min(self.config.max_rung, self.rung + 1))
        else:
            self._calm += 1
            self._hot = 0
            if self._calm >= self.config.cooldown:
                self._calm = 0
                self._move(max(0, self.rung - 1))
        return self.rung

    def _move(self, new: int) -> None:
        if new != self.rung:
            self.rung_history.append((self._observed, self.rung, new))
            self.rung = new

    # -- derived knobs the tick engine consults -------------------------
    @property
    def tick_mode_cap(self) -> str:
        """Most expensive tick mode the current rung allows:
        ``"prob"`` (rung 0), ``"approx_prob"`` (rung 1), ``"scored"``
        (rung 2) or ``"distance"`` (rung >= 3)."""
        if self.rung == 0:
            return "prob"
        if self.rung == 1:
            return "approx_prob"
        if self.rung == 2:
            return "scored"
        return "distance"

    @property
    def prefilter_divisor(self) -> int:
        """Divide ``prefilter_top`` by this (rung >= 4 prunes deeper)."""
        return 2 if self.rung >= 4 else 1

    @property
    def cohort_scale(self) -> float:
        """Stretch factor for ``TickCohorts`` re-arm intervals."""
        return self.config.cohort_scale if self.rung >= 5 else 1.0

    def pressure(self) -> float:
        """Scalar overload pressure in [0, 1] for admission and
        rescale-ahead: the worse of the ladder position and the
        latency-vs-target ratio."""
        rung_frac = self.rung / max(1, len(RUNGS) - 1)
        lat_frac = 0.0 if self._ewma is None else \
            min(1.0, self._ewma / self.config.target_p99)
        return max(rung_frac, lat_frac)

    # -- persistence ----------------------------------------------------
    def state_dict(self) -> dict:
        return {"rung": self.rung,
                "rung_history": [list(t) for t in self.rung_history],
                "window": list(self._window),
                "ewma": self._ewma,
                "hot": self._hot, "calm": self._calm,
                "observed": self._observed}

    def load_state(self, st: dict) -> None:
        self.rung = int(st["rung"])
        self.rung_history = [tuple(int(v) for v in t)
                             for t in st["rung_history"]]
        self._window = deque((float(v) for v in st["window"]),
                             maxlen=self.config.window)
        self._ewma = None if st["ewma"] is None else float(st["ewma"])
        self._hot = int(st["hot"])
        self._calm = int(st["calm"])
        self._observed = int(st["observed"])


@dataclasses.dataclass
class AdmissionPolicy:
    """Per-QoS shed thresholds on the admission pressure (JSON-able).

    A submit is shed when pressure >= its class threshold.  Thresholds
    must be ordered bronze <= silver <= gold, which *guarantees* gold
    jobs are never shed at a pressure that admits bronze.  ``cost_scale``
    normalises the per-job cost estimate: a job of
    ``cost_scale * mean_reference_length`` expected samples contributes
    cost-fill 1.0 on its own."""

    bronze: float = 0.7
    silver: float = 0.85
    gold: float = 1.0
    cost_scale: float = 4.0

    def __post_init__(self) -> None:
        if not 0.0 < self.bronze <= self.silver <= self.gold:
            raise ValueError(
                "thresholds must satisfy 0 < bronze <= silver <= gold")
        if self.cost_scale <= 0.0:
            raise ValueError("cost_scale must be > 0")

    def threshold(self, qos: str) -> float:
        try:
            return {"bronze": self.bronze, "silver": self.silver,
                    "gold": self.gold}[qos]
        except KeyError:
            raise ValueError(f"unknown QoS class {qos!r} "
                             "(expected bronze/silver/gold)") from None


class AdmissionController:
    """Stateless admission gate: combines the instantaneous signals into
    one pressure scalar and sheds by QoS class.

    Statelessness matters for recovery: given replayed signals the gate
    re-makes identical decisions, and shed submits are never journaled
    (the job simply never existed), so replay cannot diverge.
    """

    def __init__(self, policy: Optional[AdmissionPolicy] = None) -> None:
        self.policy = policy or AdmissionPolicy()

    def pressure(self, *, cost_fill: float, queue_fill: float,
                 rung_frac: float) -> float:
        """Worst of the three normalised signals, clipped to [0, 1]."""
        return max(0.0, min(1.0, max(float(cost_fill), float(queue_fill),
                                     float(rung_frac))))

    def admit(self, job_id: str, *, qos: str, cost_fill: float,
              queue_fill: float, rung_frac: float) -> float:
        """Return the admission pressure, or raise
        :class:`AdmissionShedError` when the class threshold is hit."""
        p = self.pressure(cost_fill=cost_fill, queue_fill=queue_fill,
                          rung_frac=rung_frac)
        thr = self.policy.threshold(qos)
        if p >= thr:
            raise AdmissionShedError(job_id, qos, p, thr)
        return p
