"""Slot scheduler of the streaming tuning service.

Second layer of the serving stack (``ingest -> scheduler -> tick engine
-> verdicts``, see ``serve.tuning``): WHO occupies WHICH row of the
device-resident ``[S, M, K]`` tick state, and WHEN each job's buffered
samples are drained into a tick.

S-axis slot bucketing
---------------------
The tick engine's device arrays are sized by the slot capacity S.  A
fixed S = ``max_slots`` wastes compute and bandwidth whenever fewer jobs
are in flight — and a serving front sized for a 1024-job burst idles at
64 jobs most of the day.  The scheduler therefore sizes S to the
power-of-two bucket of the *active* job count (floor
:data:`MIN_SLOT_BUCKET`, ceiling ``max_slots``), exactly mirroring the
K-axis survivor bucketing the wavelet prefilter introduced (PR 4): jit
shapes stay few (at most log2(S) buckets per chunk shape), growth
re-packs the state arrays by an S-axis device gather (never a host
round-trip), and shrink COMPACTS surviving jobs into the low slots
before cutting capacity.  Per-job DP state is row-independent, so slot
moves are bit-exact: every decision is invariant to packing, admission
order and capacity history (pinned by the churn-invariance tests).
Re-packs are counted by the service in ``slot_repack_count``, separate
from the K-axis ``repack_count`` and never inflating
``dispatch_count``.

Tick-rate cohorts
-----------------
Jobs declare a monitoring rate at submit (``tick_hz``); jobs sharing a
rate form a cohort with one due-clock.  ``tick(now=...)`` drains only
the cohorts whose period has elapsed, so a 4 Hz trace is touched (host
chunk assembly, score scatter, decision rule) only on its own beats
instead of paying for a 100 Hz neighbor's cadence — between beats its
samples just accumulate in the ingest queue.  Jobs without a rate sit
in the always-due cohort, and a clock-less ``tick()`` drains everyone:
the pre-cohort behavior, preserving dispatches == data-ticks.

Fault wiring
------------
The scheduler consumes the ingest layer's ``HeartbeatTracker`` sweeps:
a job whose monitoring agent stops pushing is *evicted* — slot freed
with no verdict, state compacted at the next tick — rather than pinning
a device row forever (``TuningService.sweep_stalled``).  Rescale
decisions from ``runtime.fault.ElasticController`` drive
``TuningService.rescale`` (re-homing the bank shards onto a new mesh);
the scheduler itself is mesh-agnostic.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

__all__ = ["MIN_SLOT_BUCKET", "slot_bucket", "TickCohorts", "SlotScheduler"]

#: smallest elastic S capacity: one growth step below this saves little
#: (the arrays are tiny) while doubling the compiled tick shapes.
MIN_SLOT_BUCKET = 4


def slot_bucket(n: int, max_slots: int,
                lo: int = MIN_SLOT_BUCKET) -> int:
    """Padded slot capacity for ``n`` active jobs: the power of two >= n
    (floor ``lo``), clamped to ``max_slots`` — the S-axis twin of the
    prefilter's K bucket."""
    p = max(lo, 1 << max(n - 1, 0).bit_length())
    return min(max_slots, max(p, n))


class TickCohorts:
    """Groups jobs by declared tick rate and meters their drains.

    One due-clock per distinct ``tick_hz``; a cohort becomes due when
    ``now`` passes its next-due time, and draining re-arms it one period
    ahead.  ``tick_hz=None`` jobs are always due, and a ``now=None``
    query means "ignore pacing" (every job due) — both keep the legacy
    drain-everything semantics.
    """

    def __init__(self) -> None:
        self._hz: Dict[str, Optional[float]] = {}
        self._next_due: Dict[float, float] = {}
        #: re-arm stretch factor (>= 1.0): the overload ladder's
        #: ``slow_cohorts`` rung sets this > 1 so due cohorts re-arm
        #: ``scale / hz`` ahead instead of ``1 / hz`` — jobs tick less
        #: often under load, they are never skipped outright.
        self.rate_scale: float = 1.0

    def assign(self, job_id: str, tick_hz: Optional[float]) -> None:
        if tick_hz is not None and tick_hz <= 0:
            raise ValueError("tick_hz must be positive (or None)")
        self._hz[job_id] = tick_hz
        if tick_hz is not None:
            self._next_due.setdefault(float(tick_hz), -np.inf)

    def remove(self, job_id: str) -> None:
        self._hz.pop(job_id, None)

    @property
    def n_cohorts(self) -> int:
        """Distinct rate cohorts with members (always-due counts as one
        when any unrated job exists)."""
        rates = set(self._hz.values())
        return len(rates)

    def due_jobs(self, now: Optional[float]) -> Set[str]:
        """Jobs whose cohort should drain at ``now`` (all jobs when
        ``now`` is None); due rate-cohorts are re-armed ``1/hz`` ahead.
        """
        if now is None:
            return set(self._hz)
        due_rates = {hz for hz, t in self._next_due.items() if now >= t}
        for hz in due_rates:
            self._next_due[hz] = now + self.rate_scale / hz
        return {j for j, hz in self._hz.items()
                if hz is None or float(hz) in due_rates}

    # -- (de)hydration (serve.recovery) --------------------------------------
    def state_dict(self) -> Dict:
        """JSON-able snapshot of the cohort clocks (``-inf`` next-due
        values survive the round trip — stdlib json emits ``-Infinity``)
        so a restored service re-arms every cohort exactly where the
        crashed one left it."""
        return {"hz": dict(self._hz),
                "next_due": {repr(hz): t
                             for hz, t in self._next_due.items()},
                "rate_scale": self.rate_scale}

    def load_state(self, state: Dict) -> None:
        self._hz = {j: (None if hz is None else float(hz))
                    for j, hz in state["hz"].items()}
        self._next_due = {float(hz): float(t)
                          for hz, t in state["next_due"].items()}
        self.rate_scale = float(state.get("rate_scale", 1.0))


class SlotScheduler:
    """Slot admission/eviction with power-of-two S-axis capacity.

    ``elastic=False`` pins capacity at ``max_slots`` (the pre-refactor
    fixed-slot service); ``elastic=True`` starts at the smallest bucket
    and grows/shrinks with the active set.  The scheduler only plans —
    every plan returns a gather ``src`` array (new slot -> old slot, -1
    for fresh rows) that the tick engine applies to its device arrays;
    host bookkeeping (job -> slot, free list) is committed here in the
    same call so the two views never diverge.
    """

    def __init__(self, max_slots: int, *, elastic: bool = True) -> None:
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.elastic = elastic
        self.capacity = slot_bucket(0, max_slots) if elastic else max_slots
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        self._slot_of: Dict[str, int] = {}
        self.cohorts = TickCohorts()

    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    def slot_of(self, job_id: str) -> int:
        return self._slot_of[job_id]

    def admit(self, job_id: str,
              tick_hz: Optional[float] = None
              ) -> Tuple[int, Optional[np.ndarray]]:
        """Assign the lowest free slot, growing capacity to the next
        bucket when none is free.  Returns ``(slot, grow_src)`` where
        ``grow_src`` (int64 [new_capacity], old slot or -1) is the
        S-axis gather the engine must apply BEFORE using the slot, or
        None when capacity is unchanged.  Raises ``RuntimeError`` once
        ``max_slots`` jobs are in flight — admission control is the
        caller-visible backpressure, elastic or not."""
        if job_id in self._slot_of:
            raise ValueError(f"job {job_id!r} already scheduled")
        grow_src = None
        if not self._free:
            if self.n_active >= self.max_slots:
                raise RuntimeError(f"all {self.max_slots} slots busy")
            new_cap = slot_bucket(self.n_active + 1, self.max_slots)
            grow_src = np.concatenate([
                np.arange(self.capacity, dtype=np.int64),
                np.full((new_cap - self.capacity,), -1, np.int64)])
            self._free = list(range(new_cap - 1, self.capacity - 1, -1))
            self.capacity = new_cap
        slot = self._free.pop()
        self._slot_of[job_id] = slot
        self.cohorts.assign(job_id, tick_hz)
        return slot, grow_src

    def release(self, job_id: str) -> int:
        slot = self._slot_of.pop(job_id)
        self._free.append(slot)
        self.cohorts.remove(job_id)
        return slot

    def shrink_plan(self) -> Optional[Tuple[np.ndarray,
                                            Dict[str, int]]]:
        """When the active set fits a smaller bucket, compact jobs into
        the low slots (stable: slot order preserved) and cut capacity.
        Returns ``(src, moves)`` — the S-axis gather plus the job ->
        new-slot reassignments, already committed to the host
        bookkeeping — or None when capacity should stand.  Hysteresis
        is inherent to the power-of-two buckets: a set oscillating
        within one bucket never re-packs."""
        if not self.elastic:
            return None
        target = slot_bucket(self.n_active, self.max_slots)
        if target >= self.capacity:
            return None
        order = sorted(self._slot_of.items(), key=lambda kv: kv[1])
        src = np.full((target,), -1, np.int64)
        moves: Dict[str, int] = {}
        for new_slot, (job_id, old_slot) in enumerate(order):
            src[new_slot] = old_slot
            moves[job_id] = new_slot
        self._slot_of.update(moves)
        self._free = list(range(target - 1, len(order) - 1, -1))
        self.capacity = target
        return src, moves

    def due_jobs(self, now: Optional[float],
                 job_ids: Iterable[str]) -> Set[str]:
        due = self.cohorts.due_jobs(now)
        return due.intersection(job_ids) if now is not None else set(job_ids)

    # -- (de)hydration (serve.recovery) --------------------------------------
    def state_dict(self) -> Dict:
        """JSON-able snapshot of the slot layout (capacity bucket, free
        list ORDER, job->slot map, cohort clocks).  The free-list order
        matters for bit-identical recovery: it decides which slot the
        next admit takes, and the churn-invariance suite pins decisions
        against exactly that packing history."""
        return {"max_slots": self.max_slots, "elastic": self.elastic,
                "capacity": self.capacity, "free": list(self._free),
                "slot_of": dict(self._slot_of),
                "cohorts": self.cohorts.state_dict()}

    def load_state(self, state: Dict) -> None:
        self.max_slots = int(state["max_slots"])
        self.elastic = bool(state["elastic"])
        self.capacity = int(state["capacity"])
        self._free = [int(s) for s in state["free"]]
        self._slot_of = {j: int(s) for j, s in state["slot_of"].items()}
        self.cohorts.load_state(state["cohorts"])
