"""Serving: prefill/decode step builders and a small batched engine.

The decode step mutates (donates) the KV/SSM cache; both steps carry the
activation-sharding callback so caches stay sequence- or batch-sharded per
``repro.sharding.rules.cache_specs``.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib
from ..models.config import ModelConfig

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine"]


def make_prefill_step(cfg: ModelConfig, mesh=None, data_axes=("data",),
                      shard=model_lib._id_shard) -> Callable:
    def prefill_step(params, tokens, cache, extra_embeds=None, positions=None):
        return model_lib.prefill(params, tokens, cache, cfg,
                                 extra_embeds=extra_embeds,
                                 positions=positions, mesh=mesh,
                                 data_axes=data_axes, shard=shard)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, data_axes=("data",),
                     shard=model_lib._id_shard) -> Callable:
    def decode_one(params, token, cache, pos):
        return model_lib.decode_step(params, token, cache, pos, cfg,
                                     mesh=mesh, data_axes=data_axes,
                                     shard=shard)
    return decode_one


class ServeEngine:
    """Minimal batched greedy/temperature serving loop (single host).

    Continuous-batching style: a fixed slot count; each generate() call
    prefils a batch and decodes until all sequences emit EOS or hit
    ``max_new``.  This is the runnable example path, not the dry-run path.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 2048,
                 temperature: float = 0.0, eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    def generate(self, tokens: np.ndarray, max_new: int = 32,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        B, S = tokens.shape[:2]
        assert S + max_new <= self.max_len
        cache = model_lib.make_cache(self.cfg, B, self.max_len, concrete=True)
        logits, cache = self._prefill(self.params, jnp.asarray(tokens), cache)
        out = []
        tok = self._sample(logits, key)
        out.append(np.asarray(tok))
        done = np.zeros(B, bool)
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(S + i))
            tok = self._sample(logits, key)
            t = np.asarray(tok)
            if self.eos_id is not None:
                done |= (t.reshape(B, -1)[:, 0] == self.eos_id)
            out.append(t)
            if self.eos_id is not None and done.all():
                break
        return np.stack(out, axis=1)

    def _sample(self, logits, key):
        cfg = self.cfg
        if cfg.num_codebooks > 1:
            logits = logits.reshape(logits.shape[0], cfg.num_codebooks,
                                    cfg.vocab_size)
        if self.temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)
