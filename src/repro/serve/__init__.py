from .engine import make_prefill_step, make_decode_step, ServeEngine
from .ingest import BackpressureError, BoundedBuffer, IngestFront, TraceLog
from .scheduler import (MIN_SLOT_BUCKET, SlotScheduler, TickCohorts,
                        slot_bucket)
from .tuning import InFlightJob, MultiTenantTuningService, TuningService

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine",
           "BackpressureError", "BoundedBuffer", "IngestFront", "TraceLog",
           "MIN_SLOT_BUCKET", "SlotScheduler", "TickCohorts", "slot_bucket",
           "InFlightJob", "MultiTenantTuningService", "TuningService"]
