from .engine import make_prefill_step, make_decode_step, ServeEngine

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine"]
