from .engine import make_prefill_step, make_decode_step, ServeEngine
from .ingest import (BackpressureError, BoundedBuffer, IngestFront,
                     PoisonedSampleError, TraceLog)
from .overload import (RUNGS, AdmissionController, AdmissionPolicy,
                       AdmissionShedError, OverloadConfig,
                       OverloadController)
from .recovery import (RecoverableTuningService, restore_service,
                       snapshot_service)
from .scheduler import (MIN_SLOT_BUCKET, SlotScheduler, TickCohorts,
                        slot_bucket)
from .tuning import InFlightJob, MultiTenantTuningService, TuningService

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine",
           "BackpressureError", "BoundedBuffer", "IngestFront",
           "PoisonedSampleError", "TraceLog",
           "RUNGS", "AdmissionController", "AdmissionPolicy",
           "AdmissionShedError", "OverloadConfig", "OverloadController",
           "RecoverableTuningService", "restore_service", "snapshot_service",
           "MIN_SLOT_BUCKET", "SlotScheduler", "TickCohorts", "slot_bucket",
           "InFlightJob", "MultiTenantTuningService", "TuningService"]
