from .engine import make_prefill_step, make_decode_step, ServeEngine
from .ingest import (BackpressureError, BoundedBuffer, IngestFront,
                     PoisonedSampleError, TraceLog)
from .recovery import (RecoverableTuningService, restore_service,
                       snapshot_service)
from .scheduler import (MIN_SLOT_BUCKET, SlotScheduler, TickCohorts,
                        slot_bucket)
from .tuning import InFlightJob, MultiTenantTuningService, TuningService

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine",
           "BackpressureError", "BoundedBuffer", "IngestFront",
           "PoisonedSampleError", "TraceLog",
           "RecoverableTuningService", "restore_service", "snapshot_service",
           "MIN_SLOT_BUCKET", "SlotScheduler", "TickCohorts", "slot_bucket",
           "InFlightJob", "MultiTenantTuningService", "TuningService"]
