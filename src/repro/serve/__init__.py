from .engine import make_prefill_step, make_decode_step, ServeEngine
from .tuning import InFlightJob, TuningService

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine",
           "InFlightJob", "TuningService"]
