"""Crash-safe serving: durable snapshots + write-ahead replay recovery.

The serving stack (``ingest -> scheduler -> tick engine -> verdicts``,
see :mod:`repro.serve.tuning`) holds state in three places — device
arrays (the ``[S, M, K]`` DP rows and moment slabs), host bookkeeping
(ingest queues, slot layout, cohort clocks, decision history) and the
on-disk trace.  A process crash loses the first two.  This module makes
the whole service durable with the classic database recipe:

**snapshot + write-ahead log (WAL) => bit-identical recovery.**

* :func:`snapshot_service` dehydrates a live :class:`TuningService` into
  ONE dict-nested numpy tree (device slabs pulled to host and sliced to
  the live packed columns; every queue, clock, counter and pending
  verdict alongside; the JSON-able metadata rides as a ``uint8`` leaf)
  that round-trips through :mod:`repro.checkpoint` — two-phase atomic
  saves, manifest-verified restores, no pickles.
* :func:`restore_service` rehydrates that tree into a fresh process —
  onto the SAME device mesh or a DIFFERENT one (the packed state re-pads
  per device count exactly like :meth:`TuningService.rescale`; scores
  are per-reference quantities, so column math never crosses the shard
  boundary and decisions are bitwise mesh-independent).
* :class:`RecoverableTuningService` wraps the service with the WAL
  discipline.  The ingest layer's :class:`~repro.serve.ingest.TraceLog`
  IS the journal: every accepted push already lands there with full
  replay context (samples, variance row, heartbeat stamp), and the
  wrapper journals every OTHER mutating command (submit / tick / finish
  / evict / quarantine / drain, one event record per command) into the
  same sequence space, flushing after each command so *acked == durable*.
  :meth:`RecoverableTuningService.checkpoint` saves a snapshot stamped
  with the journal watermark (``TraceLog.next_seq``);
  :meth:`RecoverableTuningService.recover` loads the newest complete
  snapshot and REPLAYS the journal tail (``seq >= watermark``) against
  it with journaling suppressed.

Because every layer underneath is already exactly re-executable —
chunked DP == one-shot DP (chunking invariance), any drain grouping ==
any other (causal filter state), decisions independent of packing
history (churn invariance) — replaying the logged commands reproduces
the crashed service's scores, probabilities, decisions and schedule
position *bitwise*, tick for tick.  The chaos harness
(:mod:`repro.runtime.chaos` + the kill-and-recover tests) SIGKILLs a
serving process mid-stream and pins exactly that equality, including
restores onto a different device count.

Torn-write tolerance: a crash mid-``flush`` may leave a truncated final
``.npz`` segment — :class:`TraceLog` skips it (counted, warned) and
recovery proceeds from the durable prefix; a crash mid-snapshot leaves
no ``manifest.json``, so :func:`repro.checkpoint.load_checkpoint_tree`
falls back to the newest COMPLETE step.  Both are exercised by tests.

What is NOT persisted: process-local handles (the device mesh, the
retry policy, a chaos plan, the ReferenceDB object) — the restoring
caller re-supplies them; and the wavelet coefficient cache — rebuilt
lazily, bitwise the same.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager, load_checkpoint_tree
from ..core.database import ReferenceDB, SeriesBank
from ..core.tuner import TuneDecision, _RowBuffer
from ..core import wavelet as _wavelet
from ..runtime.chaos import FaultPlan
from ..runtime.fault import WorkerState
from ..runtime.retry import CircuitBreaker, RetryPolicy
from .ingest import PoisonedSampleError, TraceLog
from .tuning import InFlightJob, TuningService

__all__ = ["SNAPSHOT_VERSION", "snapshot_service", "restore_service",
           "RecoverableTuningService"]

SNAPSHOT_VERSION = 1


def _bank_fingerprint(svc: TuningService) -> str:
    """Content hash of the reference bank a snapshot was taken against.

    Restore refuses a mismatched bank: the packed DP columns are
    positional, so rehydrating them against different references would
    silently mis-score every job."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(svc.bank.series).tobytes())
    h.update(np.ascontiguousarray(svc.bank.lengths).tobytes())
    h.update(json.dumps(list(svc._labels)).encode())
    return h.hexdigest()


def _decision_record(d: Optional[TuneDecision]) -> Optional[Dict]:
    return None if d is None else d.to_record()


def _decision_from(rec: Optional[Dict],
                   svc: TuningService) -> Optional[TuneDecision]:
    if rec is None:
        return None
    d = TuneDecision.from_record(rec)
    # to_record drops the transferred config (it lives on the matched DB
    # entry); re-derive it exactly as the original decision did.
    if d.matched is not None and svc.db is not None:
        d.config = svc.db.best_config(d.matched)
    return d


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------

def snapshot_service(svc: TuningService) -> Dict[str, Any]:
    """Dehydrate a live service into a dict-nested numpy tree.

    The tree is pure ``{str: array-or-dict}`` — exactly what
    :func:`repro.checkpoint.save_checkpoint` persists with leaf-path
    manifests, so :func:`repro.checkpoint.load_checkpoint_tree` can
    rebuild it in a fresh process with no target skeleton.  Device
    state comes back to the host sliced to the live packed columns
    (``k_live``); re-padding is the restorer's job (it depends on the
    TARGET device count).  Metadata that is JSON, not array — config,
    slot layout, per-job scalars, pending decisions, counters — rides
    as one ``uint8`` JSON leaf."""
    k_live = len(svc._packed_idx)
    jobs_meta: List[Dict[str, Any]] = []
    jobs_tree: Dict[str, Dict[str, np.ndarray]] = {}
    for i, job in enumerate(svc._jobs.values()):
        ji = svc._front._jobs[job.job_id]
        jm: Dict[str, Any] = {
            "job_id": job.job_id, "slot": int(job.slot),
            "expected_len": int(job.expected_len),
            "tick_hz": job.tick_hz, "n": int(job.n),
            "leader": job.leader, "stable_for": int(job.stable_for),
            "qos": job.qos,
            "degraded_level": int(job.degraded_level),
            "early": _decision_record(job.early),
            "pushed": int(ji.pushed),
            "dropped": int(ji.buffer.dropped),
            "vdropped": int(ji.vbuffer.dropped)
            if ji.vbuffer is not None else 0,
        }
        jt: Dict[str, np.ndarray] = {}
        x = job.x.view()
        if x.shape[0]:
            jt["x"] = np.array(x, np.float32)
        vx = job.vx.view()
        if vx.shape[0]:
            jt["vx"] = np.array(vx, np.float32)
        if job.last_sims is not None:
            jt["last_sims"] = np.array(job.last_sims, np.float64)
        if job.last_probs is not None:
            jt["last_probs"] = np.array(job.last_probs, np.float64)
        if job.allowed is not None:
            jt["allowed"] = np.array(job.allowed, bool)
        # pending (pushed, not yet drained) ingest queues.  Chunk
        # boundaries are irrelevant to both drain (one concatenate) and
        # drop_oldest shedding (sheds a sample COUNT off the front), so
        # one concatenated row per queue is an exact snapshot.
        buf = ji.buffer.drain()
        if buf is not None:
            jt["buf"] = np.array(buf, np.float32)
            ji.buffer.append(buf)               # put it back (read-only op)
        if ji.vbuffer is not None:
            vbuf = ji.vbuffer.drain()
            if vbuf is not None:
                jt["vbuf"] = np.array(vbuf, np.float32)
                ji.vbuffer.append(vbuf)
        if ji.filt is not None:
            jt["filtz"] = np.asarray(ji.filt._z, np.float32)
        jobs_meta.append(jm)
        jobs_tree[str(i)] = jt

    fq_meta: List[Dict[str, Any]] = []
    fq_tree: Dict[str, Dict[str, np.ndarray]] = {}
    for i, (jid, x, vxq, early) in enumerate(svc._finish_queue):
        fq_meta.append({"job_id": jid, "early": _decision_record(early)})
        ft = {"x": np.array(x, np.float32)}
        if vxq is not None:
            ft["vx"] = np.array(vxq, np.float32)
        fq_tree[str(i)] = ft

    front = svc._front
    hb = None
    if front.heartbeats is not None:
        hb = {"high_water": front.heartbeats._sweep_high_water,
              "workers": [[w.worker_id, int(w.last_step),
                           float(w.last_time), bool(w.alive)]
                          for w in front.heartbeats.workers.values()]}

    meta: Dict[str, Any] = {
        "version": SNAPSHOT_VERSION,
        "bank": {"k": svc._k, "m": svc._m,
                 "fingerprint": _bank_fingerprint(svc)},
        "config": svc._config,
        "scheduler": svc._sched.state_dict(),
        "dirty": [int(s) for s in svc._dirty],
        "jobs": jobs_meta,
        "finish_queue": fq_meta,
        "finished": {j: d.to_record() for j, d in svc._finished.items()},
        "undelivered": {j: d.to_record()
                        for j, d in svc._undelivered.items()},
        "quarantined": dict(svc.quarantined),
        "last_push": dict(front._last_push),
        "heartbeats": hb,
        "stragglers": {j: list(d)
                       for j, d in front.stragglers._durations.items()},
        "counters": {
            "dispatch_count": svc.dispatch_count,
            "repack_count": svc.repack_count,
            "slot_repack_count": svc.slot_repack_count,
            "rescale_count": svc.rescale_count,
            "evicted_count": svc.evicted_count,
            "offline_dispatch_count": svc.offline_dispatch_count,
            "ticks": svc.ticks,
            "retry_count": svc.retry_count,
            "degraded_dispatch_count": svc.degraded_dispatch_count,
            "quarantined_count": svc.quarantined_count,
            "quarantine_dropped": svc.quarantine_dropped,
            "shed_count": svc.shed_count,
            "shed_by_class": dict(svc.shed_by_class),
            "overload_ticks": svc.overload_ticks,
            "worst_rung": svc.worst_rung,
        },
        # overload control plane (PR 9): the ladder's rung/window and
        # the breaker's state machine must survive a crash so recovery
        # of an OVERLOADED service replays the same rung trajectory.
        "overload": (svc._overload.state_dict()
                     if svc._overload is not None else None),
        "breaker": (svc.breaker.state_dict()
                    if svc.breaker is not None else None),
        # WAL watermark: replay records with seq >= this after restoring.
        "watermark": front.trace.next_seq if front.trace is not None
        else 0,
    }

    device: Dict[str, np.ndarray] = {
        "packed_idx": np.asarray(svc._packed_idx, np.int64),
        "rows": np.asarray(svc._rows, np.float32)[:, :, :k_live],
        "ns": np.asarray(svc._ns, np.int32),
        "sx": np.asarray(svc._sx, np.float32),
        "sxx": np.asarray(svc._sxx, np.float32),
        "qlens": np.asarray(svc._qlens, np.int32),
    }
    if svc._moms is not None:
        device["moms"] = np.asarray(svc._moms, np.float32)[:, :, :, :k_live]
    if svc._vstats is not None:
        device["vstats"] = np.asarray(svc._vstats, np.float32)

    return {"meta_json": np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8).copy(),
        "device": device, "jobs": jobs_tree, "fq": fq_tree}


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def restore_service(tree: Dict[str, Any],
                    refs: Union[ReferenceDB, SeriesBank], *,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    trace_log: Optional[TraceLog] = None,
                    retry_policy: Optional[RetryPolicy] = None,
                    chaos: Optional[FaultPlan] = None,
                    breaker: Optional[CircuitBreaker] = None
                    ) -> TuningService:
    """Rehydrate a :func:`snapshot_service` tree into a live service.

    ``refs`` must be the SAME reference bank the snapshot was taken
    against (content-hash enforced).  ``mesh`` may differ from the
    crashed process — the packed device state re-pads to the new device
    count by the same gather a :meth:`TuningService.rescale` uses, and
    every score is a per-column quantity, so the restored service's
    decisions are bitwise identical whatever the mesh.  Process-local
    handles (``trace_log``, ``retry_policy``, ``chaos``, ``breaker``)
    are re-supplied here, not persisted — but the breaker's state
    machine and the overload ladder's rung/window ARE restored onto
    them, so an overloaded service recovers mid-ladder."""
    meta = json.loads(bytes(np.asarray(tree["meta_json"],
                                       np.uint8)).decode())
    if meta["version"] != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot version {meta['version']} != "
                         f"{SNAPSHOT_VERSION}")
    svc = TuningService(refs, mesh=mesh, trace_log=trace_log,
                        retry_policy=retry_policy, chaos=chaos,
                        breaker=breaker, **meta["config"])
    if meta.get("overload") is not None and svc._overload is not None:
        svc._overload.load_state(meta["overload"])
    if meta.get("breaker") is not None and svc.breaker is not None:
        svc.breaker.load_state(meta["breaker"])
    if meta["bank"]["fingerprint"] != _bank_fingerprint(svc):
        raise ValueError("snapshot was taken against a different "
                         "reference bank (content hash mismatch)")

    svc._sched.load_state(meta["scheduler"])
    svc._s_cap = svc._sched.capacity
    svc._dirty = [int(s) for s in meta["dirty"]]

    dev = tree.get("device", {})
    svc._ns = svc._put(np.asarray(dev["ns"], np.int32), (None,))
    svc._sx = svc._put(np.asarray(dev["sx"], np.float32), (None,))
    svc._sxx = svc._put(np.asarray(dev["sxx"], np.float32), (None,))
    if "vstats" in dev:
        svc._vstats = svc._put(np.asarray(dev["vstats"], np.float32),
                               (None, None))
    svc._qlens = np.asarray(dev["qlens"], np.int32).copy()

    # Re-home the packed DP state.  _pack_device_state gathers surviving
    # columns out of arrays aligned with the PREVIOUS _packed_idx — set
    # that to the snapshot's index first and the gather is the identity
    # on the live columns, with fresh +inf/zero padding to the TARGET
    # mesh's bucket width (exactly a rescale's re-pad).
    idx = np.asarray(dev["packed_idx"], np.int64)
    rows = jnp.asarray(np.asarray(dev["rows"], np.float32))
    moms = jnp.asarray(np.asarray(dev["moms"], np.float32)) \
        if "moms" in dev else None
    svc._packed_idx = idx
    svc._pack_device_state(idx, rows, moms)

    jobs_tree = tree.get("jobs", {})
    for i, jm in enumerate(meta["jobs"]):
        jt = jobs_tree.get(str(i), {})
        job = InFlightJob(
            job_id=jm["job_id"], slot=int(jm["slot"]),
            expected_len=int(jm["expected_len"]),
            tick_hz=jm["tick_hz"],
            haar=_wavelet.StreamingHaar(int(jm["expected_len"]))
            if svc.prefilter_top is not None else None)
        job.n = int(jm["n"])
        job.leader = jm["leader"]
        job.stable_for = int(jm["stable_for"])
        job.qos = jm.get("qos", "silver")
        job.degraded_level = int(jm.get("degraded_level", 0))
        job.early = _decision_from(jm["early"], svc)
        if "x" in jt:
            x = np.asarray(jt["x"], np.float32)
            job.x.append(x)
            if job.haar is not None:
                # one-shot rebuild == the original per-chunk updates,
                # bitwise (the pyramid refresh is prefix-deterministic).
                job.haar.update(x)
        if "vx" in jt:
            job.vx.append(np.asarray(jt["vx"], np.float32))
        if "last_sims" in jt:
            job.last_sims = np.asarray(jt["last_sims"], np.float64)
        if "last_probs" in jt:
            job.last_probs = np.asarray(jt["last_probs"], np.float64)
        if "allowed" in jt:
            job.allowed = np.asarray(jt["allowed"], bool)
        svc._front.register(job.job_id)
        ji = svc._front._jobs[job.job_id]
        ji.pushed = int(jm["pushed"])
        ji.buffer.dropped = int(jm["dropped"])
        if "buf" in jt:
            ji.buffer.append(np.asarray(jt["buf"], np.float32))
        if ji.vbuffer is not None:
            ji.vbuffer.dropped = int(jm["vdropped"])
            if "vbuf" in jt:
                ji.vbuffer.append(np.asarray(jt["vbuf"], np.float32))
        if ji.filt is not None and "filtz" in jt:
            ji.filt._z = jnp.asarray(np.asarray(jt["filtz"], np.float32))
        svc._jobs[job.job_id] = job

    fq_tree = tree.get("fq", {})
    for i, fm in enumerate(meta["finish_queue"]):
        ft = fq_tree[str(i)]
        svc._finish_queue.append(
            (fm["job_id"], np.asarray(ft["x"], np.float32),
             np.asarray(ft["vx"], np.float32) if "vx" in ft else None,
             _decision_from(fm["early"], svc)))
    svc._finished = {j: _decision_from(r, svc)
                     for j, r in meta["finished"].items()}
    svc._undelivered = {j: _decision_from(r, svc)
                        for j, r in meta["undelivered"].items()}
    svc.quarantined = dict(meta["quarantined"])

    front = svc._front
    front._last_push = {j: float(t)
                        for j, t in meta["last_push"].items()}
    if front.heartbeats is not None and meta["heartbeats"] is not None:
        front.heartbeats._sweep_high_water = float(
            meta["heartbeats"]["high_water"])
        for wid, step, t, alive in meta["heartbeats"]["workers"]:
            front.heartbeats.workers[wid] = WorkerState(
                wid, last_step=int(step), last_time=float(t),
                alive=bool(alive))
    for j, durs in meta["stragglers"].items():
        for d in durs:
            front.stragglers.record(j, float(d))

    c = meta["counters"]
    svc.dispatch_count = int(c["dispatch_count"])
    svc.repack_count = int(c["repack_count"])
    svc.slot_repack_count = int(c["slot_repack_count"])
    svc.rescale_count = int(c["rescale_count"])
    svc.evicted_count = int(c["evicted_count"])
    svc.offline_dispatch_count = int(c["offline_dispatch_count"])
    svc.ticks = int(c["ticks"])
    svc.retry_count = int(c["retry_count"])
    svc.degraded_dispatch_count = int(c["degraded_dispatch_count"])
    svc.quarantined_count = int(c["quarantined_count"])
    svc.quarantine_dropped = int(c["quarantine_dropped"])
    svc.shed_count = int(c.get("shed_count", 0))
    svc.shed_by_class = {k: int(v)
                         for k, v in c.get("shed_by_class", {}).items()}
    svc.overload_ticks = int(c.get("overload_ticks", 0))
    svc.worst_rung = int(c.get("worst_rung", 0))
    return svc


# ---------------------------------------------------------------------------
# the WAL wrapper
# ---------------------------------------------------------------------------

class RecoverableTuningService:
    """Crash-safe façade: ``TuningService`` + journal + snapshots.

    Layout under ``root``::

        root/wal/    TraceLog journal (push chunks + command events)
        root/ckpt/   CheckpointManager snapshots (two-phase atomic)

    Every mutating command is executed, journaled, then FLUSHED before
    it returns — a command the caller saw succeed is durable, and a
    crash mid-command at worst loses that un-acked command (at-most-once
    on the unflushed tail, never divergence).  Pushes are journaled by
    the ingest layer itself (with variance row and heartbeat stamp);
    everything else becomes one ``append_event`` record, so the journal
    is a total order over commands and ``next_seq`` doubles as the
    schedule position.  :meth:`checkpoint` snapshots the service with
    the current watermark and prunes the journal below it (override
    with ``prune=False``); :meth:`recover` = newest complete snapshot +
    replay of the journal tail, bit-identical to the uninterrupted run
    (see the module docstring for why replay is exact).

    Poisoned pushes need one extra journal record: the push itself is
    rejected atomically (never journaled), but the quarantine eviction
    it triggers DID mutate the service, so the wrapper journals an
    explicit ``quarantine`` event before re-raising — replay re-evicts
    instead of re-poisoning.
    """

    def __init__(self, refs: Union[ReferenceDB, SeriesBank], *,
                 root: str,
                 keep: int = 3,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 chaos: Optional[FaultPlan] = None,
                 _service: Optional[TuningService] = None,
                 **svc_kwargs) -> None:
        import os
        self.root = root
        # effectively unbounded rotation: the journal is bounded by
        # checkpoint-time pruning, not by dropping un-replayed tail.
        self.wal = TraceLog(os.path.join(root, "wal"),
                            max_segments=1 << 30)
        self.manager = CheckpointManager(os.path.join(root, "ckpt"),
                                         keep=keep)
        self.refs = refs
        self.svc = _service if _service is not None else TuningService(
            refs, mesh=mesh, trace_log=self.wal,
            retry_policy=retry_policy, chaos=chaos, **svc_kwargs)
        #: journal records replayed by :meth:`recover` (0 on a cold
        #: start or when the snapshot was current).
        self.replayed = 0

    # -- journaling -----------------------------------------------------------
    def _journal(self, kind: str, payload: Dict[str, Any]) -> None:
        self.wal.append_event(kind, payload)
        self.wal.flush()

    # -- journaled commands ---------------------------------------------------
    def submit(self, job_id: str, expected_len: int,
               tick_hz: Optional[float] = None,
               qos: str = "silver") -> InFlightJob:
        # a SHED submit mutates nothing and is never journaled — the
        # AdmissionShedError propagates before the journal line below.
        job = self.svc.submit(job_id, expected_len, tick_hz=tick_hz,
                              qos=qos)
        self._journal("submit", {"job_id": job_id,
                                 "expected_len": int(expected_len),
                                 "tick_hz": tick_hz, "qos": qos})
        return job

    def push(self, job_id: str, samples, variance=None,
             now: Optional[float] = None) -> None:
        # the accepted chunk is journaled inside IngestFront.push (same
        # sequence space); flush makes it durable before the ack.
        try:
            self.svc.push(job_id, samples, variance=variance, now=now)
        except PoisonedSampleError as err:
            self._journal("quarantine", {"job_id": job_id,
                                         "reason": err.reason})
            raise
        self.wal.flush()

    def tick(self, now: Optional[float] = None):
        # journal AFTER execution so the measured tick latency — the
        # overload ladder's input signal — rides in the record; replay
        # feeds it back via ``tick(latency=...)`` and the restored
        # service walks the exact same rung trajectory.
        out = self.svc.tick(now=now)
        self._journal("tick", {"now": now,
                               "latency": self.svc.last_tick_latency})
        return out

    def finish(self, job_id: str) -> TuneDecision:
        return self.finish_many((job_id,))[job_id]

    def finish_many(self, job_ids) -> Dict[str, TuneDecision]:
        ids = list(job_ids)
        out = self.svc.finish_many(ids)
        self._journal("finish", {"job_ids": ids})
        return out

    def finish_later(self, job_id: str) -> None:
        self.svc.finish_later(job_id)
        self._journal("finish_later", {"job_id": job_id})

    def drain_finishes(self) -> Dict[str, TuneDecision]:
        out = self.svc.drain_finishes()
        self._journal("drain", {})
        return out

    def evict(self, job_id: str) -> Optional[TuneDecision]:
        out = self.svc.evict(job_id)
        self._journal("evict", {"job_id": job_id})
        return out

    def sweep_stalled(self, now: float):
        out = self.svc.sweep_stalled(now)
        self._journal("sweep", {"now": float(now)})
        return out

    # -- read-only passthroughs ----------------------------------------------
    def __getattr__(self, name: str):
        # counters, properties, diagnostics — anything not journaled.
        if name == "svc":               # not set yet (mid-construction)
            raise AttributeError(name)
        return getattr(self.svc, name)

    # -- snapshots ------------------------------------------------------------
    def checkpoint(self, step: Optional[int] = None,
                   prune: bool = True) -> int:
        """Durable snapshot of the full service at the current journal
        watermark.  Returns the step id.  ``prune=True`` (default) drops
        journal segments wholly below the watermark — they precede every
        snapshot the manager retains only when ``keep`` snapshots agree,
        so pruning uses the OLDEST retained snapshot's watermark.

        Refuses (``RuntimeError``) while the journal is DEGRADED
        (:attr:`TraceLog.journal_degraded` — flush failing with
        ``OSError``): commands the caller saw succeed are then only in
        memory, and stamping a watermark past ``durable_seq`` would
        silently drop them from every future recovery."""
        self.wal.flush()
        if self.wal.journal_degraded:
            raise RuntimeError(
                "journal degraded: commands past durable_seq="
                f"{self.wal.durable_seq} are not on disk; refusing to "
                "checkpoint a watermark that would orphan them "
                f"(write errors: {self.wal.journal_write_errors})")
        if step is None:
            latest = self.manager.latest_step()
            step = 0 if latest is None else latest + 1
        tree = snapshot_service(self.svc)
        self.manager.save(step, tree)
        if prune:
            floors = []
            for s in self.manager.steps():
                try:
                    t, _ = load_checkpoint_tree(self.manager.root, step=s,
                                                verify=False)
                    floors.append(json.loads(bytes(np.asarray(
                        t["meta_json"], np.uint8)).decode())["watermark"])
                except Exception:        # torn/partial step: keep journal
                    floors.append(0)
            if floors:
                self.wal.prune(min(floors))
        return step

    # -- recovery -------------------------------------------------------------
    @classmethod
    def recover(cls, refs: Union[ReferenceDB, SeriesBank], *,
                root: str,
                keep: int = 3,
                mesh: Optional[jax.sharding.Mesh] = None,
                retry_policy: Optional[RetryPolicy] = None,
                chaos: Optional[FaultPlan] = None,
                breaker: Optional[CircuitBreaker] = None,
                **svc_kwargs) -> "RecoverableTuningService":
        """Rebuild the service a crashed process was running: newest
        complete snapshot (if any) + replay of every journal record at
        or past its watermark.  With no snapshot the journal replays
        from the beginning against a fresh service.  The restored
        service is bit-identical to the crashed one's last DURABLE
        state — same scores, probabilities, decisions, counters, and
        schedule position — even when ``mesh`` differs from the crashed
        process's."""
        import os
        wal = TraceLog(os.path.join(root, "wal"), max_segments=1 << 30)
        watermark = 0
        svc: Optional[TuningService] = None
        try:
            tree, _ = load_checkpoint_tree(os.path.join(root, "ckpt"))
        except FileNotFoundError:
            tree = None
        if tree is not None:
            svc = restore_service(tree, refs, mesh=mesh, trace_log=wal,
                                  retry_policy=retry_policy, chaos=chaos,
                                  breaker=breaker)
            watermark = json.loads(bytes(np.asarray(
                tree["meta_json"], np.uint8)).decode())["watermark"]
        else:
            svc = TuningService(refs, mesh=mesh, trace_log=wal,
                                retry_policy=retry_policy, chaos=chaos,
                                breaker=breaker, **svc_kwargs)

        out = cls.__new__(cls)
        out.root = root
        out.wal = wal
        out.manager = CheckpointManager(os.path.join(root, "ckpt"),
                                        keep=keep)
        out.refs = refs
        out.svc = svc
        out.replayed = _replay(svc, wal, watermark)
        return out


def _replay(svc: TuningService, wal: TraceLog, watermark: int) -> int:
    """Re-execute journal records with ``seq >= watermark`` against a
    restored service, with journaling SUPPRESSED (the records are
    already durable; re-journaling would double them).  Returns the
    number of records replayed."""
    records = [r for r in wal.records(since=watermark)]
    # suppress journaling (the records are already durable), chaos
    # injection (replayed samples are the post-corruption originals;
    # re-corrupting them would diverge from the crashed run) AND
    # admission control (a journaled submit was by definition admitted;
    # re-gating it against the restored rung could shed it).
    trace, svc._front.trace = svc._front.trace, None
    chaos, svc.chaos = svc.chaos, None
    suppressed = svc._admission_suppressed
    svc._admission_suppressed = True
    try:
        for _, kind, payload in records:
            if kind == "push":
                svc.push(payload["job_id"], payload["samples"],
                         variance=payload.get("variance"),
                         now=payload.get("now"))
            elif kind == "submit":
                svc.submit(payload["job_id"],
                           int(payload["expected_len"]),
                           tick_hz=payload["tick_hz"],
                           qos=payload.get("qos", "silver"))
            elif kind == "tick":
                # replay the MEASURED latency (absent in pre-PR-9
                # journals: wall-clock is re-measured, harmless when no
                # overload controller is configured).
                svc.tick(now=payload["now"],
                         latency=payload.get("latency"))
            elif kind == "finish":
                svc.finish_many(payload["job_ids"])
            elif kind == "finish_later":
                svc.finish_later(payload["job_id"])
            elif kind == "drain":
                svc.drain_finishes()
            elif kind == "evict":
                svc.evict(payload["job_id"])
            elif kind == "sweep":
                svc.sweep_stalled(float(payload["now"]))
            elif kind == "quarantine":
                svc._quarantine(payload["job_id"], payload["reason"])
            else:
                raise ValueError(f"unknown journal record kind {kind!r}")
    finally:
        svc._front.trace = trace
        svc.chaos = chaos
        svc._admission_suppressed = suppressed
    return len(records)
