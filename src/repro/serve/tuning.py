"""Streaming self-tuning service: match in-flight jobs WHILE they execute.

The paper's end goal is acting on a job *before* it finishes: compare the
utilization pattern observed so far against the reference database, and as
soon as the most probable execution pattern is clear, transfer that
workload's tuned configuration.  The offline ``AutoTuner.match`` scores
complete series only; this service runs the matching phase online.

Layered serving stack
---------------------
The service is a continuous-batching front split into four layers; this
module is the tick engine and verdict renderer, and the facade that wires
the stack together:

* **ingest** (``serve.ingest``): bounded per-job sample queues with
  backpressure, optional rotated trace persistence, the causal streaming
  Chebyshev filter, and heartbeat/straggler stamping of every push.
* **scheduler** (``serve.scheduler``): slot admission/eviction with
  power-of-two S-axis capacity buckets (the device state is sized to the
  ACTIVE job count, growing and compact-shrinking by on-device gathers —
  the S twin of the prefilter's K-axis re-pack), plus tick-rate cohorts
  so ``tick(now=...)`` drains a 4 Hz trace only on its own beats.
* **tick engine** (this module + ``core.dtw``): the device-resident
  fused scored-extend dispatch, unchanged numerics.
* **verdicts** (this module): matrix-free batched finish rendering.

Tick engine (device-resident tick)
----------------------------------
* Each in-flight job occupies one slot of the current S bucket.  Its
  incremental DTW state — the DP row against the whole reference bank,
  plus the warp-path correlation moments of every row cell — lives
  stacked with every other job's as ``[S, M, K]`` / ``[3, S, M, K]``
  device arrays (K last, so the reference axis both vectorizes and
  shards).
* :meth:`TuningService.tick` drains every due job's buffered samples in
  **one** jitted dispatch of the wavefront chunk-extend (``core.dtw``),
  with prefix scoring FUSED into the same dispatch: the device returns a
  ``[S, K]`` open-end warp-correlation array, not DP rows.
  ``dispatch_count`` records the invariant: dispatches == ticks(with
  data) no matter how many jobs are in flight.  On TPU backends BOTH
  tick flavors route to the Pallas streaming kernels
  (``kernels.dtw.stream``).
* ``mesh=`` shards the bank: a 1-D device mesh partitions the ``[M, K]``
  reference bank and every ``[.., K]`` state slab over its single axis
  via ``sharding.compat.shard_map``.  The sharded tick is bit-identical
  to the unsharded one and remains ONE dispatch.  :meth:`rescale`
  re-homes the state onto a different mesh mid-flight (or back to a
  single device) — the hook a ``runtime.fault.ElasticController``
  decision drives when hosts die or join.
* ``prefilter_top=`` prunes the bank at large K exactly as before (the
  streaming-Haar ranking with the in-flight DTW soundness veto, sticky
  per job, bucket-padded K-axis re-packs counted in ``repack_count``).
  S-axis slot re-packs are counted in ``slot_repack_count``; neither
  ever inflates ``dispatch_count``.
* The early-decision rule is confidence/abstain: emit a
  :class:`core.tuner.TuneDecision` only once the leading workload has
  cleared the threshold AND led the runner-up by ``margin`` for
  ``stable_ticks`` consecutive scoring ticks, with at least
  ``min_fraction`` of the job observed (>= 2 distinct workloads
  required — no vacuous margins).

Probabilistic (uncertain-series) mode
-------------------------------------
``min_probability=`` switches the decision gates from the point
correlation to a calibrated match probability (arXiv:1112.5505): pushes
may carry per-sample measurement variances (``push(..., variance=)``;
unsupplied variances default to the causal filter's squared residual,
or 0.0 without ``denoise``), the tick's moment slab doubles to SIX
channels ([6, S, M, K]: sy, syy, sxy and their variance-weighted twins
svy, svyy, svxy carried along the SAME backtrack-identical warp path)
beside a per-slot [S, 3] (sv, svx, svxx) fold, and the fused dispatch
returns a ``[S, K]`` probability array
``P[true warp correlation >= threshold]`` beside the scores
(``core.dtw._prob_from_moments`` — one factored tail shared by the
streaming tick, the offline jnp scorer and both Pallas kernels).  The
leader is still ranked by point correlation, but the commit gate
becomes ``P >= min_probability`` (in flight AND at the final verdict),
so the service *abstains* while the posterior is flat instead of
committing on a lucky noisy prefix; the emitted ``TuneDecision``
records the probability.  At zero input variance the probability is
exactly 1.0 iff the correlation clears ``threshold``, so probabilistic
decisions reduce bitwise to the point rule.  The exact tick's compiled
graph is untouched when the mode is off (separate jitted entry
points).

Two probability tails share that machinery (``prob_mode=``):

* ``"exact"`` (default) — the six-channel slab above.  This is the tail
  that VERDICTS: :meth:`finish` / :meth:`finish_many` always recompute
  final probabilities offline through the exact six-channel scorer,
  whatever mode served the ticks, so verdict probabilities are bitwise
  independent of ``prob_mode``.
* ``"approx"`` — the tail that SERVES under tight tick budgets: the
  slab carries ONE variance channel (svy, the path-accumulated sigma^2
  proxy) beside (sy, syy, sxy), and the score tail reconstructs
  svyy/svxy from the per-slot (sv, svx, svxx) folds
  (``core.dtw._prob_from_moments_approx``), cutting per-cell slab
  traffic from 7 channels to 5 (~1.3x a scored tick instead of ~2x).
  In-flight probabilities sit within a small tolerance band of the
  exact tail (pinned by the calibration tests/bench) and reduce
  BITWISE to it at zero input variance; early decisions gate on the
  approx probability, final verdicts stay exact.  The overload ladder
  exposes the same trade as a rung: an exact-mode service capped at
  ``approx_prob`` keeps shipping (approximate) probabilities instead
  of losing them entirely (see ``serve.overload``).

Verdicts
--------
:meth:`TuningService.finish` recomputes the final verdict offline from
the job's full (causally filtered) query — matrix-free: one
``dtw.dtw_score_bank_many`` dispatch carries the warp-path correlation
moments through the DP on device and scores at the closed alignment
endpoint.  Verdicts BATCH: :meth:`finish_many` renders J decisions from
one drain tick + one dispatch, and :meth:`finish_later` parks completed
jobs in a drain queue (slot freed immediately) that
:meth:`drain_finishes` — or an automatic drain at ``finish_batch``
pending verdicts — renders in one dispatch, so
``offline_dispatch_count`` amortizes instead of growing 1:1 with
completions; batched and sequential verdicts are bit-identical by
construction.  When a :class:`ReferenceDB` backs the service, each
decision is recorded into the DB's decision history.

Multi-tenant serving
--------------------
:class:`MultiTenantTuningService` keys jobs to per-tenant reference
banks at submit: each tenant owns an isolated tick engine (its own
bank, device state and counters), the front routes
push/tick/finish by job id, and a tick dispatches only for engines
whose due jobs have data — total dispatches are bounded by data-ticks x
tenants (x cohorts within each engine).

The hard invariant across ALL of the above: a job's decisions (early
and final — matched workload, correlation, ``decided_at_fraction``) are
bit-for-bit independent of slot packing, admission order, tick-rate
cohort, capacity history, sharding and verdict batching.  Per-job DP
state is row-independent and per-reference, so none of the batching
machinery can touch the numbers.

``denoise=True`` pushes raw samples through the causal streaming
Chebyshev filter (``filters.StreamingFilter``) before matching.
Reference banks are expected to be stored pre-processed (as
``AutoTuner.profile`` does).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dtw as _dtw
from ..core import wavelet as _wavelet
from ..core.database import ReferenceDB, SeriesBank
from ..core.similarity import MATCH_THRESHOLD
from ..core.tuner import TuneDecision, _RowBuffer
from ..runtime.chaos import FaultPlan, InjectedDispatchError
from ..runtime.retry import CircuitBreaker, RetryPolicy, call_with_retry
from ..sharding.compat import shard_map as _shard_map
from .ingest import IngestFront, PoisonedSampleError, TraceLog
from .overload import (RUNGS, AdmissionController, AdmissionPolicy,
                       AdmissionShedError, OverloadConfig,
                       OverloadController)
from .scheduler import SlotScheduler


def _transient_errors() -> tuple:
    """Exception classes a dispatch retry treats as transient: injected
    chaos faults plus the runtime's real device-side failure class."""
    errs = [InjectedDispatchError]
    rt = getattr(jax.errors, "JaxRuntimeError", None)
    if rt is not None:
        errs.append(rt)
    return tuple(errs)

__all__ = ["InFlightJob", "TuningService", "MultiTenantTuningService"]


@dataclasses.dataclass
class InFlightJob:
    """Host-side bookkeeping for one slot (device state lives stacked in
    the service's ``[S, M, K]`` arrays; buffering/filtering lives in the
    ingest layer)."""
    job_id: str
    slot: int
    expected_len: int
    tick_hz: Optional[float] = None
    x: _RowBuffer = dataclasses.field(default_factory=_RowBuffer)
    n: int = 0
    leader: Optional[str] = None
    stable_for: int = 0
    early: Optional[TuneDecision] = None
    #: per-sample measurement variances aligned with ``x`` (filled only
    #: in probabilistic mode; empty otherwise).
    vx: _RowBuffer = dataclasses.field(default_factory=_RowBuffer)
    #: last [K] on-device prefix-score row seen for this job (float64 on
    #: the host side; None until the first scoring tick touches the job).
    last_sims: Optional[np.ndarray] = None
    #: last [K] match-probability row (probabilistic mode only).
    last_probs: Optional[np.ndarray] = None
    #: streaming-Haar prefix coefficients of the (filtered) query — the
    #: wavelet prefilter's per-job transform state (None w/o prefilter).
    haar: Optional[_wavelet.StreamingHaar] = None
    #: bool [K] over the FULL bank: references still live for this job.
    #: None means "all" (prefilter off, or not engaged yet).  Monotone:
    #: once False a reference never comes back for this job, so its DP
    #: column may leave the packed tick without ever going stale for us.
    allowed: Optional[np.ndarray] = None
    #: QoS class (bronze/silver/gold) the job was admitted under.
    qos: str = "silver"
    #: staleness marker set by degraded (ladder) ticks — monotone per
    #: job, because a skipped side-channel contribution can never be
    #: recovered in flight.  0 = all channels exact; 1 = variance
    #: channels stale (probability-gated early decisions suppressed;
    #: point scores and the prefilter veto stay exact); 2 = all moment
    #: channels stale (``last_sims`` frozen, no early decisions ever —
    #: the final verdict recomputes offline from the full query and is
    #: bitwise unchanged).
    degraded_level: int = 0

    @property
    def fraction_seen(self) -> float:
        return self.n / max(self.expected_len, 1)


class TuningService:
    """Multiplexed online matcher over a fixed reference bank.

    ``refs`` is a :class:`ReferenceDB` (bank + config transfer) or a bare
    :class:`SeriesBank` (matching only).  ``min_probability=`` enables the
    probabilistic (uncertain-series) decision rule — see the module
    docstring; it requires ``score_in_flight=True`` and gates BOTH the
    early decision and the final verdict on the leader's calibrated match
    probability instead of its point correlation (``threshold`` keeps its
    role as the correlation level the probability is calibrated
    against).  ``prob_mode="approx"`` (requires ``min_probability=``)
    serves the IN-FLIGHT probability through the four-channel
    approximate tail — ~1.3x a scored tick instead of ~2x, probabilities
    within a calibrated tolerance band of exact — while :meth:`finish` /
    :meth:`finish_many` verdicts stay on the exact six-channel tail,
    bitwise unchanged (see the module docstring's "Two probability
    tails").  ``score_in_flight=False`` is the
    distance-only throughput mode: the tick skips the fused scoring (so no
    early decisions; :meth:`finish` still renders the offline verdict) and
    carries no moment slabs — marginally cheaper at very large K.
    ``collect_rows`` is accepted as a deprecated alias from the PR-2 API
    (rows are never collected any more; the name survives because the
    semantics — "score while in flight" — do).

    ``mesh=`` (a 1-D ``jax.sharding.Mesh``) partitions the reference axis
    K over the mesh devices; the bank is padded up to a device-count
    multiple internally and padded rows never surface in scores.

    ``prefilter_top=P`` enables the streaming wavelet prefilter: ticks
    dispatch over the pruned survivor union instead of all K references
    (see the module docstring for the pruning rule and its soundness
    veto).  Composes with ``mesh=``; off by default.

    ``finish_batch=`` sets the drain-queue auto-flush threshold: once
    that many :meth:`finish_later` verdicts are pending they are rendered
    in one batched offline dispatch (:meth:`drain_finishes` flushes
    early).

    Serving-front knobs (the layered stack):

    * ``slots`` caps concurrent jobs; with ``elastic_slots=True`` (the
      default) the device state is sized to the power-of-two bucket of
      the ACTIVE job count and grows/compact-shrinks by S-axis device
      gathers (``slot_repack_count``), instead of paying for ``slots``
      rows around the clock.  ``elastic_slots=False`` pins the
      pre-refactor fixed-capacity layout.
    * ``queue_limit``/``queue_policy`` bound each job's ingest queue
      (``"reject"`` raises ``serve.ingest.BackpressureError`` at the
      producer, ``"drop_oldest"`` sheds and counts).
    * ``trace_log`` (a :class:`serve.ingest.TraceLog`) persists every
      accepted chunk with size/count rotation.
    * ``heartbeat_timeout`` arms per-job heartbeats: pushes carrying a
      ``now=`` timestamp beat the tracker, and :meth:`sweep_stalled`
      evicts jobs whose agent went silent (slot freed, no verdict,
      survivors untouched).
    * ``submit(..., tick_hz=)`` assigns the job to a tick-rate cohort;
      ``tick(now=...)`` drains only due cohorts.
    """

    def __init__(self, refs: Union[ReferenceDB, SeriesBank], *,
                 band: Optional[int] = None,
                 threshold: float = MATCH_THRESHOLD,
                 min_probability: Optional[float] = None,
                 prob_mode: str = "exact",
                 margin: float = 0.02, stable_ticks: int = 3,
                 min_fraction: float = 0.15, slots: int = 8,
                 denoise: bool = False,
                 score_in_flight: Optional[bool] = None,
                 collect_rows: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 prefilter_top: Optional[int] = None,
                 prefilter_margin: float = 0.05,
                 prefilter_min_fraction: float = 0.1,
                 prefilter_coeffs: int = 64,
                 finish_batch: int = 16,
                 elastic_slots: bool = True,
                 queue_limit: Optional[int] = None,
                 queue_policy: str = "reject",
                 trace_log: Optional[TraceLog] = None,
                 heartbeat_timeout: Optional[float] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 chaos: Optional[FaultPlan] = None,
                 overload: Union[OverloadConfig, OverloadController,
                                 Dict, None] = None,
                 admission: Union[AdmissionPolicy, AdmissionController,
                                  Dict, None] = None,
                 breaker: Optional[CircuitBreaker] = None) -> None:
        if isinstance(refs, ReferenceDB):
            self.db: Optional[ReferenceDB] = refs
            self.bank = refs.bank()
        else:
            self.db = None
            self.bank = refs
        if len(self.bank) == 0:
            raise ValueError("empty reference bank")
        if score_in_flight is None:
            score_in_flight = True if collect_rows is None else collect_rows
        self._labels: Tuple[str, ...] = self.bank.labels or tuple(
            f"ref{k}" for k in range(len(self.bank)))
        self._n_workloads = len(set(self._labels))
        if min_probability is not None:
            if not (0.0 < min_probability <= 1.0):
                raise ValueError("min_probability must be in (0, 1]")
            if not score_in_flight:
                raise ValueError("min_probability needs "
                                 "score_in_flight=True (the probability "
                                 "rides the fused scoring tick)")
        if prob_mode not in ("exact", "approx"):
            raise ValueError("prob_mode must be 'exact' or 'approx', got "
                             f"{prob_mode!r}")
        if prob_mode == "approx" and min_probability is None:
            raise ValueError("prob_mode='approx' needs min_probability= "
                             "(the approximate tail serves the in-flight "
                             "probability gate)")
        self.band = band
        self.threshold = threshold
        self.min_probability = min_probability
        self.prob_mode = prob_mode
        self.margin = margin
        self.stable_ticks = stable_ticks
        self.min_fraction = min_fraction
        self.slots = slots
        self.denoise = denoise
        self.score_in_flight = score_in_flight
        self.mesh = mesh
        if prefilter_top is not None and prefilter_top < 1:
            raise ValueError("prefilter_top must be >= 1 (or None)")
        if prefilter_top is not None and not score_in_flight:
            # without the fused tick's scores there is no DTW veto: the
            # warp-blind wavelet ranking alone evicts warp-matching
            # references (the paper's exim-vs-wordcount case), and sticky
            # pruning makes that irrecoverable in flight.
            raise ValueError("prefilter_top needs score_in_flight=True "
                             "(the prune rule's soundness veto runs on "
                             "the in-flight DTW scores)")
        self.prefilter_top = prefilter_top
        self.prefilter_margin = prefilter_margin
        self.prefilter_min_fraction = prefilter_min_fraction
        self.prefilter_coeffs = prefilter_coeffs
        if finish_batch < 1:
            raise ValueError("finish_batch must be >= 1")
        self.finish_batch = finish_batch
        self.retry_policy = retry_policy
        self.chaos = chaos
        self.breaker = breaker
        self._transient = _transient_errors()
        # overload control plane: the degradation-ladder controller and
        # the admission gate (see serve.overload's runbook docstring).
        # Dict forms are accepted so a snapshot's JSON config rebuilds
        # them; passing a live controller keeps its walked state.
        if isinstance(overload, dict):
            overload = OverloadConfig(**overload)
        if isinstance(overload, OverloadConfig):
            overload = OverloadController(overload)
        self._overload: Optional[OverloadController] = overload
        if isinstance(admission, dict):
            admission = AdmissionPolicy(**admission)
        if isinstance(admission, AdmissionPolicy):
            admission = AdmissionController(admission)
        self._admission: Optional[AdmissionController] = admission
        # replay suppression (serve.recovery): a replayed submit must
        # never be shed — the live run already admitted it.
        self._admission_suppressed = False
        # the serializable constructor config — what serve.recovery
        # persists in a snapshot's manifest so a restoring process can
        # rebuild an identical service without the caller re-supplying
        # every knob (mesh/trace_log/retry/chaos are process-local and
        # re-supplied at restore).
        self._config: Dict[str, object] = dict(
            band=band, threshold=threshold,
            min_probability=min_probability, prob_mode=prob_mode,
            margin=margin,
            stable_ticks=stable_ticks, min_fraction=min_fraction,
            slots=slots, denoise=denoise, score_in_flight=score_in_flight,
            prefilter_top=prefilter_top, prefilter_margin=prefilter_margin,
            prefilter_min_fraction=prefilter_min_fraction,
            prefilter_coeffs=prefilter_coeffs, finish_batch=finish_batch,
            elastic_slots=elastic_slots, queue_limit=queue_limit,
            queue_policy=queue_policy,
            heartbeat_timeout=heartbeat_timeout,
            overload=(dataclasses.asdict(self._overload.config)
                      if self._overload is not None else None),
            admission=(dataclasses.asdict(self._admission.policy)
                       if self._admission is not None else None))

        k, m = self.bank.series.shape
        self._k = k
        self._m = m
        ndev = 1
        axis = None
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError("TuningService needs a 1-D mesh (one bank "
                                 f"axis); got axes {mesh.axis_names}")
            axis = mesh.axis_names[0]
            ndev = mesh.devices.size
        self._ndev = ndev
        self._axis = axis
        # full-bank host copies: the pruned tick re-packs (gathers) state
        # and bank columns from these, so the full [M, K] layout is the
        # single source of truth whatever subset is currently on device.
        self._full_series_t = np.ascontiguousarray(
            self.bank.series.T.astype(np.float32))
        self._full_lengths = self.bank.lengths.astype(np.int32)
        # admission cost proxy: expected job length over the bank's mean
        # reference length (the cumulative-CPU estimate stand-in).
        self._mean_ref_len = float(np.mean(self._full_lengths))
        self._wcoeff_cache: Dict[Tuple[int, int], np.ndarray] = {}
        self._jobs: Dict[str, InFlightJob] = {}
        # slots awaiting their fresh-state reset (applied in one masked
        # op at the top of the next data tick, see submit()).
        self._dirty: List[int] = []

        # serving-front layers: ingest (queues/filter/trace/heartbeats)
        # and the S-axis slot scheduler (buckets, cohorts).
        self._front = IngestFront(
            denoise=denoise, queue_limit=queue_limit,
            queue_policy=queue_policy, trace=trace_log,
            heartbeat_timeout=heartbeat_timeout,
            track_variance=min_probability is not None)
        self._sched = SlotScheduler(slots, elastic=elastic_slots)
        self._s_cap = self._sched.capacity

        self._ns = self._put(np.zeros((self._s_cap,), np.int32), (None,))
        self._sx = self._put(np.zeros((self._s_cap,), np.float32), (None,))
        self._sxx = self._put(np.zeros((self._s_cap,), np.float32), (None,))
        # probabilistic mode: per-slot (sv, svx, svxx) variance folds —
        # K-independent like sx/sxx, so replicated under a mesh.
        self._vstats = self._put(
            np.zeros((self._s_cap, 3), np.float32), (None, None)) \
            if min_probability is not None else None
        self._qlens = np.zeros((self._s_cap,), np.int32)
        self._packed_idx = np.arange(k)
        self._pack_device_state(self._packed_idx, rows=None, moms=None)
        # per-mode tick callables, built lazily: the configured mode is
        # compiled eagerly (the pre-overload behavior); the degraded
        # ladder modes compile on first use under load.
        self._tick_fns: Dict[str, Tuple] = {}
        self._tick_fn, self._tick_fallback = \
            self._tick_fn_for(self._base_mode())

        #: device dispatches issued by :meth:`tick` — the scaling invariant
        #: is one dispatch per data-carrying tick, however many jobs are
        #: live (and however many devices the bank is sharded over).
        self.dispatch_count = 0
        #: prefilter re-pack events: the (occasional) device uploads that
        #: shrink or re-grow the packed bank/state when the survivor set
        #: changes.  Counted SEPARATELY from ``dispatch_count`` — a
        #: re-pack is state motion, not a tick dispatch, and the
        #: dispatches == data-ticks invariant must survive pruning.
        self.repack_count = 0
        #: S-axis capacity changes (elastic grow / compact-shrink, plus
        #: stall evictions' compactions) — the slot twin of
        #: ``repack_count``, likewise never a dispatch.
        self.slot_repack_count = 0
        #: mesh re-homes driven by :meth:`rescale`.
        self.rescale_count = 0
        #: jobs dropped by :meth:`evict`/:meth:`sweep_stalled` (no
        #: verdict rendered).
        self.evicted_count = 0
        #: offline verdict dispatches (the matrix-free
        #: ``dtw.dtw_score_bank_many`` recompute): one per
        #: :meth:`finish`, but one per *drain* for :meth:`finish_many` /
        #: the :meth:`finish_later` queue — the counter grows sublinearly
        #: in completions when verdicts batch.
        self.offline_dispatch_count = 0
        self.ticks = 0
        #: failed dispatch attempts absorbed by the retry/backoff wrapper
        #: (transient device errors + injected chaos faults).
        self.retry_count = 0
        #: dispatches that exhausted their retries and were served by the
        #: degraded fallback path (Pallas kernel -> jnp wavefront twin —
        #: bit-identical results, degraded latency).
        self.degraded_dispatch_count = 0
        #: True when the most recent tick/verdict dispatch came from the
        #: fallback path — the per-tick ``degraded`` surface.
        self.last_tick_degraded = False
        #: {job_id: reason} for jobs evicted by the input-poison
        #: quarantine (NaN/Inf samples, bad variances).  Survivors are
        #: bit-identical to a run that never saw the poisoned job's tail:
        #: per-job state is row-independent and the poisoned push itself
        #: was rejected atomically before touching any queue.
        self.quarantined: Dict[str, str] = {}
        self.quarantined_count = 0
        #: pushes silently dropped because their job was already
        #: quarantined (a sick agent keeps pushing; the service must not
        #: crash on it, and must not resurrect the job either).
        self.quarantine_dropped = 0
        #: submits refused by admission control (monitoring only: a shed
        #: submit is never journaled — the job simply never existed as
        #: far as recovery is concerned).
        self.shed_count = 0
        self.shed_by_class: Dict[str, int] = {}
        #: top-level ticks observed while the ladder was above rung 0.
        self.overload_ticks = 0
        #: high-water ladder rung reached (see serve.overload.RUNGS).
        self.worst_rung = 0
        #: measured wall-clock latency of the most recent top-level tick
        #: (plus any chaos-injected slowdown) — what the ladder observes
        #: and what the recovery journal records per tick command.
        self.last_tick_latency = 0.0
        # early decisions emitted by a tick the caller didn't see (e.g.
        # the internal drain tick of another job's finish()); surfaced by
        # the next tick() return so no decision is ever dropped.
        self._undelivered: Dict[str, TuneDecision] = {}
        # deferred-finish drain queue: (job_id, full query, variances or
        # None, early decision) tuples awaiting one batched verdict
        # dispatch, plus auto-drained decisions not yet handed to the
        # caller.
        self._finish_queue: List[Tuple[str, np.ndarray,
                                       Optional[np.ndarray],
                                       Optional[TuneDecision]]] = []
        self._finished: Dict[str, TuneDecision] = {}

    # -- packed device state (full bank or pruned survivor subset) -----------
    def _put(self, arr, spec):
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec(*spec)))

    def _k_bucket(self, k: int) -> int:
        """Padded width of a pruned pack: power-of-two (so re-packs cycle
        through at most log2(K) compiled tick shapes), at least one VPU
        sublane tile, and a device-count multiple so the shard_map fan-out
        still divides evenly."""
        kp = max(8, 1 << (max(k, 1) - 1).bit_length())
        return kp + ((-kp) % self._ndev)

    def _pack_device_state(self, idx: np.ndarray, rows, moms) -> None:
        """(Re)build the device-resident tick arrays over bank columns
        ``idx`` (full-bank order preserved).  ``rows``/``moms`` carry the
        surviving columns' DP state ([S, M, K_old] / [3, S, M, K_old]
        DEVICE arrays aligned with the PREVIOUS ``_packed_idx``) —
        re-packing gathers the surviving columns on device, so a re-pack
        never round-trips the state slabs through the host.  Columns
        without prior state start fresh (+inf row, zero moments) — exact
        for jobs that have consumed nothing, don't-care for jobs whose
        prefilter already dropped the reference (their scores for it are
        masked on the way out of every tick).

        The full pack keeps the legacy padding (K up to a device-count
        multiple); pruned packs pad to :meth:`_k_bucket`.
        """
        k_new, m, axis = len(idx), self._m, self._axis
        kp = self._k + ((-self._k) % self._ndev) if k_new == self._k \
            else self._k_bucket(k_new)
        series_t = np.zeros((m, kp), np.float32)
        series_t[:, :k_new] = self._full_series_t[:, idx]
        lengths = np.ones((kp,), np.int32)
        lengths[:k_new] = self._full_lengths[idx]
        self._bank_t = self._put(series_t, (None, axis))
        self._lengths = self._put(lengths, (axis,))
        if rows is None:
            self._rows = self._put(
                np.full((self._s_cap, m, kp), float(_dtw._INF), np.float32),
                (None, None, axis))
            if self.min_probability is None:
                nch = 3
            else:
                nch = 4 if self.prob_mode == "approx" else 6
            self._moms = self._put(
                np.zeros((nch, self._s_cap, m, kp), np.float32),
                (None, None, None, axis)) if self.score_in_flight else None
        else:
            pos = np.full((self._k,), -1, np.int64)
            pos[self._packed_idx] = np.arange(len(self._packed_idx))
            src = np.concatenate([pos[idx], np.full((kp - k_new,), -1)])
            gather = jnp.asarray(np.maximum(src, 0), jnp.int32)
            fresh = jnp.asarray(src < 0)
            new_rows = jnp.where(fresh[None, None, :],
                                 _dtw._INF, jnp.take(rows, gather, axis=2))
            self._rows = self._put(new_rows, (None, None, axis))
            if moms is not None:
                self._moms = self._put(
                    jnp.where(fresh[None, None, None, :], 0.0,
                              jnp.take(moms, gather, axis=3)),
                    (None, None, None, axis))
        self._packed_idx = np.asarray(idx)
        self._kp = kp

    def _repack_slots(self, src: np.ndarray) -> None:
        """Apply an S-axis gather plan from the scheduler (new slot ->
        old slot, -1 = fresh) to every slot-indexed array — on device,
        mirroring the K-axis `_pack_device_state` gather.  Per-job DP
        state is row-independent, so a slot move is bit-exact; fresh
        rows get the same +inf/zero init a ``submit`` reset would
        write."""
        axis = self._axis
        gather = jnp.asarray(np.maximum(src, 0), jnp.int32)
        fresh = jnp.asarray(src < 0)
        self._rows = self._put(
            jnp.where(fresh[:, None, None], _dtw._INF,
                      jnp.take(self._rows, gather, axis=0)),
            (None, None, axis))
        if self._moms is not None:
            self._moms = self._put(
                jnp.where(fresh[None, :, None, None], 0.0,
                          jnp.take(self._moms, gather, axis=1)),
                (None, None, None, axis))
        self._ns = self._put(jnp.where(fresh, 0,
                                       jnp.take(self._ns, gather, axis=0)),
                             (None,))
        self._sx = self._put(jnp.where(fresh, 0.0,
                                       jnp.take(self._sx, gather, axis=0)),
                             (None,))
        self._sxx = self._put(jnp.where(fresh, 0.0,
                                        jnp.take(self._sxx, gather, axis=0)),
                              (None,))
        if self._vstats is not None:
            self._vstats = self._put(
                jnp.where(fresh[:, None], 0.0,
                          jnp.take(self._vstats, gather, axis=0)),
                (None, None))
        self._qlens = np.where(src >= 0, self._qlens[np.maximum(src, 0)],
                               0).astype(np.int32)
        self._s_cap = len(src)
        self.slot_repack_count += 1

    def _apply_resets(self) -> None:
        """Fresh-initialize every slot submitted since the last data tick
        (+inf DP row, zero moments/query stats) in ONE masked op per
        array.  Runs before any state gather or dispatch, so lazy resets
        are indistinguishable from the eager per-submit resets they
        replace."""
        if not self._dirty:
            return
        axis = self._axis
        mask = np.zeros((self._s_cap,), bool)
        mask[self._dirty] = True
        md = jnp.asarray(mask)
        self._rows = self._put(
            jnp.where(md[:, None, None], _dtw._INF, self._rows),
            (None, None, axis))
        if self._moms is not None:
            self._moms = self._put(
                jnp.where(md[None, :, None, None], 0.0, self._moms),
                (None, None, None, axis))
        self._ns = self._put(jnp.where(md, 0, self._ns), (None,))
        self._sx = self._put(jnp.where(md, 0.0, self._sx), (None,))
        self._sxx = self._put(jnp.where(md, 0.0, self._sxx), (None,))
        if self._vstats is not None:
            self._vstats = self._put(
                jnp.where(md[:, None], 0.0, self._vstats), (None, None))
        self._dirty = []

    def _maybe_shrink_slots(self) -> None:
        """Compact-shrink the S axis when the active set fits a smaller
        power-of-two bucket (elastic mode; a data tick's preamble, like
        the K-axis ``_maybe_repack``)."""
        plan = self._sched.shrink_plan()
        if plan is None:
            return
        src, moves = plan
        self._repack_slots(src)
        for jid, s in moves.items():
            self._jobs[jid].slot = s

    # -- streaming wavelet prefilter -----------------------------------------
    def _ref_prefix_coeffs(self, size: int, n: int) -> np.ndarray:
        """Compressed Haar coefficient bank of every reference's first
        ``n`` samples, edge-extended to target length ``size`` — the
        apples-to-apples counterpart of a job's :class:`StreamingHaar`
        prefix coefficients (sampling rates are shared, so ``n`` job
        samples correspond to ~``n`` reference samples; comparing the
        prefix against FULL references would just correlate the job's
        constant extension tail against unseen reference structure).
        Cached per (size, n): lockstep jobs share the transform."""
        key = (size, n)
        cb = self._wcoeff_cache.get(key)
        if cb is None:
            series = self.bank.series.astype(np.float64)
            w = series.shape[1]
            cut = np.minimum(self._full_lengths, n)             # [K]
            edge = np.take_along_axis(series, (cut - 1)[:, None], axis=1)
            bp = np.where(np.arange(w)[None, :] < cut[:, None], series,
                          edge)
            bp = np.pad(bp, ((0, 0), (0, size - w)), mode="edge") \
                if size >= w else bp[:, :size]
            cb = _wavelet.compress_bank(_wavelet.haar_dwt_bank(bp),
                                        self.prefilter_coeffs)
            if len(self._wcoeff_cache) >= 16:
                self._wcoeff_cache.pop(next(iter(self._wcoeff_cache)))
            self._wcoeff_cache[key] = cb
        return cb

    @staticmethod
    def _top_p_with_margin(sims: np.ndarray, allowed: np.ndarray, p: int,
                           margin: float) -> np.ndarray:
        """Bool keep-mask: references ranking in the top ``p`` of ``sims``
        among ``allowed``, widened by ``margin`` (anything within margin
        of the p-th best survives too, so near-ties can't be evicted on
        ranking noise)."""
        ranked = np.where(allowed, sims, -np.inf)
        kth = np.partition(ranked, -p)[-p]
        return ranked >= kth - margin

    def _update_prefilter(self, pending) -> None:
        """Shrink each touched job's live-reference set.  Two top-P (+
        soundness margin) rules vote and the UNION survives:

        * the streaming-Haar ranking (coarse, warp-blind, cheap) proposes
          the bulk prune — at large K this is what collapses the tick;
        * the job's own in-flight open-end DTW scores (from the previous
          fused tick) veto the eviction of anything still plausibly
          winning — the Haar cosine compares prefixes rigidly, so a
          reference that matches the job only under warping (the paper's
          exim-vs-wordcount case) ranks poorly there while its warp
          correlation is already high; without the veto the prefilter
          would drop the eventual winner.

        Sticky per job: sets only ever shrink, so a dropped reference's
        DP column never has to re-enter for a job that already has
        samples (re-entry would be stale)."""
        p = self.prefilter_top
        if self._overload is not None:
            # deep_prune rung: survivor sets shrink harder (sticky, so
            # the deeper cut persists after de-escalation — monotone
            # like every other prune).
            p = max(1, p // self._overload.prefilter_divisor)
        for job, *_ in pending:
            if job.haar is None or job.n < 2:
                continue
            if job.degraded_level >= 2:
                # distance-only ticks froze this job's DTW veto scores;
                # pruning on a stale veto could evict the eventual
                # winner, so the live set just stops shrinking.
                continue
            if job.fraction_seen < self.prefilter_min_fraction:
                continue
            if self.score_in_flight and job.last_sims is None:
                continue          # no DTW veto yet: too early to prune
            allowed = job.allowed if job.allowed is not None \
                else np.ones((self._k,), bool)
            if int(allowed.sum()) <= p:
                continue                              # converged
            keep = self._top_p_with_margin(
                _wavelet.coeff_similarity_bank(
                    job.haar.compressed(self.prefilter_coeffs),
                    self._ref_prefix_coeffs(job.haar.size, job.n)),
                allowed, p, self.prefilter_margin)
            if job.last_sims is not None:
                dsims = np.where(allowed,
                                 np.nan_to_num(job.last_sims, neginf=-1.0),
                                 -np.inf)
                keep |= self._top_p_with_margin(dsims, allowed, p,
                                                self.prefilter_margin)
                # the early-decision margin compares the leader WORKLOAD
                # against the runner-up WORKLOAD: protect the best
                # reference of each of the current top-2 workloads, or
                # evicting the whole runner-up family would floor its
                # score to -1.0 and make the margin gate vacuously true.
                seen = set()
                for r in np.argsort(dsims)[::-1]:
                    if not np.isfinite(dsims[r]) or len(seen) == 2:
                        break
                    if self._labels[r] not in seen:
                        seen.add(self._labels[r])
                        keep[r] = True
            job.allowed = np.logical_and(allowed, keep)

    def _survivors(self) -> np.ndarray:
        """Union of the active jobs' live sets -> full-bank index array.
        A job whose prefilter has not engaged needs every reference."""
        mask = np.zeros((self._k,), bool)
        for job in self._jobs.values():
            if job.allowed is None:
                return np.arange(self._k)
            mask |= job.allowed
        return np.flatnonzero(mask)

    def _maybe_repack(self) -> None:
        """Re-pack the device state when the survivor union has outgrown
        the packed columns (a fresh job needs everything again) or when it
        has shrunk past the next power-of-two bucket.  A packed set that
        merely *contains* the survivors is left alone: the extra columns
        cost one bucket's worth of compute at most, while every re-pack
        is a state upload and (first time per shape) an XLA compile —
        chasing each membership change would churn far more than the
        stragglers cost."""
        if self.prefilter_top is None:
            return
        idx = self._survivors()
        grown = not np.isin(idx, self._packed_idx,
                            assume_unique=True).all()
        full = len(idx) == self._k
        kp_target = self._k + ((-self._k) % self._ndev) if full \
            else self._k_bucket(len(idx))
        if not grown and kp_target >= self._kp:
            return
        self._pack_device_state(idx, self._rows, self._moms)
        self.repack_count += 1

    # -- tick compilation ----------------------------------------------------
    def _base_mode(self) -> str:
        """The configured (unloaded) tick mode: ``"prob"`` (exact
        6-channel probabilities), ``"approx_prob"`` (the 4-channel
        approximate tail — ``prob_mode="approx"``), ``"scored"`` or
        ``"distance"``."""
        if self.min_probability is not None:
            return "approx_prob" if self.prob_mode == "approx" else "prob"
        return "scored" if self.score_in_flight else "distance"

    def _tick_mode(self) -> str:
        """Effective tick mode this tick: the configured mode, capped by
        the overload ladder's current rung (a cap can only ever be
        CHEAPER than the configured mode — ``min`` over the expense
        order, so a distance-only service is never upgraded and an
        approx-probability service is never promoted to the exact
        tail)."""
        base = self._base_mode()
        if self._overload is None:
            return base
        order = {"prob": 0, "approx_prob": 1, "scored": 2, "distance": 3}
        cap = self._overload.tick_mode_cap
        return cap if order[cap] > order[base] else base

    def _tick_fn_for(self, mode: str):
        """Cached ``(tick_fn, fallback)`` per mode — the configured mode
        compiles at construction, degraded modes on first use."""
        fns = self._tick_fns.get(mode)
        if fns is None:
            fns = self._build_tick_fn(self._axis, mode)
            self._tick_fns[mode] = fns
        return fns

    def _build_tick_fn(self, axis: Optional[str], mode: str):
        """The ONE jitted callable a tick dispatches: fused scored extend
        (or the distance-only variant), optionally shard_mapped over the
        bank axis.  Sharding is exact — every DP cell and score is a
        per-reference quantity, so the fan-out computes disjoint K slices
        and the [S, K] score gather is the only cross-device output.

        ``mode`` selects the dispatch flavor (``"prob"`` /
        ``"approx_prob"`` / ``"scored"`` / ``"distance"``): the
        configured mode in an unloaded service, or a cheaper ladder
        rung's flavor under overload (every flavor updates the DP rows
        identically — same warp-path predecessor selection — so a
        degraded tick leaves the rows bitwise what the full tick would
        have computed and only side channels go stale).

        Returns ``(tick_fn, fallback_fn_or_None)``.  On the unsharded
        paths the fallback is the same dispatch pinned to the jnp
        wavefront twin (``use_kernel=False``) — bit-identical to the
        Pallas kernel, so a degraded tick after retry exhaustion changes
        latency, never results.  The shard_mapped paths already close
        over the jnp impl, so their fallback is None (retries only)."""
        band = self.band
        if mode == "prob":
            threshold = float(self.threshold)
            if self.mesh is None:
                # probabilistic twin: six moment slabs + variance
                # folds through the same kernel machinery, probs
                # beside scores.  Separate entry point, so the exact
                # tick's compiled graph is untouched.
                return (functools.partial(
                    _dtw.bank_extend_tick_scored_var_dispatch,
                    band=band, threshold=threshold),
                    functools.partial(
                        _dtw.bank_extend_tick_scored_var_dispatch,
                        band=band, threshold=threshold,
                        use_kernel=False))

            def inner_var(rows, moms, ns, sx, sxx, vstats, bank_t,
                          lengths, chunks, vchunks, nvalid, qlens):
                return _dtw._bank_extend_diag_impl(
                    rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                    nvalid, qlens, band=band, score=True,
                    vchunks=vchunks, vstats=vstats,
                    threshold=threshold)
            P = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                inner_var, mesh=self.mesh,
                in_specs=(P(None, None, axis),
                          P(None, None, None, axis),
                          P(), P(), P(), P(None, None), P(None, axis),
                          P(axis), P(), P(), P(), P()),
                out_specs=(P(None, None, axis),
                           P(None, None, None, axis),
                           P(), P(), P(), P(None, axis),
                           P(None, None), P(None, axis)))), None
        if mode == "approx_prob":
            threshold = float(self.threshold)
            if self.mesh is None:
                # approximate-tail twin: FOUR moment slabs (sy, syy,
                # sxy, svy) through the same kernel machinery; svyy and
                # svxy are reconstructed at the score tail from the
                # per-slot variance folds (core.dtw's
                # _prob_from_moments_approx), trading a tolerance-band
                # probability error for ~2 fewer slab channels per
                # cell.  Separate entry point: neither the exact prob
                # graph nor the scored graph is touched.
                return (functools.partial(
                    _dtw.bank_extend_tick_scored_var_approx_dispatch,
                    band=band, threshold=threshold),
                    functools.partial(
                        _dtw.bank_extend_tick_scored_var_approx_dispatch,
                        band=band, threshold=threshold,
                        use_kernel=False))

            def inner_approx(rows, moms, ns, sx, sxx, vstats, bank_t,
                             lengths, chunks, vchunks, nvalid, qlens):
                return _dtw._bank_extend_diag_impl(
                    rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                    nvalid, qlens, band=band, score=True,
                    vchunks=vchunks, vstats=vstats,
                    threshold=threshold)
            P = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                inner_approx, mesh=self.mesh,
                in_specs=(P(None, None, axis),
                          P(None, None, None, axis),
                          P(), P(), P(), P(None, None), P(None, axis),
                          P(axis), P(), P(), P(), P()),
                out_specs=(P(None, None, axis),
                           P(None, None, None, axis),
                           P(), P(), P(), P(None, axis),
                           P(None, None), P(None, axis)))), None
        if mode == "scored":
            if self.mesh is None:
                # routes to the moment-carrying Pallas streaming kernel on
                # TPU (DP row + (sy, syy, sxy) slabs pinned in VMEM across
                # the chunk), the jnp wavefront elsewhere.
                return (functools.partial(
                    _dtw.bank_extend_tick_scored_dispatch, band=band),
                    functools.partial(
                        _dtw.bank_extend_tick_scored_dispatch, band=band,
                        use_kernel=False))

            def inner(rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                      nvalid, qlens):
                return _dtw._bank_extend_diag_impl(
                    rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                    nvalid, qlens, band=band, score=True)
            P = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                inner, mesh=self.mesh,
                in_specs=(P(None, None, axis), P(None, None, None, axis),
                          P(), P(), P(), P(None, axis), P(axis), P(), P(),
                          P()),
                out_specs=(P(None, None, axis), P(None, None, None, axis),
                           P(), P(), P(), P(None, axis)))), None

        if mode != "distance":
            raise ValueError(f"unknown tick mode {mode!r}")
        if self.mesh is None:
            # bank_extend_tick_dispatch routes to the Pallas streaming
            # kernel on TPU and the (already-jitted) jnp wavefront
            # elsewhere.
            return (functools.partial(_dtw.bank_extend_tick_dispatch,
                                      band=band),
                    functools.partial(_dtw.bank_extend_tick_dispatch,
                                      band=band, use_kernel=False))

        def inner(rows, ns, bank_t, lengths, chunks, nvalid, qlens):
            return _dtw.bank_extend_tick(rows, ns, bank_t, lengths, chunks,
                                         nvalid, qlens, band=band)
        P = jax.sharding.PartitionSpec
        return jax.jit(_shard_map(
            inner, mesh=self.mesh,
            in_specs=(P(None, None, axis), P(), P(None, axis), P(axis),
                      P(), P(), P()),
            out_specs=(P(None, None, axis), P()))), None

    # -- dispatch resilience --------------------------------------------------
    def _dispatch_resilient(self, primary, fallback, kind: str):
        """Run one device dispatch through the retry/backoff wrapper.

        ``primary``/``fallback`` are zero-arg thunks (the fallback is the
        jnp wavefront twin on unsharded paths, None when the primary
        already is jnp).  Transient device errors — and chaos-injected
        ones, consulted per *attempt* so a fault burst spans retries —
        are retried per ``self.retry_policy``; after exhaustion the
        fallback serves the tick once and the service surfaces
        ``degraded``.  Results are bit-identical either way (the twin is
        pinned against the kernel), so injected faults move latency and
        counters, never scores or decisions.  With neither a policy nor
        a chaos plan nor a breaker armed this is a plain call — the hot
        path pays one attribute test.

        A :class:`runtime.retry.CircuitBreaker` (``breaker=``) wraps the
        whole ladder: while OPEN the fallback serves directly (no
        primary attempt, no chaos consult, no retry backoff — the point
        is not paying the failing kernel every tick); in HALF-OPEN a
        seeded probe re-tries the primary once per probe slot, and a
        success re-promotes the kernel path (``degraded`` clears)."""
        chaos = self.chaos
        breaker = self.breaker if fallback is not None else None
        if chaos is None and self.retry_policy is None and breaker is None:
            return primary()

        def attempt():
            if chaos is not None:
                chaos.on_dispatch(kind)
            return primary()

        if breaker is not None:
            route = breaker.before_dispatch()
            if route == "fallback":
                self.degraded_dispatch_count += 1
                self.last_tick_degraded = True
                return fallback()
            if route == "probe":
                try:
                    result = attempt()       # one un-retried attempt
                except self._transient:
                    breaker.record_failure()
                    self.degraded_dispatch_count += 1
                    self.last_tick_degraded = True
                    return fallback()
                breaker.record_success()
                return result

        policy = self.retry_policy or RetryPolicy(max_retries=0,
                                                  base_delay=0.0)
        result, report = call_with_retry(
            attempt, policy=policy, transient=self._transient,
            fallback=fallback)
        self.retry_count += report["retries"]
        if report["degraded"]:
            self.degraded_dispatch_count += 1
            self.last_tick_degraded = True
            if breaker is not None:
                breaker.record_failure()
        elif breaker is not None:
            breaker.record_success()
        return result

    # -- input quarantine -----------------------------------------------------
    def _quarantine(self, job_id: str, reason: str) -> None:
        """Evict a job whose stream produced a poisoned sample (NaN/Inf,
        bad variance).  The offending push was rejected atomically before
        touching any buffer, and per-job DP state is row-independent, so
        survivors are bit-identical to a run that never saw the sick
        job's tail — the same guarantee the churn-invariance suite pins
        for ordinary evictions.  Later pushes for the job are dropped
        (counted), not resurrected."""
        self.quarantined[job_id] = reason
        self.quarantined_count += 1
        self.evict(job_id)

    # -- elastic rescale ------------------------------------------------------
    def rescale(self, mesh: Optional[jax.sharding.Mesh]) -> None:
        """Re-home the device state onto a different 1-D mesh (or back
        to a single device with ``mesh=None``) mid-flight — the hook a
        ``runtime.fault.ElasticController`` rescale decision drives when
        hosts die or join.  The bank re-pads to the new device-count
        multiple and every state slab moves by the same on-device gather
        a prefilter re-pack uses, so scores and decisions are unchanged
        (sharding is exact); the tick callable recompiles for the new
        mesh."""
        ndev, axis = 1, None
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError("TuningService needs a 1-D mesh (one bank "
                                 f"axis); got axes {mesh.axis_names}")
            axis = mesh.axis_names[0]
            ndev = mesh.devices.size
        rows, moms = self._rows, self._moms
        self.mesh, self._ndev, self._axis = mesh, ndev, axis
        self._ns = self._put(np.asarray(self._ns), (None,))
        self._sx = self._put(np.asarray(self._sx), (None,))
        self._sxx = self._put(np.asarray(self._sxx), (None,))
        if self._vstats is not None:
            self._vstats = self._put(np.asarray(self._vstats), (None, None))
        self._pack_device_state(self._packed_idx, rows, moms)
        self._tick_fns = {}            # per-mode callables are mesh-bound
        self._tick_fn, self._tick_fallback = \
            self._tick_fn_for(self._base_mode())
        self.rescale_count += 1

    # -- job lifecycle -------------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._jobs)

    @property
    def slot_capacity(self) -> int:
        """Current S bucket (== ``slots`` when ``elastic_slots=False``)."""
        return self._s_cap

    # -- overload surface (serve.overload runbook) ---------------------------
    @property
    def rung(self) -> int:
        """Current degradation-ladder rung (0 without a controller)."""
        return 0 if self._overload is None else self._overload.rung

    @property
    def rung_history(self) -> List[Tuple[int, int, int]]:
        """Ladder transitions ``(observation_index, from, to)`` — empty
        without a controller."""
        return [] if self._overload is None \
            else list(self._overload.rung_history)

    @property
    def degraded(self) -> bool:
        """True while the service is NOT serving its configured quality:
        the circuit breaker has demoted the kernel path, or the overload
        ladder sits above rung 0.  Clears when the breaker re-closes and
        the ladder de-escalates back to normal."""
        return (self.breaker is not None and self.breaker.engaged) \
            or self.rung > 0

    def overload_pressure(self) -> float:
        """Scalar [0, 1] rescale-ahead signal for
        ``runtime.fault.ElasticController.decide_ahead``: the worse of
        the ladder's latency pressure and the ingest queue fill."""
        p = self._front.queue_fill()
        if self._overload is not None:
            p = max(p, self._overload.pressure())
        return p

    def submit(self, job_id: str, expected_len: int,
               tick_hz: Optional[float] = None,
               qos: str = "silver") -> InFlightJob:
        """Register an in-flight job (``expected_len`` = predicted total
        sample count; it anchors the Sakoe-Chiba band and the
        fraction-seen gate of the early-decision rule).  ``tick_hz``
        assigns the job to a tick-rate cohort: ``tick(now=...)`` drains
        it only on its own period (None = every tick).

        ``qos`` (bronze/silver/gold) is the job's admission class: with
        an admission controller armed (``admission=``), a submit under
        measured overload raises
        :class:`serve.overload.AdmissionShedError` — bronze sheds first,
        gold last (see the ``serve.overload`` runbook).  A shed submit
        leaves NO state behind (and is never journaled): the producer
        retries later or routes the job elsewhere."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already in flight")
        if expected_len < 1:
            raise ValueError("expected_len must be >= 1")
        if self._admission is not None and not self._admission_suppressed:
            rung_frac = (self._overload.rung / max(1, len(RUNGS) - 1)
                         if self._overload is not None else 0.0)
            cost_fill = min(1.0, expected_len / (
                self._admission.policy.cost_scale * self._mean_ref_len))
            try:
                self._admission.admit(
                    job_id, qos=qos, cost_fill=cost_fill,
                    queue_fill=self._front.queue_fill(),
                    rung_frac=rung_frac)
            except AdmissionShedError:
                self.shed_count += 1
                self.shed_by_class[qos] = \
                    self.shed_by_class.get(qos, 0) + 1
                raise
        slot, grow_src = self._sched.admit(job_id, tick_hz)
        if grow_src is not None:
            self._repack_slots(grow_src)
        # the slot's device state is reset LAZILY (one masked op at the
        # next data tick covers every submit since the last one) — a
        # stale freed row is inert until then: its nvalid is 0 in every
        # dispatch and only pending jobs' scores are ever read.  Under
        # churn this turns S x M x K copies per *submit* into one per
        # *tick*.
        self._dirty.append(slot)
        self._qlens[slot] = expected_len
        job = InFlightJob(job_id=job_id, slot=slot, expected_len=expected_len,
                          tick_hz=tick_hz, qos=qos,
                          haar=_wavelet.StreamingHaar(expected_len)
                          if self.prefilter_top is not None else None)
        self._front.register(job_id)
        self._jobs[job_id] = job
        return job

    def push(self, job_id: str, samples: np.ndarray,
             variance: Optional[np.ndarray] = None,
             now: Optional[float] = None) -> None:
        """Buffer newly observed samples; consumed at the job's next due
        tick.  ``now`` stamps the heartbeat/straggler trackers (when
        armed) — a clock-less push is accepted but invisible to
        :meth:`sweep_stalled`.  ``variance`` (probabilistic mode only)
        carries aligned per-sample measurement variances; when omitted
        the ingest layer estimates them from the causal filter residual
        at drain time (0.0 without ``denoise`` — exact pushes stay
        exact).

        Poisoned payloads (NaN/Inf samples, negative or non-finite
        variances) QUARANTINE the job: the push is rejected atomically
        by the ingest layer, the job is evicted with the poison reason
        recorded in :attr:`quarantined`, and ``PoisonedSampleError`` is
        re-raised to the caller.  Survivors are untouched — bit-identical
        scores and decisions (see :meth:`_quarantine`)."""
        if job_id in self.quarantined:
            # a sick agent keeps streaming; swallow, never resurrect.
            self.quarantine_dropped += 1
            return
        if job_id not in self._jobs:
            raise KeyError(job_id)
        if self.chaos is not None:
            samples = self.chaos.corrupt(samples)
            now = self.chaos.skew(now)
        try:
            self._front.push(job_id, samples, variance=variance, now=now)
        except PoisonedSampleError as err:
            self._quarantine(job_id, err.reason)
            raise

    # -- the hot path --------------------------------------------------------
    def tick(self, now: Optional[float] = None, *,
             latency: Optional[float] = None,
             _observe: bool = True) -> Dict[str, Optional[TuneDecision]]:
        """Drain every due job's buffered samples in ONE jitted dispatch
        (DP extend + prefix scoring fused, sharded over the bank when a
        mesh is set), then apply the early-decision rule to the returned
        [S, K] score array.

        ``now`` meters the tick-rate cohorts: only cohorts whose period
        has elapsed drain (others keep buffering).  Without a clock
        every job is due — the legacy cadence.

        Overload plumbing (``overload=``): the rung decided by PRIOR
        observations is in force for this whole tick (mode cap, deeper
        pruning, cohort stretch — decided pre-dispatch, so replay can
        reproduce it), then the tick's measured wall-clock latency (plus
        any chaos-injected slowdown) feeds the ladder.  ``latency=``
        overrides the measurement — the recovery journal records each
        live tick's latency and replays it here, which is what makes the
        rung trajectory (hence tick modes and staleness markers)
        bit-identical across recovery.  ``_observe=False`` marks an
        internal drain tick (see :meth:`finish`): it must not advance
        the ladder, because only top-level tick commands are journaled
        with a latency.

        Returns {job_id: TuneDecision} for decisions *newly emitted* this
        tick (None for touched jobs where the service abstains), plus any
        decision a previous internal tick (see :meth:`finish`) emitted but
        could not deliver.
        """
        if self._overload is not None:
            self._sched.cohorts.rate_scale = self._overload.cohort_scale
            if _observe and self._overload.rung >= 1:
                self.overload_ticks += 1
        t0 = time.perf_counter()
        out = self._tick_impl(now)
        if _observe:
            lat = time.perf_counter() - t0 if latency is None \
                else float(latency)
            if latency is None and self.chaos is not None:
                lat += self.chaos.slow_dispatch("tick")
            self.last_tick_latency = lat
            if self._overload is not None:
                self._overload.observe(lat)
                self.worst_rung = max(self.worst_rung,
                                      self._overload.rung)
        return out

    def _tick_impl(self, now: Optional[float]
                   ) -> Dict[str, Optional[TuneDecision]]:
        self.ticks += 1
        self.last_tick_degraded = False
        out: Dict[str, Optional[TuneDecision]] = self._undelivered
        self._undelivered = {}
        due = self._sched.due_jobs(now, self._jobs.keys())
        prob_mode = self.min_probability is not None
        pending: List[Tuple[InFlightJob, np.ndarray,
                            Optional[np.ndarray]]] = []
        for job in self._jobs.values():
            if job.job_id not in due:
                continue
            if prob_mode:
                chunk, vchunk = self._front.drain(job.job_id,
                                                  with_variance=True)
            else:
                chunk, vchunk = self._front.drain(job.job_id), None
            if chunk is None:
                continue
            job.x.append(chunk)
            if vchunk is not None:
                job.vx.append(vchunk)
            if job.haar is not None:
                job.haar.update(chunk)
            pending.append((job, chunk, vchunk))
        if not pending:
            return out

        # re-pack preamble (state motion, never a dispatch): deferred
        # fresh-slot resets first (so no gather ever moves stale rows),
        # then K-axis when the prefilter's survivor union crossed a
        # bucket boundary, then S-axis when the active set fits a
        # smaller slot bucket.
        self._apply_resets()
        if self.prefilter_top is not None:
            self._maybe_repack()
        self._maybe_shrink_slots()
        k_live = len(self._packed_idx)

        c = _dtw._chunk_bucket(max(ch.shape[0] for _, ch, _ in pending))
        chunks = np.zeros((self._s_cap, c), np.float32)
        nvalid = np.zeros((self._s_cap,), np.int32)
        vchunks = np.zeros((self._s_cap, c), np.float32) if prob_mode \
            else None
        for job, ch, vch in pending:
            chunks[job.slot, : ch.shape[0]] = ch
            nvalid[job.slot] = ch.shape[0]
            if prob_mode:
                vchunks[job.slot, : ch.shape[0]] = vch

        # Effective tick mode: the configured flavor, or a cheaper one
        # under the overload ladder.  Every flavor updates the DP rows
        # (and ns) identically — the warp-path predecessor selection is
        # shared — so a degraded tick DELAYS decisions (side channels go
        # stale, marked on the job) but can never change them.
        mode = self._tick_mode()
        base = self._base_mode()
        tick_fn, tick_fb = self._tick_fn_for(mode)
        sims_all = probs_all = None
        if mode == "prob":
            args = (self._rows, self._moms, self._ns, self._sx, self._sxx,
                    self._vstats, self._bank_t, self._lengths,
                    jnp.asarray(chunks), jnp.asarray(vchunks),
                    jnp.asarray(nvalid), jnp.asarray(self._qlens))
            (self._rows, self._moms, self._ns, self._sx, self._sxx,
             scores, self._vstats, probs) = self._dispatch_resilient(
                lambda: tick_fn(*args),
                (lambda: tick_fb(*args))
                if tick_fb is not None else None, "tick")
            sims_all = np.full((self._s_cap, self._k), -np.inf)
            sims_all[:, self._packed_idx] = \
                np.asarray(scores, np.float64)[:, :k_live]
            # pruned-out references carry zero match probability.
            probs_all = np.zeros((self._s_cap, self._k))
            probs_all[:, self._packed_idx] = \
                np.asarray(probs, np.float64)[:, :k_live]
        elif mode == "approx_prob":
            # the approx tail needs only channels 0:4 (sy, syy, sxy,
            # svy).  An approx-configured service carries exactly those
            # four; an exact-configured service capped to this rung
            # dispatches over the first four of its six — svyy/svxy
            # stay stale (degraded_level=1 suppresses early decisions),
            # but probabilities keep flowing: the rung sheds precision,
            # not probabilities.
            moms_in = self._moms[:4] if base == "prob" else self._moms
            args = (self._rows, moms_in, self._ns, self._sx, self._sxx,
                    self._vstats, self._bank_t, self._lengths,
                    jnp.asarray(chunks), jnp.asarray(vchunks),
                    jnp.asarray(nvalid), jnp.asarray(self._qlens))
            (self._rows, moms_out, self._ns, self._sx, self._sxx,
             scores, self._vstats, probs) = self._dispatch_resilient(
                lambda: tick_fn(*args),
                (lambda: tick_fb(*args))
                if tick_fb is not None else None, "tick")
            if base == "prob":
                self._moms = self._put(
                    jnp.concatenate([moms_out, self._moms[4:]], axis=0),
                    (None, None, None, self._axis))
            else:
                self._moms = moms_out
            sims_all = np.full((self._s_cap, self._k), -np.inf)
            sims_all[:, self._packed_idx] = \
                np.asarray(scores, np.float64)[:, :k_live]
            probs_all = np.zeros((self._s_cap, self._k))
            probs_all[:, self._packed_idx] = \
                np.asarray(probs, np.float64)[:, :k_live]
        elif mode == "scored":
            # a prob-configured service ticking at the exact_score rung
            # runs the 3-channel dispatch over channels 0:3 of its
            # moment slab (6 channels exact, 4 approx); the variance
            # channels (and vstats) simply stay what they were — stale,
            # never wrong-and-used, because degraded_level >= 1
            # suppresses every probability read.
            moms_in = self._moms[:3] \
                if base in ("prob", "approx_prob") else self._moms
            args = (self._rows, moms_in, self._ns, self._sx, self._sxx,
                    self._bank_t, self._lengths, jnp.asarray(chunks),
                    jnp.asarray(nvalid), jnp.asarray(self._qlens))
            (self._rows, moms_out, self._ns, self._sx, self._sxx,
             scores) = self._dispatch_resilient(
                lambda: tick_fn(*args),
                (lambda: tick_fb(*args))
                if tick_fb is not None else None, "tick")
            if base in ("prob", "approx_prob"):
                self._moms = self._put(
                    jnp.concatenate([moms_out, self._moms[3:]], axis=0),
                    (None, None, None, self._axis))
            else:
                self._moms = moms_out
            # the tick's ONLY device->host transfer: the [S, K_live]
            # scores, scattered back to full-bank columns (pruned-out
            # references read -inf — never a leader, never a runner-up).
            sims_all = np.full((self._s_cap, self._k), -np.inf)
            sims_all[:, self._packed_idx] = \
                np.asarray(scores, np.float64)[:, :k_live]
        else:
            args = (self._rows, self._ns, self._bank_t, self._lengths,
                    jnp.asarray(chunks), jnp.asarray(nvalid),
                    jnp.asarray(self._qlens))
            self._rows, self._ns = self._dispatch_resilient(
                lambda: tick_fn(*args),
                (lambda: tick_fb(*args))
                if tick_fb is not None else None, "tick")
        self.dispatch_count += 1

        if mode != base:
            lvl = 2 if mode == "distance" else 1
            for job, *_ in pending:
                job.degraded_level = max(job.degraded_level, lvl)

        for job, ch, _ in pending:
            job.n += ch.shape[0]
            decision = None
            # a level-2 job's moment/query-stat channels are stale, so
            # any score a later scored tick emits for its slot is
            # garbage: freeze last_sims/last_probs at their last exact
            # values instead of overwriting them.
            if sims_all is not None and job.degraded_level < 2:
                sims = sims_all[job.slot]
                if job.allowed is not None:
                    # a column another job kept alive may be pruned for
                    # THIS job: mask it out of this job's view.
                    sims = np.where(job.allowed, sims, -np.inf)
                job.last_sims = sims
                if probs_all is not None:
                    pr = probs_all[job.slot]
                    if job.allowed is not None:
                        pr = np.where(job.allowed, pr, 0.0)
                    job.last_probs = pr
                if job.early is None and job.degraded_level == 0:
                    decision = self._maybe_decide(job)
            if out.get(job.job_id) is None:
                out[job.job_id] = decision
        # prune with THIS tick's information (scores just computed, n just
        # advanced): eviction decisions lag the data by zero ticks, the
        # re-pack they imply happens at the top of the next tick.
        if self.prefilter_top is not None:
            self._update_prefilter(pending)
        return out

    # -- decision rule -------------------------------------------------------
    def _reduce(self, sims: np.ndarray) -> Dict[str, float]:
        """Per-workload best over the bank's (possibly multi-entry) rows."""
        scores: Dict[str, float] = {}
        for lbl, s in zip(self._labels, sims):
            scores[lbl] = max(scores.get(lbl, -1.0), float(s))
        return scores

    @staticmethod
    def _rank(scores: Dict[str, float]) -> Tuple[str, float, float]:
        """(leader, leader_score, runner_up_score); insertion order breaks
        ties so repeated ticks rank deterministically."""
        leader, ls = None, -np.inf
        for w, s in scores.items():
            if s > ls:
                leader, ls = w, s
        rs = max((s for w, s in scores.items() if w != leader), default=-1.0)
        return leader, ls, rs

    def _maybe_decide(self, job: InFlightJob) -> Optional[TuneDecision]:
        if job.n < 2:
            return None
        scores = self._reduce(job.last_sims)
        leader, ls, rs = self._rank(scores)
        # the margin test needs a real runner-up: with < 2 workloads in
        # the bank it would be vacuously true (rs == -1.0), so the
        # service abstains in flight instead of fast-tracking the only
        # candidate (finish() still decides from the complete series).
        margin_ok = self._n_workloads >= 2 and ls - rs >= self.margin
        if leader == job.leader and margin_ok:
            job.stable_for += 1
        else:
            job.stable_for = 1 if margin_ok else 0
        job.leader = leader
        # confidence gate: the point correlation threshold, or in
        # probabilistic mode the leader workload's match probability —
        # a flat posterior (noisy prefix) keeps the service abstaining
        # even when the point estimate momentarily clears the threshold.
        # At zero input variance the probability is exactly
        # 1{corr >= threshold}, so the two gates coincide bitwise.
        lp = None
        if self.min_probability is not None:
            lp = self._reduce(job.last_probs).get(leader, 0.0)
            confident = lp >= self.min_probability
        else:
            confident = ls >= self.threshold
        if (job.fraction_seen >= self.min_fraction
                and confident
                and job.stable_for >= self.stable_ticks):
            cfg = self.db.best_config(leader) if self.db is not None else None
            job.early = TuneDecision(
                workload=job.job_id, matched=leader, corr=ls, config=cfg,
                scores=scores, fraction_seen=job.fraction_seen, final=False,
                decided_at_fraction=job.fraction_seen, probability=lp)
            return job.early
        return None

    # -- fault handling ------------------------------------------------------
    def evict(self, job_id: str) -> Optional[TuneDecision]:
        """Drop an in-flight job WITHOUT a verdict: slot freed, queue and
        heartbeat state discarded, device rows left to be compacted away
        by the next data tick's S-axis shrink.  Returns the job's early
        decision if one was emitted (the only tuning signal a stalled
        job ever produced).  Survivors are untouched — per-job state is
        row-independent, so eviction cannot perturb their scores."""
        if job_id not in self._jobs:
            raise KeyError(job_id)
        _, _, early = self._retire(job_id)
        self.evicted_count += 1
        return early

    def sweep_stalled(self, now: float) -> Dict[str, Optional[TuneDecision]]:
        """Evict every job whose heartbeat (stamped by ``push(...,
        now=)``) is older than the service's ``heartbeat_timeout`` —
        stalled ingest must not pin a slot forever.  Returns {job_id:
        early decision or None} for the evicted set; a no-op (empty
        dict) when heartbeats are not armed."""
        return {jid: self.evict(jid) for jid in self._front.stalled(now)}

    def stragglers(self) -> List[str]:
        """In-flight jobs whose observed push cadence is consistently
        slower than the cohort median (``runtime.fault
        .StragglerDetector`` over inter-push gaps) — candidates for a
        slower tick-rate cohort or eviction."""
        return [j for j in self._front.stragglers.stragglers()
                if j in self._jobs]

    # -- completion ----------------------------------------------------------
    #
    # Final verdicts are MATRIX-FREE and batchable: one
    # ``dtw.dtw_score_bank_many`` dispatch carries the warp-path
    # correlation moments through the DP on device and reads them at the
    # closed alignment endpoint, so J completed jobs cost one dispatch —
    # not J ``[K, N, M]`` matrix materializations with host backtracking.
    # Per-job scores are bitwise independent of how verdicts are batched
    # (per-cell arithmetic plus host-side per-query moment folds), so
    # ``finish``, ``finish_many`` and the deferred drain queue all render
    # identical decisions for the same job.

    def _verdict_scores(self, queries, variances=None):
        """[J, K] float64 offline scores (and, in probabilistic mode, the
        [J, K] match probabilities) for J completed queries in ONE
        matrix-free dispatch, the Sakoe-Chiba band re-derived from each
        query's TRUE length (the in-flight corridor was anchored to the
        ``expected_len`` prediction).  Queries with fewer than 2 samples
        score 0 without touching the device; the bank's tiled device
        upload is memoized on the SeriesBank (``score_plan``), so
        verdicts move query bytes only."""
        prob_mode = self.min_probability is not None
        out = np.zeros((len(queries), self._k), np.float64)
        pout = np.zeros((len(queries), self._k), np.float64) \
            if prob_mode else None
        live = [i for i, q in enumerate(queries) if q.shape[0] >= 2]
        if not live:
            return out, pout
        # pow2 buckets on both axes so repeat drains reuse jit shapes
        jb = _dtw._pad_pow2(len(live), lo=1)
        npad = _dtw._pad_pow2(max(queries[i].shape[0] for i in live))
        xs = np.zeros((jb, npad), np.float32)
        xl = np.zeros((jb,), np.int32)
        sx = np.zeros((jb,), np.float32)
        sxx = np.zeros((jb,), np.float32)
        xv = np.zeros((jb, npad), np.float32) if prob_mode else None
        for r, i in enumerate(live):
            q = queries[i]
            xs[r, : q.shape[0]] = q
            xl[r] = q.shape[0]
            sx[r], sxx[r] = _dtw.query_moments(q)
            if prob_mode:
                v = variances[i]
                if v is not None and v.shape[0] == q.shape[0]:
                    xv[r, : q.shape[0]] = v
        if prob_mode:
            def call(use_kernel=None):
                return _dtw.dtw_score_bank_many(
                    xs, self.bank.series, self.bank.lengths, xlens=xl,
                    band=self.band, sx=sx, sxx=sxx, xvars=xv,
                    threshold=float(self.threshold),
                    plan=self.bank.score_plan(), use_kernel=use_kernel)
            scores, probs = self._dispatch_resilient(
                call, lambda: call(use_kernel=False), "verdict")
            probs = np.asarray(probs, np.float64)
        else:
            def call(use_kernel=None):
                return _dtw.dtw_score_bank_many(
                    xs, self.bank.series, self.bank.lengths, xlens=xl,
                    band=self.band, sx=sx, sxx=sxx,
                    plan=self.bank.score_plan(), use_kernel=use_kernel)
            scores, probs = self._dispatch_resilient(
                call, lambda: call(use_kernel=False), "verdict"), None
        scores = np.asarray(scores, np.float64)
        self.offline_dispatch_count += 1
        for r, i in enumerate(live):
            out[i] = scores[r]
            if prob_mode:
                pout[i] = probs[r]
        return out, pout

    def _render_verdict(self, job_id: str, sims: np.ndarray,
                        early: Optional[TuneDecision],
                        probs: Optional[np.ndarray] = None) -> TuneDecision:
        scores = self._reduce(sims)
        leader, ls, _ = self._rank(scores)
        lp = None
        if self.min_probability is not None:
            lp = self._reduce(probs).get(leader, 0.0)
            matched = leader if lp >= self.min_probability else None
        else:
            matched = leader if ls >= self.threshold else None
        cfg = self.db.best_config(matched) \
            if self.db is not None and matched is not None else None
        decision = TuneDecision(
            workload=job_id, matched=matched, corr=ls, config=cfg,
            scores=scores, fraction_seen=1.0, final=True,
            decided_at_fraction=(early.decided_at_fraction
                                 if early is not None else 1.0),
            probability=lp)
        if self.db is not None:
            self.db.record_decision(decision)
        return decision

    def _drain_tick_for(self, finishing) -> None:
        """Flush buffered samples before a verdict (ONE tick covering
        every live job) and park early decisions emitted for jobs that
        are NOT being finished, so they surface from the next tick().
        Internal ticks never advance the overload ladder
        (``_observe=False``): only top-level tick commands are journaled
        with a latency, so replay could not reproduce an observation
        made here."""
        if any(self._front.has_data(j) for j in finishing):
            emitted = self.tick(_observe=False)
            for jid, d in emitted.items():
                if jid not in finishing and d is not None:
                    self._undelivered[jid] = d

    def _retire(self, job_id: str):
        """Free a job's slot, returning its (full query, per-sample
        variances or None, early decision).  A parked early decision
        must not outlive the job (the id is reusable), so it is purged
        here."""
        job = self._jobs.pop(job_id)
        self._undelivered.pop(job_id, None)
        self._sched.release(job_id)
        self._front.retire(job_id)
        vx = job.vx.view() if self.min_probability is not None else None
        return job.x.view(), vx, job.early

    def finish(self, job_id: str) -> TuneDecision:
        """Final verdict for a completed job, recomputed offline from the
        full streamed (causally filtered) query by the matrix-free
        closed-end scorer.  Frees the slot and, when a ReferenceDB backs
        the service, records the decision history.  For many jobs ending
        together prefer :meth:`finish_many` (or the
        :meth:`finish_later` drain queue): the verdict dispatch amortizes
        across jobs instead of growing 1:1 with completions."""
        return self.finish_many((job_id,))[job_id]

    def finish_many(self, job_ids) -> Dict[str, TuneDecision]:
        """Final verdicts for several completed jobs — ONE buffer-drain
        tick plus ONE batched offline scoring dispatch
        (``offline_dispatch_count`` grows per *drain*, not per job), each
        decision identical to what a sequential :meth:`finish` would have
        rendered."""
        ids = list(job_ids)
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate job ids in finish_many")
        missing = [j for j in ids if j not in self._jobs]
        if missing:
            raise KeyError(f"unknown job(s): {missing}")
        if not ids:
            return {}
        self._drain_tick_for(set(ids))
        retired = [self._retire(j) for j in ids]
        sims, probs = self._verdict_scores([x for x, _, _ in retired],
                                           [v for _, v, _ in retired])
        return {jid: self._render_verdict(
                    jid, sims[i], retired[i][2],
                    None if probs is None else probs[i])
                for i, jid in enumerate(ids)}

    def finish_later(self, job_id: str) -> None:
        """Deferred finish: the job leaves its slot now (so slots
        recycle), but its verdict joins the drain queue and is rendered
        by the next :meth:`drain_finishes` — or automatically once
        ``finish_batch`` verdicts are pending — in one batched dispatch
        with the others.

        Job ids are reusable once retired, but a pending verdict claims
        the id until it is delivered: deferring a reused id while its
        predecessor's verdict is still undelivered would silently drop
        one of the two decisions (they are keyed by id), so that is
        refused — drain first.
        """
        if any(jid == job_id for jid, *_ in self._finish_queue) \
                or job_id in self._finished:
            raise ValueError(
                f"a verdict for job {job_id!r} is already pending "
                "delivery; drain_finishes() before deferring a reused id")
        self._drain_tick_for({job_id})
        x, vx, early = self._retire(job_id)
        self._finish_queue.append((job_id, x, vx, early))
        if len(self._finish_queue) >= self.finish_batch:
            self._finished.update(self._drain_queue())

    def _drain_queue(self) -> Dict[str, TuneDecision]:
        if not self._finish_queue:
            return {}
        queued, self._finish_queue = self._finish_queue, []
        sims, probs = self._verdict_scores([x for _, x, _, _ in queued],
                                           [v for _, _, v, _ in queued])
        return {jid: self._render_verdict(
                    jid, sims[i], early,
                    None if probs is None else probs[i])
                for i, (jid, _, _, early) in enumerate(queued)}

    def drain_finishes(self) -> Dict[str, TuneDecision]:
        """Render every deferred verdict (one batched dispatch), plus any
        decisions an automatic drain already rendered but has not yet
        delivered."""
        out = self._finished
        self._finished = {}
        out.update(self._drain_queue())
        return out

    @property
    def pending_finishes(self) -> int:
        """Verdicts owed to the caller: queued by :meth:`finish_later`
        and not yet rendered, PLUS auto-drained decisions not yet
        delivered — ``if svc.pending_finishes: svc.drain_finishes()`` is
        the intended polling idiom and must not skip either kind."""
        return len(self._finish_queue) + len(self._finished)


class MultiTenantTuningService:
    """Continuous-batching front over per-tenant reference banks.

    ``banks`` maps tenant name -> :class:`ReferenceDB` or
    :class:`SeriesBank`; each tenant gets an isolated
    :class:`TuningService` engine (its own bank, device state, cohorts
    and counters) built with the shared ``**engine_kwargs``.  Jobs are
    keyed to a tenant at :meth:`submit` and routed by job id afterwards
    — ids are unique across the front, so ``push``/``finish`` need no
    tenant argument.  A :meth:`tick` drains every engine (each engine
    dispatches only when one of its due jobs has data), so total device
    dispatches are bounded by data-ticks x tenants, and by data-ticks x
    cohorts within each engine when tick rates are declared.
    """

    def __init__(self, banks: Mapping[str, Union[ReferenceDB, SeriesBank]],
                 **engine_kwargs) -> None:
        if not banks:
            raise ValueError("no tenants")
        self._engines: Dict[str, TuningService] = {
            t: TuningService(bank, **engine_kwargs)
            for t, bank in banks.items()}
        self._tenant_of: Dict[str, str] = {}

    # -- routing --------------------------------------------------------------
    def engine(self, tenant: str) -> TuningService:
        """The tenant's tick engine (for counters/diagnostics)."""
        return self._engines[tenant]

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._engines)

    @property
    def n_active(self) -> int:
        return sum(e.n_active for e in self._engines.values())

    @property
    def dispatch_count(self) -> int:
        return sum(e.dispatch_count for e in self._engines.values())

    @property
    def offline_dispatch_count(self) -> int:
        return sum(e.offline_dispatch_count for e in self._engines.values())

    @property
    def quarantined(self) -> Dict[str, str]:
        """{job_id: poison reason} across every tenant engine."""
        out: Dict[str, str] = {}
        for e in self._engines.values():
            out.update(e.quarantined)
        return out

    def _engine_of(self, job_id: str) -> TuningService:
        return self._engines[self._tenant_of[job_id]]

    # -- lifecycle ------------------------------------------------------------
    def submit(self, job_id: str, expected_len: int, *, tenant: str,
               tick_hz: Optional[float] = None,
               qos: str = "silver") -> InFlightJob:
        if tenant not in self._engines:
            raise KeyError(f"unknown tenant {tenant!r}")
        if job_id in self._tenant_of:
            raise ValueError(f"job {job_id!r} already in flight "
                             f"(tenant {self._tenant_of[job_id]!r})")
        job = self._engines[tenant].submit(job_id, expected_len,
                                           tick_hz=tick_hz, qos=qos)
        self._tenant_of[job_id] = tenant
        return job

    def push(self, job_id: str, samples,
             variance: Optional[np.ndarray] = None,
             now: Optional[float] = None) -> None:
        self._engine_of(job_id).push(job_id, samples, variance=variance,
                                     now=now)

    def tick(self, now: Optional[float] = None
             ) -> Dict[str, Optional[TuneDecision]]:
        out: Dict[str, Optional[TuneDecision]] = {}
        for engine in self._engines.values():
            out.update(engine.tick(now=now))
        return out

    def finish(self, job_id: str) -> TuneDecision:
        return self.finish_many((job_id,))[job_id]

    def finish_many(self, job_ids) -> Dict[str, TuneDecision]:
        """Batched verdicts, grouped per tenant: one drain tick + one
        offline dispatch per tenant with completing jobs."""
        ids = list(job_ids)
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate job ids in finish_many")
        missing = [j for j in ids if j not in self._tenant_of]
        if missing:
            raise KeyError(f"unknown job(s): {missing}")
        by_tenant: Dict[str, List[str]] = {}
        for jid in ids:
            by_tenant.setdefault(self._tenant_of[jid], []).append(jid)
        out: Dict[str, TuneDecision] = {}
        for tenant, group in by_tenant.items():
            out.update(self._engines[tenant].finish_many(group))
            for jid in group:
                del self._tenant_of[jid]
        return out

    def finish_later(self, job_id: str) -> None:
        self._engine_of(job_id).finish_later(job_id)
        del self._tenant_of[job_id]

    def drain_finishes(self) -> Dict[str, TuneDecision]:
        out: Dict[str, TuneDecision] = {}
        for engine in self._engines.values():
            out.update(engine.drain_finishes())
        return out

    @property
    def pending_finishes(self) -> int:
        return sum(e.pending_finishes for e in self._engines.values())

    def sweep_stalled(self, now: float) -> Dict[str, Optional[TuneDecision]]:
        out: Dict[str, Optional[TuneDecision]] = {}
        for engine in self._engines.values():
            evicted = engine.sweep_stalled(now)
            for jid in evicted:
                self._tenant_of.pop(jid, None)
            out.update(evicted)
        return out
