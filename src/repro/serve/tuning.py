"""Streaming self-tuning service: match in-flight jobs WHILE they execute.

The paper's end goal is acting on a job *before* it finishes: compare the
utilization pattern observed so far against the reference database, and as
soon as the most probable execution pattern is clear, transfer that
workload's tuned configuration.  The offline ``AutoTuner.match`` scores
complete series only; this service runs the matching phase online.

Architecture (device-resident tick)
-----------------------------------
* Each in-flight job occupies one of ``slots`` fixed slots (continuous-
  batching style, like ``serve.engine.ServeEngine``).  Its incremental DTW
  state — the DP row against the whole reference bank, plus the warp-path
  correlation moments of every row cell — lives stacked with every other
  job's as ``[S, M, K]`` / ``[3, S, M, K]`` device arrays (K last, so the
  reference axis both vectorizes and shards).
* :meth:`tick` drains every job's buffered samples in **one** jitted
  dispatch of the wavefront chunk-extend (``core.dtw``), with prefix
  scoring FUSED into the same dispatch: the device returns a ``[S, K]``
  open-end warp-correlation array, not DP rows.  Nothing of shape
  [C, S, K, M] ever crosses the device boundary — the PR-2 design shipped
  the full row stack to the host and backtracked in numpy every tick.
  ``dispatch_count`` records the invariant: dispatches == ticks(with data)
  no matter how many jobs are in flight.  On TPU backends the distance-
  only tick routes to the Pallas streaming kernel (``kernels.dtw.stream``,
  DP row pinned in VMEM across the chunk).
* ``mesh=`` shards the bank: a 1-D device mesh partitions the ``[M, K]``
  reference bank and every ``[.., K]`` state slab over its single axis via
  ``sharding.compat.shard_map`` (tick fan-out, ``[S, K]`` score gather).
  K scales with device count; the computation is per-reference, so the
  sharded tick is bit-identical to the unsharded one and remains ONE
  dispatch.
* The early-decision rule is confidence/abstain: emit a
  :class:`core.tuner.TuneDecision` only once the leading workload has
  cleared the threshold AND led the runner-up by ``margin`` for
  ``stable_ticks`` consecutive scoring ticks, with at least
  ``min_fraction`` of the job observed.  The margin test requires >= 2
  distinct workloads in the bank — with a single candidate there is no
  runner-up to beat, so the service abstains in flight rather than
  vacuously passing the margin gate (:meth:`finish` still renders the
  final verdict).
* :meth:`finish` recomputes the final verdict offline from the job's full
  (causally filtered) query — one batched ``similarity_bank`` dispatch,
  counted in ``offline_dispatch_count`` — so the end-of-job score is the
  exact offline score regardless of f32 in-flight accumulation or a
  mispredicted ``expected_len`` (the banded corridor anchors to the
  *predicted* length; the offline recompute re-derives it from the true
  one).  When a :class:`ReferenceDB` backs the service, the decision
  (with its ``decided_at_fraction``) is recorded into the DB's decision
  history for margin/stable_ticks/min_fraction calibration.

``denoise=True`` pushes raw samples through the causal streaming Chebyshev
filter (``filters.StreamingFilter``) before matching — the online stand-in
for the offline anti-causal ``filtfilt`` pipeline.  Reference banks are
expected to be stored pre-processed (as ``AutoTuner.profile`` does).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dtw as _dtw
from ..core.database import ReferenceDB, SeriesBank
from ..core.filters import StreamingFilter
from ..core.similarity import MATCH_THRESHOLD, similarity_bank
from ..core.tuner import TuneDecision, _RowBuffer
from ..sharding.compat import shard_map as _shard_map

__all__ = ["InFlightJob", "TuningService"]


@dataclasses.dataclass
class InFlightJob:
    """Host-side bookkeeping for one slot (device state lives stacked in
    the service's ``[S, M, K]`` arrays)."""
    job_id: str
    slot: int
    expected_len: int
    buffered: List[np.ndarray] = dataclasses.field(default_factory=list)
    x: _RowBuffer = dataclasses.field(default_factory=_RowBuffer)
    filt: Optional[StreamingFilter] = None
    n: int = 0
    leader: Optional[str] = None
    stable_for: int = 0
    early: Optional[TuneDecision] = None
    #: last [K] on-device prefix-score row seen for this job (float64 on
    #: the host side; None until the first scoring tick touches the job).
    last_sims: Optional[np.ndarray] = None

    @property
    def fraction_seen(self) -> float:
        return self.n / max(self.expected_len, 1)


class TuningService:
    """Multiplexed online matcher over a fixed reference bank.

    ``refs`` is a :class:`ReferenceDB` (bank + config transfer) or a bare
    :class:`SeriesBank` (matching only).  ``score_in_flight=False`` is the
    distance-only throughput mode: the tick skips the fused scoring (so no
    early decisions; :meth:`finish` still renders the offline verdict) and
    carries no moment slabs — marginally cheaper at very large K.
    ``collect_rows`` is accepted as a deprecated alias from the PR-2 API
    (rows are never collected any more; the name survives because the
    semantics — "score while in flight" — do).

    ``mesh=`` (a 1-D ``jax.sharding.Mesh``) partitions the reference axis
    K over the mesh devices; the bank is padded up to a device-count
    multiple internally and padded rows never surface in scores.
    """

    def __init__(self, refs: Union[ReferenceDB, SeriesBank], *,
                 band: Optional[int] = None,
                 threshold: float = MATCH_THRESHOLD,
                 margin: float = 0.02, stable_ticks: int = 3,
                 min_fraction: float = 0.15, slots: int = 8,
                 denoise: bool = False,
                 score_in_flight: Optional[bool] = None,
                 collect_rows: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None) -> None:
        if isinstance(refs, ReferenceDB):
            self.db: Optional[ReferenceDB] = refs
            self.bank = refs.bank()
        else:
            self.db = None
            self.bank = refs
        if len(self.bank) == 0:
            raise ValueError("empty reference bank")
        if score_in_flight is None:
            score_in_flight = True if collect_rows is None else collect_rows
        self._labels: Tuple[str, ...] = self.bank.labels or tuple(
            f"ref{k}" for k in range(len(self.bank)))
        self._n_workloads = len(set(self._labels))
        self.band = band
        self.threshold = threshold
        self.margin = margin
        self.stable_ticks = stable_ticks
        self.min_fraction = min_fraction
        self.slots = slots
        self.denoise = denoise
        self.score_in_flight = score_in_flight
        self.mesh = mesh

        k, m = self.bank.series.shape
        self._k = k
        ndev = 1
        axis = None
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError("TuningService needs a 1-D mesh (one bank "
                                 f"axis); got axes {mesh.axis_names}")
            axis = mesh.axis_names[0]
            ndev = mesh.devices.size
        kp = k + ((-k) % ndev)
        series_t = np.zeros((m, kp), np.float32)
        series_t[:, :k] = self.bank.series.T
        lengths = np.ones((kp,), np.int32)
        lengths[:k] = self.bank.lengths

        def put(arr, spec):
            if mesh is None:
                return jnp.asarray(arr)
            return jax.device_put(arr, jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*spec)))

        self._bank_t = put(series_t, (None, axis))
        self._lengths = put(lengths, (axis,))
        self._rows = put(np.full((slots, m, kp), float(_dtw._INF),
                                 np.float32), (None, None, axis))
        self._moms = put(np.zeros((3, slots, m, kp), np.float32),
                         (None, None, None, axis)) \
            if score_in_flight else None
        self._ns = put(np.zeros((slots,), np.int32), (None,))
        self._sx = put(np.zeros((slots,), np.float32), (None,))
        self._sxx = put(np.zeros((slots,), np.float32), (None,))
        self._qlens = np.zeros((slots,), np.int32)
        self._free: List[int] = list(range(slots - 1, -1, -1))
        self._jobs: Dict[str, InFlightJob] = {}
        self._tick_fn = self._build_tick_fn(axis)

        #: device dispatches issued by :meth:`tick` — the scaling invariant
        #: is one dispatch per data-carrying tick, however many jobs are
        #: live (and however many devices the bank is sharded over).
        self.dispatch_count = 0
        #: offline ``similarity_bank`` dispatches issued by :meth:`finish`
        #: (the end-of-job exact-verdict recompute; not part of the tick
        #: hot path).
        self.offline_dispatch_count = 0
        self.ticks = 0
        # early decisions emitted by a tick the caller didn't see (e.g.
        # the internal drain tick of another job's finish()); surfaced by
        # the next tick() return so no decision is ever dropped.
        self._undelivered: Dict[str, TuneDecision] = {}

    # -- tick compilation ----------------------------------------------------
    def _build_tick_fn(self, axis: Optional[str]):
        """The ONE jitted callable a tick dispatches: fused scored extend
        (or the distance-only variant), optionally shard_mapped over the
        bank axis.  Sharding is exact — every DP cell and score is a
        per-reference quantity, so the fan-out computes disjoint K slices
        and the [S, K] score gather is the only cross-device output."""
        band = self.band
        if self.score_in_flight:
            if self.mesh is None:
                return functools.partial(_dtw.bank_extend_tick_scored,
                                         band=band)

            def inner(rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                      nvalid, qlens):
                return _dtw._bank_extend_diag_impl(
                    rows, moms, ns, sx, sxx, bank_t, lengths, chunks,
                    nvalid, qlens, band=band, score=True)
            P = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                inner, mesh=self.mesh,
                in_specs=(P(None, None, axis), P(None, None, None, axis),
                          P(), P(), P(), P(None, axis), P(axis), P(), P(),
                          P()),
                out_specs=(P(None, None, axis), P(None, None, None, axis),
                           P(), P(), P(), P(None, axis))))

        if self.mesh is None:
            # bank_extend_tick_dispatch routes to the Pallas streaming
            # kernel on TPU and the (already-jitted) jnp wavefront
            # elsewhere.
            return functools.partial(_dtw.bank_extend_tick_dispatch,
                                     band=band)

        def inner(rows, ns, bank_t, lengths, chunks, nvalid, qlens):
            return _dtw.bank_extend_tick(rows, ns, bank_t, lengths, chunks,
                                         nvalid, qlens, band=band)
        P = jax.sharding.PartitionSpec
        return jax.jit(_shard_map(
            inner, mesh=self.mesh,
            in_specs=(P(None, None, axis), P(), P(None, axis), P(axis),
                      P(), P(), P()),
            out_specs=(P(None, None, axis), P())))

    # -- job lifecycle -------------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._jobs)

    def submit(self, job_id: str, expected_len: int) -> InFlightJob:
        """Register an in-flight job (``expected_len`` = predicted total
        sample count; it anchors the Sakoe-Chiba band and the
        fraction-seen gate of the early-decision rule)."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already in flight")
        if not self._free:
            raise RuntimeError(f"all {self.slots} slots busy")
        if expected_len < 1:
            raise ValueError("expected_len must be >= 1")
        slot = self._free.pop()
        self._rows = self._rows.at[slot].set(_dtw._INF)
        self._ns = self._ns.at[slot].set(0)
        if self._moms is not None:
            self._moms = self._moms.at[:, slot].set(0.0)
        self._sx = self._sx.at[slot].set(0.0)
        self._sxx = self._sxx.at[slot].set(0.0)
        self._qlens[slot] = expected_len
        job = InFlightJob(job_id=job_id, slot=slot, expected_len=expected_len,
                          filt=StreamingFilter() if self.denoise else None)
        self._jobs[job_id] = job
        return job

    def push(self, job_id: str, samples: np.ndarray) -> None:
        """Buffer newly observed samples; consumed at the next tick."""
        s = np.asarray(samples, np.float32).reshape(-1)
        if s.shape[0]:
            self._jobs[job_id].buffered.append(s)

    # -- the hot path --------------------------------------------------------
    def tick(self) -> Dict[str, Optional[TuneDecision]]:
        """Drain every job's buffered samples in ONE jitted dispatch (DP
        extend + prefix scoring fused, sharded over the bank when a mesh
        is set), then apply the early-decision rule to the returned
        [S, K] score array.

        Returns {job_id: TuneDecision} for decisions *newly emitted* this
        tick (None for touched jobs where the service abstains), plus any
        decision a previous internal tick (see :meth:`finish`) emitted but
        could not deliver.
        """
        self.ticks += 1
        out: Dict[str, Optional[TuneDecision]] = self._undelivered
        self._undelivered = {}
        pending: List[Tuple[InFlightJob, np.ndarray]] = []
        for job in self._jobs.values():
            if not job.buffered:
                continue
            chunk = np.concatenate(job.buffered)
            job.buffered.clear()
            if job.filt is not None:
                chunk = job.filt(chunk)
            job.x.append(chunk)
            pending.append((job, chunk))
        if not pending:
            return out

        c = _dtw._chunk_bucket(max(ch.shape[0] for _, ch in pending))
        chunks = np.zeros((self.slots, c), np.float32)
        nvalid = np.zeros((self.slots,), np.int32)
        for job, ch in pending:
            chunks[job.slot, : ch.shape[0]] = ch
            nvalid[job.slot] = ch.shape[0]

        sims_all = None
        if self.score_in_flight:
            (self._rows, self._moms, self._ns, self._sx, self._sxx,
             scores) = self._tick_fn(
                self._rows, self._moms, self._ns, self._sx, self._sxx,
                self._bank_t, self._lengths, jnp.asarray(chunks),
                jnp.asarray(nvalid), jnp.asarray(self._qlens))
            # the tick's ONLY device->host transfer: [S, K] scores.
            sims_all = np.asarray(scores, np.float64)[:, : self._k]
        else:
            self._rows, self._ns = self._tick_fn(
                self._rows, self._ns, self._bank_t, self._lengths,
                jnp.asarray(chunks), jnp.asarray(nvalid),
                jnp.asarray(self._qlens))
        self.dispatch_count += 1

        for job, ch in pending:
            job.n += ch.shape[0]
            decision = None
            if sims_all is not None:
                job.last_sims = sims_all[job.slot]
                if job.early is None:
                    decision = self._maybe_decide(job)
            if out.get(job.job_id) is None:
                out[job.job_id] = decision
        return out

    # -- decision rule -------------------------------------------------------
    def _reduce(self, sims: np.ndarray) -> Dict[str, float]:
        """Per-workload best over the bank's (possibly multi-entry) rows."""
        scores: Dict[str, float] = {}
        for lbl, s in zip(self._labels, sims):
            scores[lbl] = max(scores.get(lbl, -1.0), float(s))
        return scores

    @staticmethod
    def _rank(scores: Dict[str, float]) -> Tuple[str, float, float]:
        """(leader, leader_score, runner_up_score); insertion order breaks
        ties so repeated ticks rank deterministically."""
        leader, ls = None, -np.inf
        for w, s in scores.items():
            if s > ls:
                leader, ls = w, s
        rs = max((s for w, s in scores.items() if w != leader), default=-1.0)
        return leader, ls, rs

    def _maybe_decide(self, job: InFlightJob) -> Optional[TuneDecision]:
        if job.n < 2:
            return None
        scores = self._reduce(job.last_sims)
        leader, ls, rs = self._rank(scores)
        # the margin test needs a real runner-up: with < 2 workloads in
        # the bank it would be vacuously true (rs == -1.0), so the
        # service abstains in flight instead of fast-tracking the only
        # candidate (finish() still decides from the complete series).
        margin_ok = self._n_workloads >= 2 and ls - rs >= self.margin
        if leader == job.leader and margin_ok:
            job.stable_for += 1
        else:
            job.stable_for = 1 if margin_ok else 0
        job.leader = leader
        if (job.fraction_seen >= self.min_fraction
                and ls >= self.threshold
                and job.stable_for >= self.stable_ticks):
            cfg = self.db.best_config(leader) if self.db is not None else None
            job.early = TuneDecision(
                workload=job.job_id, matched=leader, corr=ls, config=cfg,
                scores=scores, fraction_seen=job.fraction_seen, final=False,
                decided_at_fraction=job.fraction_seen)
            return job.early
        return None

    # -- completion ----------------------------------------------------------
    def finish(self, job_id: str) -> TuneDecision:
        """Final verdict for a completed job, recomputed offline from the
        full streamed (causally filtered) query: exactly the batched
        ``similarity_bank`` score, with the Sakoe-Chiba band re-derived
        from the *true* length (the in-flight corridor was anchored to
        the ``expected_len`` prediction).  Frees the slot and, when a
        ReferenceDB backs the service, records the decision history.
        """
        job = self._jobs[job_id]
        if job.buffered:
            emitted = self.tick()
            for jid, d in emitted.items():
                if jid != job_id and d is not None:
                    self._undelivered[jid] = d
        x = job.x.view()
        if job.n >= 2:
            sims = similarity_bank(x, self.bank, band=self.band)
            self.offline_dispatch_count += 1
        else:
            sims = np.zeros((len(self.bank),), np.float64)
        scores = self._reduce(sims)
        leader, ls, _ = self._rank(scores)
        matched = leader if ls >= self.threshold else None
        cfg = self.db.best_config(matched) \
            if self.db is not None and matched is not None else None
        del self._jobs[job_id]
        # a drain tick may have parked this job's own early decision for
        # later delivery; it must not outlive the job (the id is reusable)
        self._undelivered.pop(job_id, None)
        self._free.append(job.slot)
        decision = TuneDecision(
            workload=job_id, matched=matched, corr=ls, config=cfg,
            scores=scores, fraction_seen=1.0, final=True,
            decided_at_fraction=(job.early.decided_at_fraction
                                 if job.early is not None else 1.0))
        if self.db is not None:
            self.db.record_decision(decision)
        return decision
