"""Streaming self-tuning service: match in-flight jobs WHILE they execute.

The paper's end goal is acting on a job *before* it finishes: compare the
utilization pattern observed so far against the reference database, and as
soon as the most probable execution pattern is clear, transfer that
workload's tuned configuration.  The offline ``AutoTuner.match`` scores
complete series only; this service runs the matching phase online.

Architecture
------------
* Each in-flight job occupies one of ``slots`` fixed slots (continuous-
  batching style, like ``serve.engine.ServeEngine``).  Its incremental DTW
  state — the [K, M] DP row against the whole reference bank — lives
  stacked with every other job's as one ``[S, K, M]`` device array.
* :meth:`tick` drains every job's buffered samples in **one** jitted
  dispatch (``core.dtw._bank_extend_many``): per tick, the device sees one
  ``[S, C]`` chunk matrix, not one call per job.  ``dispatch_count``
  records exactly that — the service's scaling claim is dispatches ==
  ticks, independent of how many jobs are in flight.
* Prefix scores are the open-ended warp correlations of
  ``similarity.prefix_similarity_bank``; the early-decision rule is
  confidence/abstain: emit a :class:`core.tuner.TuneDecision` only once
  the leading workload has cleared the threshold AND led the runner-up by
  ``margin`` for ``stable_ticks`` consecutive scoring ticks, with at least
  ``min_fraction`` of the job observed.  Otherwise the service abstains
  and keeps watching.
* :meth:`finish` produces the final verdict from the full streamed DP —
  exactly the offline ``similarity_bank`` score of the completed query
  (same matrix, same backtrack), so going online costs no accuracy at the
  end of the job.

``denoise=True`` pushes raw samples through the causal streaming Chebyshev
filter (``filters.StreamingFilter``) before matching — the online stand-in
for the offline anti-causal ``filtfilt`` pipeline.  Reference banks are
expected to be stored pre-processed (as ``AutoTuner.profile`` does).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core import dtw as _dtw
from ..core.database import ReferenceDB, SeriesBank
from ..core.filters import StreamingFilter
from ..core.similarity import (MATCH_THRESHOLD, prefix_similarity_bank,
                               similarity_bank)
from ..core.tuner import TuneDecision, _RowBuffer

__all__ = ["InFlightJob", "TuningService"]


@dataclasses.dataclass
class InFlightJob:
    """Host-side bookkeeping for one slot (device state lives stacked in
    the service's ``[S, K, M]`` array)."""
    job_id: str
    slot: int
    expected_len: int
    buffered: List[np.ndarray] = dataclasses.field(default_factory=list)
    x: _RowBuffer = dataclasses.field(default_factory=_RowBuffer)
    rows: _RowBuffer = dataclasses.field(default_factory=_RowBuffer)
    filt: Optional[StreamingFilter] = None
    n: int = 0
    leader: Optional[str] = None
    stable_for: int = 0
    early: Optional[TuneDecision] = None

    @property
    def fraction_seen(self) -> float:
        return self.n / max(self.expected_len, 1)


class TuningService:
    """Multiplexed online matcher over a fixed reference bank.

    ``refs`` is a :class:`ReferenceDB` (bank + config transfer) or a bare
    :class:`SeriesBank` (matching only).  ``collect_rows=False`` is the
    distance-only throughput mode: no warp correlations in flight (early
    decisions are disabled; :meth:`finish` falls back to one offline
    ``similarity_bank`` dispatch), but ticks move no [C, S, K, M] row
    traffic — the mode to run with very large banks.
    """

    def __init__(self, refs: Union[ReferenceDB, SeriesBank], *,
                 band: Optional[int] = None,
                 threshold: float = MATCH_THRESHOLD,
                 margin: float = 0.02, stable_ticks: int = 3,
                 min_fraction: float = 0.15, slots: int = 8,
                 denoise: bool = False, collect_rows: bool = True) -> None:
        if isinstance(refs, ReferenceDB):
            self.db: Optional[ReferenceDB] = refs
            self.bank = refs.bank()
        else:
            self.db = None
            self.bank = refs
        if len(self.bank) == 0:
            raise ValueError("empty reference bank")
        self._labels: Tuple[str, ...] = self.bank.labels or tuple(
            f"ref{k}" for k in range(len(self.bank)))
        self.band = band
        self.threshold = threshold
        self.margin = margin
        self.stable_ticks = stable_ticks
        self.min_fraction = min_fraction
        self.slots = slots
        self.denoise = denoise
        self.collect_rows = collect_rows

        k, m = self.bank.series.shape
        self._bank_dev = jnp.asarray(self.bank.series, jnp.float32)
        self._lengths_dev = jnp.asarray(self.bank.lengths, jnp.int32)
        self._rows_dev = jnp.full((slots, k, m), _dtw._INF)
        self._ns_dev = jnp.zeros((slots,), jnp.int32)
        self._qlens = np.zeros((slots,), np.int32)
        self._free: List[int] = list(range(slots - 1, -1, -1))
        self._jobs: Dict[str, InFlightJob] = {}

        #: device dispatches issued by :meth:`tick` — the scaling invariant
        #: is ``dispatch_count == ticks`` no matter how many jobs are live.
        self.dispatch_count = 0
        self.ticks = 0
        # early decisions emitted by a tick the caller didn't see (e.g.
        # the internal drain tick of another job's finish()); surfaced by
        # the next tick() return so no decision is ever dropped.
        self._undelivered: Dict[str, TuneDecision] = {}

    # -- job lifecycle -------------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._jobs)

    def submit(self, job_id: str, expected_len: int) -> InFlightJob:
        """Register an in-flight job (``expected_len`` = predicted total
        sample count; it anchors the Sakoe-Chiba band and the
        fraction-seen gate of the early-decision rule)."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already in flight")
        if not self._free:
            raise RuntimeError(f"all {self.slots} slots busy")
        if expected_len < 1:
            raise ValueError("expected_len must be >= 1")
        slot = self._free.pop()
        self._rows_dev = self._rows_dev.at[slot].set(_dtw._INF)
        self._ns_dev = self._ns_dev.at[slot].set(0)
        self._qlens[slot] = expected_len
        job = InFlightJob(job_id=job_id, slot=slot, expected_len=expected_len,
                          filt=StreamingFilter() if self.denoise else None)
        self._jobs[job_id] = job
        return job

    def push(self, job_id: str, samples: np.ndarray) -> None:
        """Buffer newly observed samples; consumed at the next tick."""
        s = np.asarray(samples, np.float32).reshape(-1)
        if s.shape[0]:
            self._jobs[job_id].buffered.append(s)

    # -- the hot path --------------------------------------------------------
    def tick(self) -> Dict[str, Optional[TuneDecision]]:
        """Drain every job's buffered samples in ONE jitted dispatch, then
        re-score the touched jobs and apply the early-decision rule.

        Returns {job_id: TuneDecision} for decisions *newly emitted* this
        tick (None for touched jobs where the service abstains), plus any
        decision a previous internal tick (see :meth:`finish`) emitted but
        could not deliver.
        """
        self.ticks += 1
        out: Dict[str, Optional[TuneDecision]] = self._undelivered
        self._undelivered = {}
        pending: List[Tuple[InFlightJob, np.ndarray]] = []
        for job in self._jobs.values():
            if not job.buffered:
                continue
            chunk = np.concatenate(job.buffered)
            job.buffered.clear()
            if job.filt is not None:
                chunk = job.filt(chunk)
            job.x.append(chunk)
            pending.append((job, chunk))
        if not pending:
            return out

        c = _dtw._chunk_bucket(max(ch.shape[0] for _, ch in pending))
        chunks = np.zeros((self.slots, c), np.float32)
        nvalid = np.zeros((self.slots,), np.int32)
        for job, ch in pending:
            chunks[job.slot, : ch.shape[0]] = ch
            nvalid[job.slot] = ch.shape[0]

        self._rows_dev, self._ns_dev, collected = _dtw._bank_extend_many(
            self._rows_dev, self._ns_dev, self._bank_dev, self._lengths_dev,
            jnp.asarray(chunks), jnp.asarray(nvalid), jnp.asarray(self._qlens),
            self.band, self.collect_rows)
        self.dispatch_count += 1

        if self.collect_rows:
            collected_np = np.asarray(collected)      # [C, S, K, M]
        for job, ch in pending:
            job.n += ch.shape[0]
            if self.collect_rows:
                job.rows.append(collected_np[: ch.shape[0], job.slot])
            decision = self._maybe_decide(job) \
                if job.early is None and self.collect_rows else None
            if out.get(job.job_id) is None:
                out[job.job_id] = decision
        return out

    # -- decision rule -------------------------------------------------------
    def _reduce(self, sims: np.ndarray) -> Dict[str, float]:
        """Per-workload best over the bank's (possibly multi-entry) rows."""
        scores: Dict[str, float] = {}
        for lbl, s in zip(self._labels, sims):
            scores[lbl] = max(scores.get(lbl, -1.0), float(s))
        return scores

    @staticmethod
    def _rank(scores: Dict[str, float]) -> Tuple[str, float, float]:
        """(leader, leader_score, runner_up_score); insertion order breaks
        ties so repeated ticks rank deterministically."""
        leader, ls = None, -np.inf
        for w, s in scores.items():
            if s > ls:
                leader, ls = w, s
        rs = max((s for w, s in scores.items() if w != leader), default=-1.0)
        return leader, ls, rs

    def _maybe_decide(self, job: InFlightJob) -> Optional[TuneDecision]:
        if job.n < 2:
            return None
        sims = prefix_similarity_bank(job.x.view(), self.bank,
                                      job.rows.view())
        scores = self._reduce(sims)
        leader, ls, rs = self._rank(scores)
        if leader == job.leader and ls - rs >= self.margin:
            job.stable_for += 1
        else:
            job.stable_for = 1 if ls - rs >= self.margin else 0
        job.leader = leader
        if (job.fraction_seen >= self.min_fraction
                and ls >= self.threshold
                and job.stable_for >= self.stable_ticks):
            cfg = self.db.best_config(leader) if self.db is not None else None
            job.early = TuneDecision(
                workload=job.job_id, matched=leader, corr=ls, config=cfg,
                scores=scores, fraction_seen=job.fraction_seen, final=False)
            return job.early
        return None

    # -- completion ----------------------------------------------------------
    def finish(self, job_id: str) -> TuneDecision:
        """Final verdict for a completed job: exactly the offline
        ``similarity_bank`` score of the full streamed query.  Frees the
        slot.

        Banded caveat: the streamed corridor was anchored to the
        *predicted* ``expected_len``; if the job ended at a different
        length the streamed DP's band is misplaced, so the final score is
        recomputed offline (one batched dispatch) with the band re-derived
        from the true length — the verdict self-corrects even when the
        runtime prediction was wrong.
        """
        job = self._jobs[job_id]
        if job.buffered:
            emitted = self.tick()
            for jid, d in emitted.items():
                if jid != job_id and d is not None:
                    self._undelivered[jid] = d
        x = job.x.view()
        band_ok = self.band is None or job.n == job.expected_len
        if job.n >= 2 and self.collect_rows and band_ok:
            sims = prefix_similarity_bank(x, self.bank, job.rows.view(),
                                          open_end=False)
        elif job.n >= 2:
            sims = similarity_bank(x, self.bank, band=self.band)
            self.dispatch_count += 1
        else:
            sims = np.zeros((len(self.bank),), np.float64)
        scores = self._reduce(sims)
        leader, ls, _ = self._rank(scores)
        matched = leader if ls >= self.threshold else None
        cfg = self.db.best_config(matched) \
            if self.db is not None and matched is not None else None
        del self._jobs[job_id]
        # a drain tick may have parked this job's own early decision for
        # later delivery; it must not outlive the job (the id is reusable)
        self._undelivered.pop(job_id, None)
        self._free.append(job.slot)
        return TuneDecision(workload=job_id, matched=matched, corr=ls,
                            config=cfg, scores=scores, fraction_seen=1.0,
                            final=True)
