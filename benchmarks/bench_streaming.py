"""Streaming matching-service benchmarks (the online half of paper Fig. 4-b).

1. Paper scenario (early decision): an Exim job is matched WHILE it runs
   against a WordCount/TeraSort reference bank (Table-1 setting, monitored
   at 4 Hz).  Gates: the service emits a correct early decision at <= 60%
   of job runtime for at least one parameter set, and a tick is ONE device
   dispatch no matter how many jobs are in flight.
2. Equivalence: for every mrsim app x paper parameter set, the final
   streamed score equals the offline ``similarity_bank`` of the same
   (causally filtered) query to 1e-4 — going online costs no accuracy.
3. Throughput: chunks/sec through the multiplexed tick at bank size
   K in {8, 64, 256} — distance-only mode, plus (at K=256) the fused
   on-device scoring tick, BOTH probabilistic scoring ticks (the PR-7
   exact 6-channel tick and the approximate 4-channel serving tick,
   ``prob_mode="approx"``), and the PR-2 row-formulation jnp baseline.
   Gates: the device-resident wavefront tick is >= 3x the PR-2 path,
   the approx serving tick stays within PROB_TICK_GATE (1.35x) of the
   exact scored tick, and the exact probabilistic tick stays within
   PROB_TICK_EXACT_GATE (2.5x — its 6-channel slab sets a ~1.7-2x
   structural floor).
4. Pruned scoring (the production scored tick at large K): a DIVERSE
   256-reference bank (one distinct workload signature per row — the
   regime the streaming wavelet prefilter targets) with every in-flight
   job an instance of a profiled workload.  Gates: the pruned scored
   tick is >= 4x the unpruned (PR-3) jnp scored tick on the same
   workload, lands within 3x of the distance-only tick, keeps every
   job's true reference alive, ranks the same leaders as the unpruned
   service, and dispatches == ticks with re-packs counted separately.
5. Continuous-batching churn (stream_tick_S{8,64,256,1024}): seeded
   Poisson arrivals and finishes EVERY tick against the 3-app paper
   bank, jobs split across mixed 4/20/100 Hz tick-rate cohorts, slots
   elastic (S-axis power-of-two buckets growing/compact-shrinking under
   the live set), completions retired through the batched finish_later
   drain queue.  Gates: the scenario runs end-to-end at every S with
   dispatches bounded by data-ticks (one dispatch per tick however many
   jobs/cohorts are live), and the elastic run's decisions — early and
   final — are BIT-identical to a fixed-slot reference run of the same
   schedule.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro import mrsim
from repro.core import OnlineMatcher, StreamingFilter, similarity_bank
from repro.core.database import SeriesBank, pack_series
from repro.core.filters import preprocess_bank
from repro.serve.tuning import TuningService

#: 4 Hz monitoring of the paper's 1 Hz-profiled jobs: the same traces, fine
#: enough ticks that "decide before the job ends" is meaningful on runs of
#: 30-55 s.  The Sakoe-Chiba band scales with the sample rate (Table-1
#: uses 8 at 1 Hz).
DT = 0.25
BAND = 16
CHUNK = 8
THRESHOLD = 0.85
EARLY_FRACTION_GATE = 0.6
BANK_SIZES = (8, 64, 256)
TPUT_JOBS = 8
TPUT_TICKS = 16
TPUT_CHUNK = 16
#: ceiling on the SERVING probabilistic tick (``prob_mode="approx"``,
#: the 4-channel sigma^2-proxy tail) relative to the exact scored tick
#: at K=256.  The approx slab adds one moment channel (3 -> 4) instead
#: of three, so the bandwidth-bound wavefront stays near the scored
#: tick; 1.35 pins that — the gate the ISSUE's 1.3x aspiration asked
#: for, now achievable because the approx tail ships.
PROB_TICK_GATE = 1.35
#: ceiling on the EXACT variance-carrying tick (``prob_mode="exact"``,
#: the PR-7 6-channel slab that backs verdicts and calibration).
#: Measured 1.7-2.0x (bandwidth-bound doubling of the 3-channel moment
#: traffic); 2.5 leaves machine-variance slack above that structural
#: floor.  Kept as its own row so the exact path holds its own
#: trajectory while the serving row tightens.
PROB_TICK_EXACT_GATE = 2.5


def _paper_bank(apps) -> SeriesBank:
    """One preprocessed reference entry per (app, parameter set) — what
    ``AutoTuner.profile`` would have stored from the profiling runs."""
    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in apps:
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=DT))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


def _early_decision_rows():
    bank = _paper_bank(("wordcount", "terasort"))
    psets = mrsim.paper_param_sets()
    rows = []
    hits = []
    t0 = time.time()
    for j, p in enumerate(psets):
        svc = TuningService(bank, band=BAND, threshold=THRESHOLD,
                            margin=0.02, stable_ticks=3, min_fraction=0.15,
                            denoise=True)
        q = mrsim.simulate_cpu_series("exim", p, run=1, dt=DT)
        svc.submit("exim", expected_len=len(q))
        early = None
        for chunk in mrsim.iter_cpu_series("exim", p, run=1, chunk=CHUNK,
                                           dt=DT):
            svc.push("exim", chunk)
            d = svc.tick().get("exim")
            if d is not None and early is None:
                early = d
        final = svc.finish("exim")
        assert svc.dispatch_count <= svc.ticks, \
            "tick issued more than one dispatch"
        assert final.matched == "wordcount", final.scores
        frac = early.fraction_seen if early is not None else 1.0
        correct = early is not None and early.matched == "wordcount"
        if correct:
            hits.append(frac)
        print(f"[streaming] pset{j}: early="
              f"{early.matched if early else None}@{frac:.2f} "
              f"final={final.matched} "
              f"(wc={final.scores['wordcount']:.3f} "
              f"ts={final.scores['terasort']:.3f})")
        rows.append((f"stream_early_p{j}", frac * 1e6,
                     f"early={'%.2f' % frac if correct else 'none'}"
                     f";final={final.matched}"))
    dt = time.time() - t0
    assert hits and min(hits) <= EARLY_FRACTION_GATE, (
        f"no correct early decision at <= {EARLY_FRACTION_GATE:.0%} of "
        f"runtime (got {hits})")
    print(f"[streaming] correct early decisions on {len(hits)}/4 param sets"
          f", earliest at {min(hits):.0%} of job runtime")
    rows.append(("stream_early_best", min(hits) * 1e6,
                 f"earliest_fraction={min(hits):.2f};wall_s={dt:.1f}"))
    return rows


def _multiplex_rows():
    """All three apps in flight concurrently — dispatches stay == ticks."""
    bank = _paper_bank(tuple(mrsim.APPS))
    p = mrsim.paper_param_sets()[1]
    svc = TuningService(bank, band=BAND, threshold=THRESHOLD, denoise=True,
                        slots=len(mrsim.APPS))
    streams = {}
    for app in mrsim.APPS:
        q = mrsim.simulate_cpu_series(app, p, run=2, dt=DT)
        svc.submit(app, expected_len=len(q))
        streams[app] = mrsim.iter_cpu_series(app, p, run=2, chunk=CHUNK,
                                             dt=DT)
    t0 = time.time()
    live = set(streams)
    correct = 0
    while live:
        for app in list(live):
            chunk = next(streams[app], None)
            if chunk is None:
                d = svc.finish(app)
                # exim's own twin is wordcount (paper: same text-parse
                # family); everything else must match itself.
                want = {"exim": ("exim", "wordcount")}.get(app, (app,))
                correct += d.matched in want
                live.discard(app)
            else:
                svc.push(app, chunk)
        svc.tick()
    dt = time.time() - t0
    assert svc.dispatch_count <= svc.ticks, \
        "a multi-job tick must be ONE dispatch, not one per job"
    assert correct == len(mrsim.APPS)
    print(f"[streaming] {len(mrsim.APPS)} concurrent jobs: "
          f"{svc.dispatch_count} dispatches over {svc.ticks} ticks, "
          f"{correct}/{len(mrsim.APPS)} correct finals")
    return [("stream_multiplex", dt / max(svc.ticks, 1) * 1e6,
             f"dispatches={svc.dispatch_count};ticks={svc.ticks}"
             f";jobs={len(mrsim.APPS)}")]


def _equivalence_rows():
    """Final streamed score == offline similarity_bank, every app x pset."""
    bank = _paper_bank(tuple(mrsim.APPS))
    psets = mrsim.paper_param_sets()
    worst = 0.0
    t0 = time.time()
    for app in mrsim.APPS:
        for p in psets:
            om = OnlineMatcher(bank, band=BAND, denoise=True,
                               query_len=len(mrsim.simulate_cpu_series(
                                   app, p, run=1, dt=DT)))
            for chunk in mrsim.iter_cpu_series(app, p, run=1, chunk=CHUNK,
                                               dt=DT):
                om.extend(chunk)
            streamed = om.final_scores()
            offline = similarity_bank(
                StreamingFilter()(mrsim.simulate_cpu_series(app, p, run=1,
                                                            dt=DT)),
                bank, band=BAND)
            worst = max(worst, float(np.abs(streamed - offline).max()))
    dt = time.time() - t0
    n = len(mrsim.APPS) * len(psets)
    assert worst <= 1e-4, f"streamed vs offline diverged: {worst}"
    print(f"[streaming] streamed == offline on {n} app x pset pairs "
          f"(max err {worst:.2e})")
    return [("stream_offline_equiv", dt / n * 1e6, f"max_err={worst:.2e}")]


def _throughput_bank(rng, k):
    buckets = (180, 220, 256, 300, 330, 360)
    series = []
    for i in range(k):
        l = buckets[int(rng.integers(len(buckets)))]
        t = np.linspace(0, 1, l, dtype=np.float32)
        s = (0.5 + 0.3 * np.sin(2 * np.pi * (2 + i % 5) * t)
             + 0.1 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return pack_series(series)


def _legacy_tick_us(bank, rng):
    """us/tick of the PR-2 jnp tick (row-formulation ``_bank_extend_many``
    on [S, K, M] state) at the throughput-bench shapes — the baseline the
    wavefront tick's speedup is measured against."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    k, m = bank.series.shape
    bank_dev = jnp.asarray(bank.series)
    lengths = jnp.asarray(bank.lengths)
    qlens = jnp.full((TPUT_JOBS,), TPUT_TICKS * TPUT_CHUNK, jnp.int32)
    chunks = jnp.asarray(rng.random((TPUT_JOBS, TPUT_CHUNK),
                                    dtype=np.float32))
    nvalid = jnp.full((TPUT_JOBS,), TPUT_CHUNK, jnp.int32)

    def run(nticks):
        rows = jnp.full((TPUT_JOBS, k, m), _dtw._INF)
        ns = jnp.zeros((TPUT_JOBS,), jnp.int32)
        for _ in range(nticks):
            rows, ns, _ = _dtw._bank_extend_many(
                rows, ns, bank_dev, lengths, chunks, nvalid, qlens,
                None, False)
        rows.block_until_ready()

    run(2)                                 # warm the jit cache
    nticks = 4
    t0 = time.time()
    run(nticks)
    return (time.time() - t0) / nticks * 1e6


def _throughput_rows():
    rows = []
    rng = np.random.default_rng(0)
    for k in BANK_SIZES:
        bank = _throughput_bank(rng, k)

        def run_stream(score, prob=False, prob_mode="exact"):
            if prob:
                svc = TuningService(bank, score_in_flight=True,
                                    min_probability=0.5,
                                    prob_mode=prob_mode)
            else:
                svc = TuningService(bank, score_in_flight=score)
            for j in range(TPUT_JOBS):
                svc.submit(f"job{j}", expected_len=TPUT_TICKS * TPUT_CHUNK)
            qs = rng.random((TPUT_JOBS, TPUT_TICKS * TPUT_CHUNK),
                            dtype=np.float32)
            vs = np.full_like(qs, 1e-3) if prob else None
            for t in range(TPUT_TICKS):
                sl = slice(t * TPUT_CHUNK, (t + 1) * TPUT_CHUNK)
                for j in range(TPUT_JOBS):
                    if prob:
                        svc.push(f"job{j}", qs[j, sl], variance=vs[j, sl])
                    else:
                        svc.push(f"job{j}", qs[j, sl])
                svc.tick()
            assert svc.dispatch_count == TPUT_TICKS
            return svc

        run_stream(False)                 # warm the jit cache
        t0 = time.time()
        svc = run_stream(False)
        dt = time.time() - t0
        chunks = TPUT_TICKS * TPUT_JOBS
        cps = chunks / dt
        sps = chunks * TPUT_CHUNK / dt
        print(f"[streaming] K={k:4d}: {1e3 * dt / TPUT_TICKS:7.2f} ms/tick  "
              f"{cps:8.0f} chunks/s  {sps:9.0f} samples/s")
        rows.append((f"stream_tick_K{k}", dt / TPUT_TICKS * 1e6,
                     f"chunks_per_s={cps:.0f};samples_per_s={sps:.0f}"
                     f";jobs={TPUT_JOBS}"))

        if k == max(BANK_SIZES):
            # scoring tick (fused on-device prefix scoring, the early-
            # decision hot path) at the largest bank
            run_stream(True)
            t0 = time.time()
            run_stream(True)
            dts = time.time() - t0
            print(f"[streaming] K={k:4d}: {1e3 * dts / TPUT_TICKS:7.2f} "
                  f"ms/tick (fused scoring)")
            rows.append((f"stream_tick_scored_K{k}",
                         dts / TPUT_TICKS * 1e6,
                         f"chunks_per_s={chunks / dts:.0f};jobs={TPUT_JOBS}"))
            # probabilistic scoring ticks, both tails.  The EXACT
            # (PR-7) tick carries the 6-channel moment slab: the
            # delta-method sigma^2 needs three path-dependent sums
            # Sum v*y, Sum v*y^2, Sum v*xy on top of the base three,
            # and the wavefront scan is bandwidth-bound on slab
            # traffic, so ~1.7-2x the scored tick is its structural
            # floor.  The APPROX serving tick (prob_mode="approx")
            # carries ONE extra channel — Sum v*y riding the warp path,
            # with Sum v*y^2 / Sum v*xy reconstructed at the score tail
            # from the per-job variance folds — so it stays near the
            # scored tick and is gated at PROB_TICK_GATE (1.35x).
            # finish()/finish_many() always re-score with the exact
            # tail, so verdict probabilities are identical either way.
            run_stream(True, prob=True)
            t0 = time.time()
            run_stream(True, prob=True)
            dte = time.time() - t0
            ratio_e = dte / dts
            print(f"[streaming] K={k:4d}: {1e3 * dte / TPUT_TICKS:7.2f} "
                  f"ms/tick (exact prob scoring) -> {ratio_e:.2f}x "
                  f"exact scored")
            rows.append((f"stream_tick_prob_exact_K{k}",
                         dte / TPUT_TICKS * 1e6,
                         f"chunks_per_s={chunks / dte:.0f}"
                         f";vs_exact_scored={ratio_e:.2f}x"
                         f";jobs={TPUT_JOBS}"))
            assert ratio_e <= PROB_TICK_EXACT_GATE, (
                f"exact probabilistic tick regressed: {ratio_e:.2f}x > "
                f"{PROB_TICK_EXACT_GATE}x the exact scored tick")
            run_stream(True, prob=True, prob_mode="approx")
            t0 = time.time()
            run_stream(True, prob=True, prob_mode="approx")
            dtp = time.time() - t0
            ratio = dtp / dts
            print(f"[streaming] K={k:4d}: {1e3 * dtp / TPUT_TICKS:7.2f} "
                  f"ms/tick (approx prob scoring) -> {ratio:.2f}x "
                  f"exact scored")
            rows.append((f"stream_tick_prob_K{k}",
                         dtp / TPUT_TICKS * 1e6,
                         f"chunks_per_s={chunks / dtp:.0f}"
                         f";vs_exact_scored={ratio:.2f}x"
                         f";prob_mode=approx;jobs={TPUT_JOBS}"))
            assert ratio <= PROB_TICK_GATE, (
                f"approx probabilistic tick regressed: {ratio:.2f}x > "
                f"{PROB_TICK_GATE}x the exact scored tick")
            # PR-2 baseline + speedup gate: the device-resident wavefront
            # tick must beat the row-formulation jnp tick >= 3x here
            legacy_us = _legacy_tick_us(bank, rng)
            speedup = legacy_us / (dt / TPUT_TICKS * 1e6)
            print(f"[streaming] K={k:4d}: {legacy_us / 1e3:7.2f} ms/tick "
                  f"(PR-2 jnp path) -> wavefront speedup {speedup:.1f}x")
            rows.append((f"stream_tick_K{k}_pr2_jnp", legacy_us,
                         f"wavefront_speedup={speedup:.2f}x"))
            assert speedup >= 3.0, (
                f"device-resident tick speedup regressed: {speedup:.2f}x "
                f"< 3x over the PR-2 jnp path")
    return rows


#: pruned-tick scenario knobs: strict per-job top-P (the soundness
#: margin rides on the in-flight DTW veto — see serve.tuning), pruning
#: engaged once 10% of a job has been observed.
PRUNED_TOP = 2
PRUNED_MIN_FRACTION = 0.1


def _diverse_bank(rng, k):
    """One distinct workload signature per reference — the large-K regime
    the streaming prefilter targets (a production reference DB is many
    distinct workloads, not clones of five families)."""
    buckets = (180, 220, 256, 300, 330, 360)
    series = []
    for i in range(k):
        l = buckets[int(rng.integers(len(buckets)))]
        t = np.linspace(0, 1, l, dtype=np.float32)
        f = 1.5 + 0.07 * i
        s = (0.5 + 0.28 * np.sin(2 * np.pi * f * t + 0.37 * i)
             + 0.12 * np.sin(2 * np.pi * 3.1 * f * t)
             + 0.06 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return pack_series(series)


def _pruned_scored_rows():
    """stream_tick_scored_pruned_K256: the fused scoring tick with the
    streaming-Haar prefilter shrinking the bank to the survivor union."""
    k = max(BANK_SIZES)
    rng = np.random.default_rng(7)
    bank = _diverse_bank(rng, k)
    qlen = TPUT_TICKS * TPUT_CHUNK
    long_refs = [i for i in range(k) if bank.lengths[i] >= qlen + 8]
    # pairs of jobs run the same workload (concurrent instances), four
    # distinct workloads in flight
    targets = [long_refs[(j // 2) * 17] for j in range(TPUT_JOBS)]

    def queries(seed):
        r = np.random.default_rng(seed)
        return np.stack([np.clip(bank.row(targets[j])[:qlen]
                                 + 0.05 * r.normal(size=qlen), 0, 1)
                         .astype(np.float32) for j in range(TPUT_JOBS)])

    def run(mode, seed=1):
        svc = TuningService(
            bank, score_in_flight=(mode != "distance"),
            prefilter_top=PRUNED_TOP if mode == "pruned" else None,
            prefilter_margin=0.0,
            prefilter_min_fraction=PRUNED_MIN_FRACTION)
        for j in range(TPUT_JOBS):
            svc.submit(f"job{j}", expected_len=qlen)
        qs = queries(seed)
        for t in range(TPUT_TICKS):
            for j in range(TPUT_JOBS):
                svc.push(f"job{j}",
                         qs[j, t * TPUT_CHUNK:(t + 1) * TPUT_CHUNK])
            svc.tick()
        assert svc.dispatch_count == TPUT_TICKS, \
            "pruning broke the one-dispatch-per-tick invariant"
        return svc

    def timed(mode):
        run(mode)                     # warm the jit cache, same seed
        t0 = time.time()
        svc = run(mode)
        return svc, (time.time() - t0) / TPUT_TICKS * 1e6

    svc_d, us_dist = timed("distance")
    svc_f, us_full = timed("scored")
    svc_p, us_pruned = timed("pruned")

    # soundness: every job's true reference survived its prune, and the
    # pruned service ranks the same leader per job as the unpruned one.
    for j, tj in enumerate(targets):
        job = svc_p._jobs[f"job{j}"]
        assert tj in svc_p._packed_idx and (job.allowed is None
                                            or job.allowed[tj]), \
            f"prefilter dropped job{j}'s true reference {tj}"
        lead_p = int(np.argmax(job.last_sims))
        lead_f = int(np.argmax(svc_f._jobs[f"job{j}"].last_sims))
        assert lead_p == lead_f, (j, lead_p, lead_f)
    assert svc_p.repack_count >= 1

    speedup = us_full / us_pruned
    vs_dist = us_pruned / us_dist
    survivors = len(svc_p._packed_idx)
    print(f"[streaming] K={k:4d}: {us_full / 1e3:7.2f} ms/tick scored "
          f"(unpruned) vs {us_pruned / 1e3:7.2f} ms/tick pruned "
          f"(survivors={survivors}, repacks={svc_p.repack_count}) -> "
          f"{speedup:.1f}x, {vs_dist:.2f}x the distance-only tick "
          f"({us_dist / 1e3:.2f} ms)")
    assert speedup >= 4.0, (
        f"pruned scored tick speedup regressed: {speedup:.2f}x < 4x over "
        f"the unpruned jnp scored tick")
    assert us_pruned <= 3.0 * us_dist, (
        f"pruned scored tick not within 3x of distance-only: "
        f"{us_pruned / 1e3:.2f} ms vs {us_dist / 1e3:.2f} ms")
    return [
        ("stream_tick_scored_unpruned_K256", us_full,
         f"diverse_bank;jobs={TPUT_JOBS}"),
        ("stream_tick_scored_pruned_K256", us_pruned,
         f"pruned_speedup={speedup:.2f}x;vs_distance={vs_dist:.2f}x"
         f";survivors={survivors};repacks={svc_p.repack_count}"
         f";top={PRUNED_TOP}"),
    ]


#: churn-scenario knobs: slot capacities swept, wall-clock ticks per
#: scenario (the clock advances at the fastest cohort's 100 Hz), samples
#: pushed per job per tick, and the mixed tick-rate cohorts jobs are
#: assigned to round-robin.
CHURN_SIZES = (8, 64, 256, 1024)
CHURN_TICKS = 40
CHURN_CHUNK = 2
CHURN_RATES = (100.0, 20.0, 4.0)


def _churn_run(bank, bases, s, elastic, seed=11):
    """One churn scenario: Poisson arrivals (clamped to capacity), every
    live job pushing CHURN_CHUNK samples per 10 ms beat, cohort-metered
    ticks, and completions retired through the finish_later drain queue
    once their ingest queue is empty (so a deferred finish never forces
    an off-beat drain).  The event schedule is a pure function of
    ``seed`` — identical for the elastic and fixed-slot runs."""
    rng = np.random.default_rng(seed)
    svc = TuningService(bank, band=BAND, denoise=True, slots=s,
                        elastic_slots=elastic, finish_batch=16)
    live, early, finals = {}, {}, {}
    n_sub = 0
    lam = max(1.0, s / 12)
    for t in range(CHURN_TICKS):
        for _ in range(int(rng.poisson(lam))):
            if svc.n_active >= s:
                break
            base = bases[n_sub % len(bases)]
            ln = int(rng.integers(48, 97))
            off = int(rng.integers(0, max(1, len(base) - ln)))
            q = base[off: off + ln]
            jid = f"c{n_sub}"
            svc.submit(jid, expected_len=len(q),
                       tick_hz=CHURN_RATES[n_sub % len(CHURN_RATES)])
            live[jid] = [q, 0]
            n_sub += 1
        for jid, st in live.items():
            q, pos = st
            if pos < len(q):
                svc.push(jid, q[pos: pos + CHURN_CHUNK])
                st[1] = min(pos + CHURN_CHUNK, len(q))
        for jid, d in svc.tick(now=t / 100.0).items():
            if d is not None:
                early.setdefault(jid, d)
        for jid in [j for j, (q, pos) in live.items()
                    if pos >= len(q) and not svc._front.has_data(j)]:
            svc.finish_later(jid)             # batched: drains at 16
            del live[jid]
    finals.update(svc.drain_finishes())
    rest = sorted(live)
    for i in range(0, len(rest), 32):
        finals.update(svc.finish_many(rest[i: i + 32]))
    finals.update(svc.drain_finishes())
    assert len(finals) == n_sub, (len(finals), n_sub)
    return svc, early, finals


def _decision_keys(early, finals):
    return ({j: (d.matched, d.corr, d.decided_at_fraction)
             for j, d in early.items()},
            {j: (d.matched, d.corr, d.decided_at_fraction)
             for j, d in finals.items()})


def _churn_rows():
    bank = _paper_bank(tuple(mrsim.APPS))
    psets = mrsim.paper_param_sets()
    bases = [mrsim.simulate_cpu_series(app, psets[i], run=1, dt=DT)
             for i, app in enumerate(mrsim.APPS)]
    rows = []
    for s in CHURN_SIZES:
        _churn_run(bank, bases, s, elastic=True)   # warm the jit cache
        t0 = time.time()
        svc, early, finals = _churn_run(bank, bases, s, elastic=True)
        us = (time.time() - t0) / CHURN_TICKS * 1e6

        # one dispatch per data tick, however many jobs/cohorts are live
        assert svc.dispatch_count <= svc.ticks, \
            (svc.dispatch_count, svc.ticks)
        if s > MIN_SLOT_BUCKET_SENTINEL:
            assert svc.slot_repack_count > 0, \
                "elastic churn never crossed an S bucket"

        # the churn invariant, end to end: elastic decisions are
        # bit-identical to the fixed-slot reference of the same schedule
        _, ef, ff = _churn_run(bank, bases, s, elastic=False)
        assert _decision_keys(early, finals) == _decision_keys(ef, ff), \
            f"elastic vs fixed-slot decisions diverged at S={s}"

        print(f"[streaming] S={s:4d}: {us / 1e3:7.2f} ms/tick churn "
              f"({len(finals)} jobs, {svc.dispatch_count} dispatches / "
              f"{svc.ticks} ticks, cap={svc.slot_capacity}, "
              f"slot_repacks={svc.slot_repack_count}, "
              f"verdict_dispatches={svc.offline_dispatch_count})")
        rows.append((f"stream_tick_S{s}", us,
                     f"jobs={len(finals)};dispatches={svc.dispatch_count}"
                     f";ticks={svc.ticks};cap={svc.slot_capacity}"
                     f";slot_repacks={svc.slot_repack_count}"
                     f";verdicts={svc.offline_dispatch_count}"
                     f";cohorts={len(CHURN_RATES)}"))
    return rows


#: smallest elastic bucket (mirrors serve.scheduler.MIN_SLOT_BUCKET): at
#: or below it there is no capacity to grow through, so no repacks.
MIN_SLOT_BUCKET_SENTINEL = 8


#: crash-recovery scenario knobs (recovery_restore_S256): churn-scale
#: service shapes — S jobs x K references, short refs (M ~= 128) so the
#: [S, M, K] slabs stay bench-host sized — snapshotted mid-run, then
#: restored + WAL-tail-replayed.  Gate: restore+replay costs at most
#: RECOVERY_REPLAY_GATE x a single scored tick per replayed chunk
#: record (replay re-executes the journal; each push record is one
#: chunk, and a live tick processes S chunks in one dispatch, so the
#: gate only fails when replay is catastrophically slower than simply
#: re-serving the tail).
RECOVERY_S = 256
RECOVERY_K = 256
RECOVERY_CHUNK = 16
RECOVERY_TICKS = 4
RECOVERY_REPLAY_GATE = 5.0


def _recovery_bank(rng, k):
    series = []
    for i in range(k):
        l = int(rng.integers(100, 129))
        t = np.linspace(0, 1, l, dtype=np.float32)
        s = (0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + 0.05 * i) * t)
             + 0.05 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return pack_series(series)


def _recovery_rows():
    """recovery_restore_S256: durable snapshot + journal-tail replay at
    S=256 jobs x K=256 references (scored ticks).  Reports snapshot time
    and restore+replay time; pins the recovered device state bitwise
    against the live service before gating replay cost."""
    import shutil
    import tempfile

    from repro.serve.recovery import RecoverableTuningService

    rng = np.random.default_rng(23)
    bank = _recovery_bank(rng, RECOVERY_K)
    qlen = RECOVERY_TICKS * RECOVERY_CHUNK
    qs = rng.random((RECOVERY_S, qlen), dtype=np.float32)
    root = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        svc = RecoverableTuningService(
            bank, root=os.path.join(root, "svc"),
            score_in_flight=True, slots=RECOVERY_S)
        for j in range(RECOVERY_S):
            svc.submit(f"job{j}", expected_len=qlen)
        tick_s = []
        ckpt_s = 0.0
        for t in range(RECOVERY_TICKS):
            for j in range(RECOVERY_S):
                svc.push(f"job{j}", qs[j, t * RECOVERY_CHUNK:
                                       (t + 1) * RECOVERY_CHUNK])
            t0 = time.time()
            svc.tick()
            tick_s.append(time.time() - t0)
            if t == RECOVERY_TICKS // 2 - 1:
                t0 = time.time()
                svc.checkpoint()
                ckpt_s = time.time() - t0

        replay_ticks = RECOVERY_TICKS - RECOVERY_TICKS // 2
        tail_records = replay_ticks * (RECOVERY_S + 1)

        kw = dict(score_in_flight=True, slots=RECOVERY_S)
        rec = RecoverableTuningService.recover(
            bank, root=os.path.join(root, "svc"), **kw)   # warm caches
        t0 = time.time()
        rec = RecoverableTuningService.recover(
            bank, root=os.path.join(root, "svc"), **kw)
        restore_s = time.time() - t0

        assert rec.replayed == tail_records, (rec.replayed, tail_records)
        for j in range(RECOVERY_S):
            a = svc.svc._jobs[f"job{j}"].last_sims
            b = rec.svc._jobs[f"job{j}"].last_sims
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"recovered job{j} diverged from the live service"

        tick_ref = min(tick_s)                # post-compile tick cost
        replay_chunks = replay_ticks * RECOVERY_S
        per_chunk_ratio = restore_s / (tick_ref * replay_chunks)
        print(f"[streaming] S={RECOVERY_S} K={RECOVERY_K}: snapshot "
              f"{ckpt_s * 1e3:.1f} ms, restore+replay {restore_s * 1e3:.1f}"
              f" ms ({tail_records} records, {replay_chunks} chunks) -> "
              f"{per_chunk_ratio:.3f}x scored tick per replayed chunk")
        assert restore_s <= RECOVERY_REPLAY_GATE * tick_ref * \
            replay_chunks, (
                f"restore+replay {restore_s:.2f}s exceeds "
                f"{RECOVERY_REPLAY_GATE}x scored tick "
                f"({tick_ref * 1e3:.1f} ms) per replayed chunk "
                f"({replay_chunks} chunks)")
        return [("recovery_restore_S256", restore_s * 1e6,
                 f"snapshot_ms={ckpt_s * 1e3:.1f}"
                 f";replayed_records={tail_records}"
                 f";replayed_chunks={replay_chunks}"
                 f";tick_ms={tick_ref * 1e3:.1f}"
                 f";per_chunk_ratio={per_chunk_ratio:.3f}x")]
    finally:
        shutil.rmtree(root, ignore_errors=True)


#: overload scenario knobs (stream_tick_overload_S256): churn-style
#: serving at S=256 slots under a seeded 10x Poisson submission spike
#: (FaultPlan spike windows), slow-dispatch injection pushing measured
#: tick latency past the ladder's target, and queue-pressure bursts
#: withholding drains.  The row records shed counts (total and per QoS
#: class), the worst ladder rung reached, and the p99 tick latency the
#: controller saw.  Gates: the ladder engaged (worst rung >= 1), load
#: was actually shed, and the burst's submissions never blew the queue
#: or slot limits (admission is the backpressure, not an exception).
OVERLOAD_S = 256
OVERLOAD_TICKS = 40
OVERLOAD_CHUNK = 2
OVERLOAD_LAM = 6.0
OVERLOAD_SEED = 29
OVERLOAD_QOS = ("bronze", "silver", "gold")


def _overload_rows():
    from repro.runtime.chaos import FaultPlan
    from repro.serve.overload import (AdmissionPolicy, AdmissionShedError,
                                      OverloadConfig)

    bank = _paper_bank(tuple(mrsim.APPS))
    psets = mrsim.paper_param_sets()
    bases = [mrsim.simulate_cpu_series(app, psets[i], run=1, dt=DT)
             for i, app in enumerate(mrsim.APPS)]
    plan = FaultPlan(seed=OVERLOAD_SEED, spike_rate=0.25,
                     spike_factor=10.0, spike_len=4,
                     slow_rate=0.6, slow_extra=0.05,
                     queue_burst_rate=0.1, queue_burst_len=2)
    svc = TuningService(
        bank, band=BAND, denoise=True, slots=OVERLOAD_S,
        queue_limit=1024,
        overload=OverloadConfig(target_p99=0.02, window=8, patience=1,
                                cooldown=2),
        admission=AdmissionPolicy(), chaos=plan)
    rng = np.random.default_rng(OVERLOAD_SEED)
    live = {}
    lats = []
    n_sub = n_offered = n_withheld = 0
    t0 = time.time()
    for t in range(OVERLOAD_TICKS):
        mult = plan.spike_multiplier()
        for _ in range(int(rng.poisson(OVERLOAD_LAM * mult))):
            n_offered += 1
            base = bases[n_offered % len(bases)]
            ln = int(rng.integers(48, 97))
            off = int(rng.integers(0, max(1, len(base) - ln)))
            jid = f"o{n_offered}"
            try:
                svc.submit(jid, expected_len=ln,
                           qos=OVERLOAD_QOS[n_offered % len(OVERLOAD_QOS)])
            except (AdmissionShedError, RuntimeError):
                continue              # shed / slots busy: backpressure
            live[jid] = [base[off: off + ln], 0]
            n_sub += 1
        for jid, st in live.items():
            q, pos = st
            if pos < len(q):
                svc.push(jid, q[pos: pos + OVERLOAD_CHUNK])
                st[1] = min(pos + OVERLOAD_CHUNK, len(q))
        if plan.queue_burst():
            n_withheld += 1           # drain withheld: queues build
            continue
        svc.tick(now=t / 100.0)
        lats.append(svc.last_tick_latency)
    lat_p99 = float(np.percentile(lats, 99))
    us = (time.time() - t0) / max(svc.ticks, 1) * 1e6
    done = sorted(live)
    for i in range(0, len(done), 64):
        svc.finish_many(done[i: i + 64])

    assert svc.worst_rung >= 1, "spike never engaged the ladder"
    assert svc.shed_count > 0, "10x spike shed nothing"
    print(f"[streaming] S={OVERLOAD_S}: {us / 1e3:7.2f} ms/tick overload "
          f"(offered={n_offered}, admitted={n_sub}, "
          f"shed={svc.shed_count} {svc.shed_by_class}, "
          f"worst_rung={svc.worst_rung}, "
          f"rung_moves={len(svc.rung_history)}, "
          f"p99_seen={lat_p99 * 1e3:.1f} ms, withheld={n_withheld})")
    shed_cls = ",".join(f"{k}:{v}"
                        for k, v in sorted(svc.shed_by_class.items()))
    return [("stream_tick_overload_S256", us,
             f"offered={n_offered};admitted={n_sub}"
             f";shed={svc.shed_count};shed_by_class={shed_cls}"
             f";worst_rung={svc.worst_rung}"
             f";rung_moves={len(svc.rung_history)}"
             f";p99_tick_ms={lat_p99 * 1e3:.1f}"
             f";overload_ticks={svc.overload_ticks}")]


def run():
    return (_early_decision_rows() + _multiplex_rows()
            + _equivalence_rows() + _throughput_rows()
            + _pruned_scored_rows() + _churn_rows()
            + _recovery_rows() + _overload_rows())


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
