"""Wavelet-compressed matching (the paper's §5 future plan, implemented):
speed vs fidelity against full DTW matching.
"""

from __future__ import annotations

import time

import numpy as np

from repro import mrsim
from repro.core import similarity, wavelet


def run():
    psets = mrsim.paper_param_sets()
    pairs = []
    for p in psets:
        e = mrsim.simulate_cpu_series("exim", p, run=1)
        for app in ("wordcount", "terasort"):
            r = mrsim.simulate_cpu_series(app, p)
            pairs.append((e, r, app))

    # DTW ground truth ordering
    t0 = time.time()
    dtw_scores = [similarity(e, r, preprocess=True, band=8)
                  for e, r, _ in pairs]
    t_dtw = (time.time() - t0) / len(pairs) * 1e6

    rows = []
    for m in (16, 32, 64, 128):
        t0 = time.time()
        w_scores = [wavelet.wavelet_similarity(e, r, m=m) for e, r, _ in pairs]
        t_w = (time.time() - t0) / len(pairs) * 1e6
        # rank agreement: does wavelet matching order wc above ts per pset?
        agree = 0
        for j in range(len(psets)):
            wc, ts = w_scores[2 * j], w_scores[2 * j + 1]
            dwc, dts = dtw_scores[2 * j], dtw_scores[2 * j + 1]
            agree += int((wc > ts) == (dwc > dts))
        corr = np.corrcoef(dtw_scores, w_scores)[0, 1]
        rows.append((f"wavelet_match_m{m}", t_w,
                     f"speedup_vs_dtw={t_dtw/t_w:.1f}x"
                     f";rank_agree={agree}/{len(psets)};corr={corr:.2f}"))
        print(f"[wavelet] m={m}: {t_w:.0f}us/pair "
              f"({t_dtw/t_w:.1f}x faster than DTW) rank agree {agree}/4 "
              f"score-corr {corr:.2f}")
    rows.append(("dtw_reference_matchcall", t_dtw, "baseline"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
