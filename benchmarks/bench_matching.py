"""Matching-phase accuracy (paper Fig. 4-b): leave-one-run-out over the
three applications x parameter sets — does the matcher recover the true
application family from an unseen run's CPU series?
"""

from __future__ import annotations

import time

import numpy as np

from repro import mrsim
from repro.core import match_application

BAND = 8


def run():
    psets = mrsim.paper_param_sets()
    apps = list(mrsim.APPS)
    refs = {app: [mrsim.simulate_cpu_series(app, p, run=0) for p in psets]
            for app in apps}

    t0 = time.time()
    correct = total = 0
    for app in apps:
        for run_id in (1, 2, 3):
            qs = [mrsim.simulate_cpu_series(app, p, run=run_id)
                  for p in psets]
            res = match_application(qs, refs, band=BAND)
            total += 1
            if res.best == app:
                correct += 1
    dt = time.time() - t0
    acc = correct / total
    print(f"[matching] leave-one-run-out accuracy {correct}/{total} "
          f"({100*acc:.0f}%)")
    assert acc >= 0.8, "matching accuracy degraded"
    return [("matching_accuracy", dt / total * 1e6, f"acc={acc:.3f}")]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
