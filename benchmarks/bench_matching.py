"""Matching-phase benchmarks (paper Fig. 4-b + the §5 scaling concern).

1. Accuracy: leave-one-run-out over the three applications x parameter
   sets — does the matcher recover the true application family from an
   unseen run's CPU series?  (Runs on the batched pairs path.)
2. Throughput: one query against a K-entry reference bank, scalar
   per-pair jit loop (the seed's dispatch pattern — one device round-trip
   per reference) vs the single-dispatch ``dtw_distance_bank``, at
   K in {8, 64, 256}; verifies the two agree to 1e-4.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import mrsim
from repro.core import dtw, match_application
from repro.core.database import pack_series

BAND = 8
BANK_SIZES = (8, 64, 256)
MIN_SPEEDUP_AT_256 = 5.0


def _accuracy_rows():
    psets = mrsim.paper_param_sets()
    apps = list(mrsim.APPS)
    refs = {app: [mrsim.simulate_cpu_series(app, p, run=0) for p in psets]
            for app in apps}

    t0 = time.time()
    correct = total = 0
    for app in apps:
        for run_id in (1, 2, 3):
            qs = [mrsim.simulate_cpu_series(app, p, run=run_id)
                  for p in psets]
            res = match_application(qs, refs, band=BAND)
            total += 1
            if res.best == app:
                correct += 1
    dt = time.time() - t0
    acc = correct / total
    print(f"[matching] leave-one-run-out accuracy {correct}/{total} "
          f"({100*acc:.0f}%)")
    assert acc >= 0.8, "matching accuracy degraded"
    return [("matching_accuracy", dt / total * 1e6, f"acc={acc:.3f}")]


def _make_bank(rng, k):
    """K ragged pseudo-utilization series drawn from a few length buckets
    (parameter sets quantize real capture lengths the same way)."""
    buckets = (180, 220, 256, 300, 330, 360)
    series = []
    for i in range(k):
        l = buckets[int(rng.integers(len(buckets)))]
        t = np.linspace(0, 1, l, dtype=np.float32)
        s = (0.5 + 0.3 * np.sin(2 * np.pi * (2 + i % 5) * t)
             + 0.1 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return series, pack_series(series)


def _throughput_rows():
    rows = []
    rng = np.random.default_rng(0)
    x = np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 12, 256)), 0, 1) \
        .astype(np.float32)

    for k in BANK_SIZES:
        series, bank = _make_bank(rng, k)

        # scalar loop: one jitted dispatch per reference (seed behavior)
        def scalar():
            return np.array([float(dtw.dtw_distance(x, s)) for s in series])

        def batched():
            return np.asarray(jax.block_until_ready(
                dtw.dtw_distance_bank(x, bank.series, bank.lengths)))

        d_scalar = scalar()          # warm the per-length jit caches
        d_batched = batched()
        np.testing.assert_allclose(d_batched, d_scalar, rtol=1e-4, atol=1e-4)

        reps = 3
        t0 = time.time()
        for _ in range(reps):
            scalar()
        us_scalar = (time.time() - t0) / reps * 1e6
        t0 = time.time()
        for _ in range(reps):
            batched()
        us_batched = (time.time() - t0) / reps * 1e6

        speedup = us_scalar / max(us_batched, 1e-9)
        print(f"[matching] K={k:4d}: scalar {us_scalar/1e3:8.1f} ms  "
              f"batched {us_batched/1e3:8.1f} ms  speedup {speedup:5.1f}x")
        rows.append((f"match_scalar_K{k}", us_scalar, "per-pair jit loop"))
        rows.append((f"match_batched_K{k}", us_batched,
                     f"speedup={speedup:.1f}x"))
        # wall-clock gate; disable on loaded/shared machines with
        # BENCH_MATCHING_STRICT=0 (the distance-agreement check above is
        # unconditional either way)
        if k == max(BANK_SIZES) and \
                os.environ.get("BENCH_MATCHING_STRICT", "1") != "0":
            assert speedup >= MIN_SPEEDUP_AT_256, (
                f"batched bank matching only {speedup:.1f}x over the scalar "
                f"loop at K={k} (need >= {MIN_SPEEDUP_AT_256}x; "
                f"BENCH_MATCHING_STRICT=0 to demote)")
    return rows


def run():
    return _accuracy_rows() + _throughput_rows()


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
