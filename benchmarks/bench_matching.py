"""Matching-phase benchmarks (paper Fig. 4-b + the §5 scaling concern).

1. Accuracy: leave-one-run-out over the three applications x parameter
   sets — does the matcher recover the true application family from an
   unseen run's CPU series?  (Runs on the batched pairs path.)
2. Distance throughput: one query against a K-entry reference bank,
   scalar per-pair jit loop (the seed's dispatch pattern — one device
   round-trip per reference) vs the single-dispatch
   ``dtw_distance_bank``, at K in {8, 64, 256}; verifies the two agree
   to 1e-4.
3. SCORED (verdict) throughput: the full whole-DB warp-correlation
   match.  ``match_matrix_K*`` is the retired engine (batched [K, N, M]
   matrix materialization + host backtracking per reference) kept as the
   comparison baseline; ``match_scored_K*`` is the matrix-free
   closed-end moment scorer that now backs ``similarity_bank`` and every
   ``TuningService`` verdict.  Gate: >= MIN_SCORED_SPEEDUP_AT_256 at
   K=256.
4. Probabilistic scoring (``match_prob_K256``): the PR-7
   variance-carrying scorer (scores + calibrated match probabilities)
   vs the exact moment scorer, with the zero-variance bitwise reduction
   checked unconditionally.  ``match_prob_approx_K256`` runs the
   4-channel approximate tail (``prob_mode="approx"``) on the same
   inputs and records calibration drift — max |p_approx - p_exact| and
   gating-decision agreement at the 0.5 gate — as derived fields, so
   drift shows up in the perf trajectory, not just in tests.
5. Batched finish: J completed jobs rendered by ONE
   ``TuningService.finish_many`` drain vs J sequential ``finish()``
   calls (``finish_batched_J{8,32}``).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import mrsim
from repro.core import dtw, match_application, similarity_bank
from repro.core.database import pack_series

BAND = 8
BANK_SIZES = (8, 64, 256)
MIN_SPEEDUP_AT_256 = 5.0
#: matrix-free scored path vs the matrix+backtrack baseline at K=256.
MIN_SCORED_SPEEDUP_AT_256 = 3.0
FINISH_BATCH_SIZES = (8, 32)


def _accuracy_rows():
    psets = mrsim.paper_param_sets()
    apps = list(mrsim.APPS)
    refs = {app: [mrsim.simulate_cpu_series(app, p, run=0) for p in psets]
            for app in apps}

    t0 = time.time()
    correct = total = 0
    for app in apps:
        for run_id in (1, 2, 3):
            qs = [mrsim.simulate_cpu_series(app, p, run=run_id)
                  for p in psets]
            res = match_application(qs, refs, band=BAND)
            total += 1
            if res.best == app:
                correct += 1
    dt = time.time() - t0
    acc = correct / total
    print(f"[matching] leave-one-run-out accuracy {correct}/{total} "
          f"({100*acc:.0f}%)")
    assert acc >= 0.8, "matching accuracy degraded"
    return [("matching_accuracy", dt / total * 1e6, f"acc={acc:.3f}")]


def _make_bank(rng, k):
    """K ragged pseudo-utilization series drawn from a few length buckets
    (parameter sets quantize real capture lengths the same way)."""
    buckets = (180, 220, 256, 300, 330, 360)
    series = []
    for i in range(k):
        l = buckets[int(rng.integers(len(buckets)))]
        t = np.linspace(0, 1, l, dtype=np.float32)
        s = (0.5 + 0.3 * np.sin(2 * np.pi * (2 + i % 5) * t)
             + 0.1 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return series, pack_series(series)


def _throughput_rows():
    rows = []
    rng = np.random.default_rng(0)
    x = np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 12, 256)), 0, 1) \
        .astype(np.float32)

    for k in BANK_SIZES:
        series, bank = _make_bank(rng, k)

        # scalar loop: one jitted dispatch per reference (seed behavior)
        def scalar():
            return np.array([float(dtw.dtw_distance(x, s)) for s in series])

        def batched():
            return np.asarray(jax.block_until_ready(
                dtw.dtw_distance_bank(x, bank.series, bank.lengths)))

        d_scalar = scalar()          # warm the per-length jit caches
        d_batched = batched()
        np.testing.assert_allclose(d_batched, d_scalar, rtol=1e-4, atol=1e-4)

        reps = 3
        t0 = time.time()
        for _ in range(reps):
            scalar()
        us_scalar = (time.time() - t0) / reps * 1e6
        t0 = time.time()
        for _ in range(reps):
            batched()
        us_batched = (time.time() - t0) / reps * 1e6

        speedup = us_scalar / max(us_batched, 1e-9)
        print(f"[matching] K={k:4d}: scalar {us_scalar/1e3:8.1f} ms  "
              f"batched {us_batched/1e3:8.1f} ms  speedup {speedup:5.1f}x")
        rows.append((f"match_scalar_K{k}", us_scalar, "per-pair jit loop"))
        rows.append((f"match_batched_K{k}", us_batched,
                     f"speedup={speedup:.1f}x"))
        # wall-clock gate; disable on loaded/shared machines with
        # BENCH_MATCHING_STRICT=0 (the distance-agreement check above is
        # unconditional either way)
        if k == max(BANK_SIZES) and \
                os.environ.get("BENCH_MATCHING_STRICT", "1") != "0":
            assert speedup >= MIN_SPEEDUP_AT_256, (
                f"batched bank matching only {speedup:.1f}x over the scalar "
                f"loop at K={k} (need >= {MIN_SPEEDUP_AT_256}x; "
                f"BENCH_MATCHING_STRICT=0 to demote)")
    return rows


def _scored_rows():
    """Matrix-free scored matching vs the matrix+backtrack baseline."""
    rows = []
    rng = np.random.default_rng(0)
    x = np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 12, 256)), 0, 1) \
        .astype(np.float32)

    for k in BANK_SIZES:
        _, bank = _make_bank(rng, k)

        def matrix():
            return similarity_bank(x, bank, matrix_path=True)

        def scored():
            return similarity_bank(x, bank)

        s_matrix = matrix()               # warm jit caches (+ score plan)
        s_scored = scored()
        # warp-path-tie tolerance: float rounding differences between the
        # wavefront and the min-plus matrix formulations can flip
        # near-tie backtrack choices (exactness on tie-free data is
        # pinned in tests/test_scored_matching.py)
        np.testing.assert_allclose(s_scored, s_matrix, atol=5e-3)

        reps = 3
        t0 = time.time()
        for _ in range(reps):
            matrix()
        us_matrix = (time.time() - t0) / reps * 1e6
        t0 = time.time()
        for _ in range(reps):
            scored()
        us_scored = (time.time() - t0) / reps * 1e6

        speedup = us_matrix / max(us_scored, 1e-9)
        print(f"[matching] K={k:4d}: matrix {us_matrix/1e3:8.1f} ms  "
              f"scored {us_scored/1e3:8.1f} ms  speedup {speedup:5.1f}x")
        rows.append((f"match_matrix_K{k}", us_matrix,
                     "[K,N,M] matrices + host backtrack"))
        rows.append((f"match_scored_K{k}", us_scored,
                     f"speedup={speedup:.1f}x"))
        if k == max(BANK_SIZES) and \
                os.environ.get("BENCH_MATCHING_STRICT", "1") != "0":
            assert speedup >= MIN_SCORED_SPEEDUP_AT_256, (
                f"matrix-free scored matching only {speedup:.1f}x over "
                f"the matrix+backtrack path at K={k} (need >= "
                f"{MIN_SCORED_SPEEDUP_AT_256}x; BENCH_MATCHING_STRICT=0 "
                f"to demote)")
    return rows


def _prob_rows():
    """match_prob_K256: the variance-carrying probabilistic scorer
    (seven-channel moment slab + factored-tail match probabilities) vs
    the exact moment scorer on the same queries/bank, one dispatch each.

    Correctness is checked unconditionally (zero variance reduces the
    probabilistic scores bitwise to the exact ones with probs in {0,1},
    both tails); the emitted ratios vs the exact path are informational
    here — the wall-clock gate lives in bench_streaming's
    stream_tick_prob_K256, where the serving tick is the thing the
    paper cares about.  match_prob_approx_K256 additionally carries the
    calibration drift of the 4-channel tail (max_abs_dp and the 0.5-gate
    agreement vs the exact tail) as derived fields."""
    rows = []
    rng = np.random.default_rng(3)
    k = max(BANK_SIZES)
    _, bank = _make_bank(rng, k)
    j = 8
    xs = np.clip(0.5 + 0.3 * np.sin(
        np.linspace(0, 12, 256)[None] * (1 + 0.1 * np.arange(j)[None].T)),
        0, 1).astype(np.float32)
    xv = np.full_like(xs, 1e-3)

    def exact():
        return np.asarray(jax.block_until_ready(dtw.dtw_score_bank_many(
            xs, bank.series, bank.lengths, threshold=0.85)))

    def prob():
        s, p = dtw.dtw_score_bank_many(
            xs, bank.series, bank.lengths, xvars=xv, threshold=0.85)
        return np.asarray(jax.block_until_ready(s)), np.asarray(p)

    def prob_approx():
        s, p = dtw.dtw_score_bank_many(
            xs, bank.series, bank.lengths, xvars=xv, threshold=0.85,
            prob_mode="approx")
        return np.asarray(jax.block_until_ready(s)), np.asarray(p)

    s_exact = exact()                     # warm jit caches
    _, p_exact = prob()
    s_approx, p_approx = prob_approx()
    # zero-variance reduction: exact scores bitwise, degenerate probs —
    # both tails
    s0, p0 = dtw.dtw_score_bank_many(
        xs, bank.series, bank.lengths, xvars=np.zeros_like(xs),
        threshold=0.85)
    np.testing.assert_array_equal(np.asarray(s0), s_exact)
    assert set(np.unique(np.asarray(p0))) <= {0.0, 1.0}
    s0a, p0a = dtw.dtw_score_bank_many(
        xs, bank.series, bank.lengths, xvars=np.zeros_like(xs),
        threshold=0.85, prob_mode="approx")
    np.testing.assert_array_equal(np.asarray(s0a), s_exact)
    np.testing.assert_array_equal(np.asarray(p0a), np.asarray(p0))
    # calibration drift, derived fields: the scores themselves are
    # mode-independent (same 3 base channels), so pin them bitwise and
    # measure only the probability tail
    np.testing.assert_array_equal(s_approx, s_exact)
    max_dp = float(np.abs(p_approx - p_exact).max())
    gate_agree = float(np.mean((p_approx >= 0.5) == (p_exact >= 0.5)))

    reps = 3
    t0 = time.time()
    for _ in range(reps):
        exact()
    us_exact = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        prob()
    us_prob = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        prob_approx()
    us_approx = (time.time() - t0) / reps * 1e6
    ratio = us_prob / max(us_exact, 1e-9)
    ratio_a = us_approx / max(us_exact, 1e-9)
    print(f"[matching] K={k:4d}: exact {us_exact/1e3:8.1f} ms  "
          f"prob {us_prob/1e3:8.1f} ms  ratio {ratio:4.2f}x (J={j})")
    print(f"[matching] K={k:4d}: approx prob {us_approx/1e3:8.1f} ms  "
          f"ratio {ratio_a:4.2f}x  max|dp|={max_dp:.4f}  "
          f"gate_agree={gate_agree:.3f}")
    rows.append((f"match_prob_K{k}", us_prob,
                 f"vs_exact={ratio:.2f}x;jobs={j}"))
    rows.append((f"match_prob_approx_K{k}", us_approx,
                 f"vs_exact={ratio_a:.2f}x;max_abs_dp={max_dp:.4f}"
                 f";gate_agree_at_0.5={gate_agree:.3f};jobs={j}"))
    return rows


#: samples still in flight when a job's completion lands: a finishing
#: job arrives WITH its last chunk, so every verdict is preceded by a
#: buffer drain (the production completion shape finish_many amortizes).
FINISH_TAIL = 4


def _finish_batched_rows():
    """J completing jobs -> one finish_many drain vs J sequential
    finish() calls (same service config, same jobs, same decisions).

    Paper-faithful operating point: the reference bank is the 3-app
    mrsim corpus at the simulator's native 1 Hz (dt=1.0) and the service
    runs banded in-flight scoring, like the churn benches.  Each
    completion delivers its final FINISH_TAIL samples together with the
    finish request, so a sequential consumer pays a buffer-drain tick
    plus a one-job verdict dispatch per completion, while ``finish_many``
    drains every buffer in ONE tick and renders all J verdicts in ONE
    batched dispatch — the continuous-batching completion path."""
    from repro.serve.tuning import TuningService

    rows = []
    psets = mrsim.paper_param_sets()
    apps = ("wordcount", "terasort", "exim")
    series, labels = [], []
    for app in apps:
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=1.0))
            labels.append(app)
    bank = pack_series(series, labels=labels)

    for j in FINISH_BATCH_SIZES:
        qs = [mrsim.simulate_cpu_series(apps[i % 3], psets[i % len(psets)],
                                        run=1 + i // 3, dt=1.0)
              for i in range(j)]

        def populate():
            svc = TuningService(bank, band=6, denoise=True, slots=j)
            for i, q in enumerate(qs):
                svc.submit(f"job{i}", expected_len=len(q))
                svc.push(f"job{i}", q[:-FINISH_TAIL])
            svc.tick()
            return svc

        def sequential():
            svc = populate()
            out = []
            for i, q in enumerate(qs):
                svc.push(f"job{i}", q[-FINISH_TAIL:])
                out.append(svc.finish(f"job{i}"))
            return out

        def batched():
            svc = populate()
            for i, q in enumerate(qs):
                svc.push(f"job{i}", q[-FINISH_TAIL:])
            return svc.finish_many([f"job{i}" for i in range(j)])

        d_seq = sequential()              # warm jit caches
        d_bat = batched()
        assert [d.matched for d in d_seq] == \
            [d_bat[f"job{i}"].matched for i in range(j)]
        assert [d.corr for d in d_seq] == \
            [d_bat[f"job{i}"].corr for i in range(j)]

        reps = 3
        t_seq, t_bat = [], []
        for _ in range(reps):             # time the completion path
            svc = populate()              # only, not the setup ticks
            t0 = time.time()
            for i, q in enumerate(qs):
                svc.push(f"job{i}", q[-FINISH_TAIL:])
                svc.finish(f"job{i}")
            t_seq.append((time.time() - t0) * 1e6)
        for _ in range(reps):
            svc = populate()
            t0 = time.time()
            for i, q in enumerate(qs):
                svc.push(f"job{i}", q[-FINISH_TAIL:])
            svc.finish_many([f"job{i}" for i in range(j)])
            t_bat.append((time.time() - t0) * 1e6)
        us_seq = sorted(t_seq)[reps // 2]
        us_bat = sorted(t_bat)[reps // 2]
        speedup = us_seq / max(us_bat, 1e-9)
        print(f"[matching] finish J={j:3d}: sequential "
              f"{us_seq/1e3:8.1f} ms  batched {us_bat/1e3:8.1f} ms  "
              f"({us_bat/j/1e3:6.2f} ms/verdict, {speedup:4.1f}x, "
              f"1 vs {j} drain ticks + offline dispatches)")
        rows.append((f"finish_batched_J{j}", us_bat,
                     f"vs sequential {speedup:.1f}x; "
                     f"{us_bat/j/1e3:.2f} ms/verdict"))
    return rows


def run():
    return (_accuracy_rows() + _throughput_rows() + _scored_rows()
            + _prob_rows() + _finish_batched_rows())


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
