"""Benchmark harness: one module per paper table/figure + framework
deployment benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_paper_table1, bench_matching, bench_dtw, bench_wavelet,
               bench_autotune, bench_roofline)

BENCHES = {
    "paper_table1": bench_paper_table1.run,   # paper Table 1
    "matching": bench_matching.run,           # paper Fig. 4-b / §5
    "dtw": bench_dtw.run,                     # paper §3.1.2 scaling
    "wavelet": bench_wavelet.run,             # paper §5 future plan
    "autotune": bench_autotune.run,           # paper §4 end goal, on JAX
    "roofline": bench_roofline.run,           # dry-run aggregation
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    rows = []
    failed = []
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== bench: {name} =====")
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, repr(e)))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
