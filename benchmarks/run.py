"""Benchmark harness: one module per paper table/figure + framework
deployment benches.  Prints ``name,us_per_call,derived`` CSV; ``--json``
additionally writes the rows as a JSON document (what CI uploads as the
perf-trajectory artifact).

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from . import (bench_paper_table1, bench_matching, bench_streaming,
               bench_dtw, bench_wavelet, bench_autotune, bench_roofline)

BENCHES = {
    "paper_table1": bench_paper_table1.run,   # paper Table 1
    "matching": bench_matching.run,           # paper Fig. 4-b / §5
    "streaming": bench_streaming.run,         # online matching service
    "dtw": bench_dtw.run,                     # paper §3.1.2 scaling
    "wavelet": bench_wavelet.run,             # paper §5 future plan
    "autotune": bench_autotune.run,           # paper §4 end goal, on JAX
    "roofline": bench_roofline.run,           # dry-run aggregation
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="also write rows (+ failures) to this JSON file")
    args = ap.parse_args()

    rows = []
    failed = []
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== bench: {name} =====")
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, repr(e)))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "failed": [{"bench": n, "error": e}
                                  for n, e in failed]}, f, indent=1)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
