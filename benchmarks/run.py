"""Benchmark harness: one module per paper table/figure + framework
deployment benches.  Prints ``name,us_per_call,derived`` CSV; ``--json``
additionally writes the rows as a JSON document (what CI uploads as the
perf-trajectory artifact) and refreshes the checked-in per-bench
baselines (``BENCH_<name>.json`` at the repo root, for the benches listed
in :data:`BASELINE_BENCHES`) so the perf trajectory is visible in-repo.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from . import (bench_paper_table1, bench_matching, bench_streaming,
               bench_dtw, bench_wavelet, bench_autotune, bench_roofline)

BENCHES = {
    "paper_table1": bench_paper_table1.run,   # paper Table 1
    "matching": bench_matching.run,           # paper Fig. 4-b / §5
    "streaming": bench_streaming.run,         # online matching service
    "dtw": bench_dtw.run,                     # paper §3.1.2 scaling
    "wavelet": bench_wavelet.run,             # paper §5 future plan
    "autotune": bench_autotune.run,           # paper §4 end goal, on JAX
    "roofline": bench_roofline.run,           # dry-run aggregation
}

#: benches whose rows are checked in as BENCH_<name>.json baselines (the
#: matching-stack hot paths — the numbers PRs claim speedups against).
BASELINE_BENCHES = ("matching", "streaming")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="also write rows (+ failures) to this JSON file")
    args = ap.parse_args()

    rows = []
    failed = []
    per_bench = {}
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== bench: {name} =====")
        try:
            bench_rows = fn()
            rows.extend(bench_rows)
            per_bench[name] = bench_rows
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, repr(e)))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "failed": [{"bench": n, "error": e}
                                  for n, e in failed]}, f, indent=1)
        for name in BASELINE_BENCHES:
            if name not in per_bench:
                continue
            path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"bench": name,
                           "rows": [{"name": n, "us_per_call": us,
                                     "derived": d}
                                    for n, us, d in per_bench[name]]},
                          f, indent=1)
            print(f"[baseline] wrote {path}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
