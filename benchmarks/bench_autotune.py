"""Self-tuning on the framework itself (the paper's end goal, §4):

1. Build utilization signatures for assigned architectures by abstractly
   tracing their forward/loss step (the "small set of data" profiling run).
2. Store signatures + best-known exec configs in the ReferenceDB.
3. A "new" workload (kimi-k2, held out of the DB) is matched with the
   paper's DTW+correlation pipeline and inherits the exec config of its
   nearest neighbour — expected: deepseek-v2 (the other MLA+MoE arch).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core import ReferenceDB, AutoTuner
from repro.core.signatures import signature_of
from repro.models import model as model_lib

PROFILE_ARCHS = ["deepseek-v2-236b", "phi3-mini-3p8b", "starcoder2-15b",
                 "granite-20b", "minitron-4b", "zamba2-7b"]
QUERY_ARCH = "kimi-k2-1t-a32b"

# profiling shape: the paper profiles on a SMALL input, not the full run
PROF_B, PROF_S = 4, 512
#: signature resolution must preserve per-layer structure through the
#: Chebyshev de-noise (64 scan steps x ~32 samples/layer), and the match
#: threshold is re-calibrated for jaxpr-trace signatures the same way the
#: paper set 0.9 empirically for SysStat traces (EXPERIMENTS.md §Matching).
#: BAND is ONE layer period (2048 / 64): DTW may slide the alignment by at
#: most one layer, so matching is decided by within-layer utilization
#: shape (MoE routing dips etc.).  At two layer periods (the old 64) the
#: warp was loose enough for phi3's dense waves to cover kimi-k2's MLA+MoE
#: pattern and edge out deepseek-v2 0.8994 vs 0.8963; the golden-signature
#: regression in tests/test_database_tuner.py pins the fixed ordering.
SAMPLES = 2048
BAND = 32
THRESHOLD = 0.85


def _signature(arch: str) -> np.ndarray:
    cfg = cfglib.get(arch)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: model_lib.init(k, cfg), key)
    tok_shape = (PROF_B, PROF_S) if cfg.num_codebooks == 1 else \
        (PROF_B, PROF_S, cfg.num_codebooks)
    batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
             "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    return signature_of(
        lambda p, b: model_lib.loss_fn(p, b, cfg)[0], params, batch,
        samples=SAMPLES)


def run():
    db = ReferenceDB()
    tuner = AutoTuner(db, band=BAND, threshold=THRESHOLD)

    t0 = time.time()
    for arch in PROFILE_ARCHS:
        sig = _signature(arch)
        tuner.profile(arch, {"B": PROF_B, "S": PROF_S}, sig)
        db.set_best_config(arch, cfglib.exec_default(arch, "train_4k").as_dict(),
                           score=1.0)
    t_profile = (time.time() - t0) / len(PROFILE_ARCHS)

    t0 = time.time()
    qsig = _signature(QUERY_ARCH)
    decision = tuner.match(QUERY_ARCH, qsig)
    t_match = time.time() - t0

    print(f"[autotune] query {QUERY_ARCH} scores:")
    for w, s in sorted(decision.scores.items(), key=lambda kv: -kv[1]):
        print(f"    {w:20s} {s:.4f}")
    print(f"[autotune] matched={decision.matched} corr={decision.corr:.4f} "
          f"-> transferred config: {decision.config}")
    assert decision.matched == "deepseek-v2-236b", decision.scores
    assert decision.corr >= THRESHOLD
    assert decision.config is not None and decision.config.get("fsdp") is True

    return [("autotune_profile_per_arch", t_profile * 1e6,
             f"match={decision.matched};corr={decision.corr:.3f}"),
            ("autotune_match_call", t_match * 1e6,
             f"db_size={len(PROFILE_ARCHS)}")]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
