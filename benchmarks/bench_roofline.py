"""Roofline report: aggregates the dry-run artifacts into the per-(arch x
shape x mesh) table used by EXPERIMENTS.md §Roofline, and emits summary
rows for the benchmark CSV.
"""

from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline_table.md")


def load_records(pattern: str = "*.json"):
    recs = []
    for p in sorted(glob.glob(os.path.join(ART_DIR, pattern))):
        if "__tuned" in p or "__hc" in p:
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    rf = r["roofline"]
    t = rf["terms_seconds"]
    mem_gb = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['mesh_name']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | {rf['dominant']} "
            f"| {rf['useful_compute_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} | {mem_gb:.1f} |")


def run():
    recs = load_records()
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | useful ratio | roofline frac | temp GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    doms = {"compute": 0, "memory": 0, "collective": 0}
    worst = None
    for r in recs:
        lines.append(fmt_row(r))
        rf = r["roofline"]
        doms[rf["dominant"]] += 1
        key = (rf["roofline_fraction"], r["arch"], r["shape"])
        if r["shape"] == "train_4k" and (worst is None or key < worst):
            worst = key
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[roofline] {len(recs)} cells -> {OUT_MD}")
    print(f"[roofline] dominant-term histogram: {doms}")
    if worst:
        print(f"[roofline] worst train cell: {worst[1]} x {worst[2]} "
              f"frac={worst[0]:.3f}")
    return [("roofline_cells", float(len(recs)),
             f"dominant_hist={doms}".replace(",", ";"))]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
