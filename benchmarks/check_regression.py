"""Perf-regression guard: compare fresh ``benchmarks/run.py --json``
output against the committed ``BENCH_*.json`` baselines.

Exits non-zero when any row's ``us_per_call`` regressed more than
``--threshold`` (default 25%) over its committed baseline — CI runs this
in a non-blocking job, so a regression fails-with-warning instead of
wedging the queue (shared runners are noisy; the committed baselines come
from the bench host).  Rows present on only one side (new benches,
retired benches) are reported but never fail the check — EXCEPT that a
bench named via ``--require`` must contribute at least one fresh row
matching its committed baseline file, so a silently-crashed bench (its
rows all "[skip] in baseline only") can no longer pass as a vacuous
success: the guard genuinely diffs every required BENCH file.

NOTE: ``run.py --json`` REWRITES the repo-root baselines as a side
effect, so CI snapshots them (``--baseline-dir``) before running the
benches; comparing against the freshly rewritten files would be vacuous.

``--require-row NAME`` pins an individual row: the named row must be
present in the fresh output (baseline or not), so a scenario silently
dropped from a bench (e.g. one of the ``stream_tick_S*`` churn sizes)
fails the guard even while the bench as a whole still contributes rows.

    python -m benchmarks.check_regression \
        --fresh fresh_matching.json --fresh fresh_streaming.json \
        --require matching --require streaming \
        --require-row stream_tick_S1024 \
        [--baseline-dir DIR] [--threshold 0.25]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", ())}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", action="append", required=True,
                    help="fresh run.py --json output (repeatable)")
    ap.add_argument("--baseline-dir", default=_REPO_ROOT,
                    help="directory holding the committed BENCH_*.json "
                         "snapshots")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fail when us_per_call grows more than this "
                         "fraction over baseline")
    ap.add_argument("--require", action="append", default=[],
                    help="bench name (BENCH_<name>.json) that must "
                         "contribute fresh rows; repeatable.  Guards "
                         "against a crashed bench passing vacuously.")
    ap.add_argument("--require-row", action="append", default=[],
                    help="row name that must appear in the fresh output; "
                         "repeatable.  Guards against a scenario being "
                         "silently dropped from a still-running bench.")
    args = ap.parse_args()

    baseline: dict = {}
    per_bench: dict = {}
    for path in sorted(glob.glob(os.path.join(args.baseline_dir,
                                              "BENCH_*.json"))):
        rows = load_rows(path)
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        per_bench[name] = rows
        baseline.update(rows)
    if not baseline:
        print(f"no BENCH_*.json baselines under {args.baseline_dir}; "
              "nothing to compare", file=sys.stderr)
        raise SystemExit(2)

    fresh: dict = {}
    for path in args.fresh:
        fresh.update(load_rows(path))

    uncovered = []
    for name in args.require:
        base_rows = per_bench.get(name)
        if base_rows is None:
            uncovered.append((name, "no committed BENCH baseline"))
            continue
        hit = len(set(base_rows) & set(fresh))
        print(f"[coverage] {name}: {hit}/{len(base_rows)} baseline rows "
              "have fresh measurements")
        if hit == 0:
            uncovered.append((name, "no fresh rows (bench crashed or "
                                    "not run?)"))
    for row in args.require_row:
        if row in fresh:
            print(f"[coverage] row {row}: present "
                  f"({fresh[row]:.1f} us)")
        else:
            uncovered.append((row, "required row missing from fresh "
                                    "output (scenario dropped?)"))

    regressions = []
    for name in sorted(baseline):
        if name not in fresh:
            print(f"[skip] {name}: in baseline only (bench not run?)")
            continue
        base, now = baseline[name], fresh[name]
        ratio = (now - base) / base if base > 0 else 0.0
        flag = "REGRESSED" if ratio > args.threshold else "ok"
        print(f"[{flag}] {name}: {base:.1f} -> {now:.1f} us "
              f"({ratio:+.1%})")
        if ratio > args.threshold:
            regressions.append((name, base, now, ratio))
    for name in sorted(set(fresh) - set(baseline)):
        print(f"[new] {name}: {fresh[name]:.1f} us (no baseline yet)")

    failed = False
    if uncovered:
        failed = True
        for name, why in uncovered:
            print(f"\nrequired bench {name!r} not covered: {why}",
                  file=sys.stderr)
    if regressions:
        failed = True
        print(f"\n{len(regressions)} row(s) regressed more than "
              f"{args.threshold:.0%} vs committed baselines:",
              file=sys.stderr)
        for name, base, now, ratio in regressions:
            print(f"  {name}: {base:.1f} -> {now:.1f} us ({ratio:+.1%})",
                  file=sys.stderr)
    if failed:
        raise SystemExit(1)
    print("\nno perf regressions beyond threshold")


if __name__ == "__main__":
    main()
