"""DTW implementations: jnp min-plus scan vs Pallas kernel (interpret) vs
Sakoe-Chiba banded, over series lengths (paper §3.1.2 + §5 scaling
discussion: DTW is the quadratic hot spot of cluster-scale matching).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dtw
from repro.kernels.dtw import dtw_batched


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    for n in (128, 256, 512):
        x = rng.normal(size=n).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        us_jnp = _timeit(dtw.dtw_matrix, x, y)
        us_band = _timeit(lambda a, b: dtw.dtw_matrix_banded(a, b, band=n // 8),
                          x, y)
        rows.append((f"dtw_jnp_n{n}", us_jnp, "full_matrix"))
        rows.append((f"dtw_banded_n{n}", us_band,
                     f"band={n//8};work_ratio~{2*(n//8)/n:.2f}"))
    # pallas kernel (interpret mode on CPU -> correctness timing only)
    x = rng.normal(size=128).astype(np.float32)
    ys = rng.normal(size=(4, 128)).astype(np.float32)
    us_k = _timeit(lambda a, b: dtw_batched(a, b), x, ys, reps=1)
    rows.append(("dtw_pallas_interpret_n128_k4", us_k,
                 "interpret-mode (CPU container); TPU target"))
    for r in rows:
        print(f"[dtw] {r[0]}: {r[1]:.0f}us {r[2]}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
