"""Paper Table 1 reproduction: similarity (DTW + correlation, %) between
Exim-mainlog (query) and WordCount / TeraSort (reference DB) for the
paper's four configuration-parameter sets.

Expected structure (paper §5): the Exim x WordCount diagonal (same param
set) is the highest and clears the 0.9 threshold; TeraSort scores lower.
"""

from __future__ import annotations

import time

import numpy as np

from repro import mrsim
from repro.core import similarity

BAND = 8   # Sakoe-Chiba band (see DESIGN.md §8: improves discrimination)


def run():
    psets = mrsim.paper_param_sets()
    refs = {app: [mrsim.simulate_cpu_series(app, p) for p in psets]
            for app in ("wordcount", "terasort")}
    queries = [mrsim.simulate_cpu_series("exim", p, run=1) for p in psets]

    t0 = time.time()
    n_calls = 0
    table = {}
    for app, series in refs.items():
        M = np.zeros((len(psets), len(psets)))
        for i in range(len(psets)):          # reference param set
            for j in range(len(psets)):      # query param set
                M[i, j] = similarity(queries[j], series[i], preprocess=True,
                                     band=BAND)
                n_calls += 1
        table[app] = M
    dt = time.time() - t0

    print("\n=== Table 1 reproduction: SIM(Exim_j, {app}_i) in % ===")
    hdr = " | ".join(f"exim p{j}" for j in range(len(psets)))
    for app, M in table.items():
        print(f"-- {app} --        {hdr}")
        for i in range(len(psets)):
            row = " | ".join(f"{100*M[i,j]:7.2f}" for j in range(len(psets)))
            print(f"  {app[:9]:9s} p{i}:  {row}")

    wc_diag = np.diag(table["wordcount"])
    ts_diag = np.diag(table["terasort"])
    ok_thresh = bool((wc_diag >= 0.9).all())
    ok_order = bool(wc_diag.mean() > ts_diag.mean())
    print(f"wordcount diag mean {100*wc_diag.mean():.2f}%  "
          f"terasort diag mean {100*ts_diag.mean():.2f}%  "
          f"diag>=90%: {ok_thresh}  wc>ts: {ok_order}")
    assert ok_thresh and ok_order, "Table-1 structure not reproduced"

    us = dt / n_calls * 1e6
    return [("paper_table1_simcall", us,
             f"wc_diag={100*wc_diag.mean():.1f}%"
             f";ts_diag={100*ts_diag.mean():.1f}%;structure_ok=True")]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
