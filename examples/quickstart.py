"""Quickstart: the paper's full pipeline in ~40 lines.

Profiling phase: simulate CPU-utilization series for WordCount and
TeraSort under the paper's four {M, R, FS, I} parameter sets, de-noise
with the 6th-order Chebyshev filter, store in the reference DB with their
known-good configs.  Matching phase: a new application (Exim mainlog
parsing) is DTW-matched and inherits WordCount's configuration.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import mrsim
from repro.core import AutoTuner, ReferenceDB

db = ReferenceDB()
tuner = AutoTuner(db, band=8)

# --- profiling phase (paper Fig. 4-a) -----------------------------------
for app in ("wordcount", "terasort"):
    for pset in mrsim.paper_param_sets():
        series = mrsim.simulate_cpu_series(app, pset)
        tuner.profile(app, pset.as_dict(), series)

# suppose prior runs found these optimal configuration parameters:
db.set_best_config("wordcount", {"mappers": 21, "reducers": 30,
                                 "split_mb": 10, "input_mb": 80}, score=1.0)
db.set_best_config("terasort", {"mappers": 42, "reducers": 33,
                                "split_mb": 20, "input_mb": 60}, score=1.0)

# --- matching phase (paper Fig. 4-b) -------------------------------------
new_series = mrsim.simulate_cpu_series("exim", mrsim.paper_param_sets()[0],
                                       run=1)
decision = tuner.match("exim-mainlog", new_series)

print("candidate scores:", {k: f"{v:.3f}" for k, v in decision.scores.items()})
print(f"matched application: {decision.matched} "
      f"(CORR={decision.corr:.3f} >= 0.9)")
print(f"transferred configuration parameters: {decision.config}")
assert decision.matched == "wordcount"
