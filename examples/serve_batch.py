"""Batched serving demo: prefill + greedy decode with a KV cache on a
smoke-sized StarCoder2-family model (the 'serve a small model with
batched requests' end-to-end path).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.argv = ["serve", "--arch", "starcoder2-15b", "--smoke", "--batch", "4",
            "--prompt-len", "32", "--max-new", "16"]
import runpy
runpy.run_module("repro.launch.serve", run_name="__main__")
