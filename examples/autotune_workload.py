"""The paper's technique deployed on the framework itself: a new
architecture inherits tuned execution parameters from its nearest
utilization-signature neighbour instead of a parameter search.

    PYTHONPATH=src python examples/autotune_workload.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import bench_autotune

for name, us, derived in bench_autotune.run():
    print(f"{name}: {us:.0f}us  {derived}")
