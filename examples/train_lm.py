"""End-to-end training driver: decoder-only LM on the synthetic corpus
with checkpoint/restart, cosine schedule and signature recording.

Default run fits a CPU container; pass --hundred-m for the ~100M-param
configuration (12L x 768, vocab 32768, seq 256, a few hundred steps —
sized for a real accelerator).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import runpy

args = sys.argv[1:]
if "--hundred-m" in args:
    args.remove("--hundred-m")
    args = ["--layers", "12", "--d-model", "768", "--vocab", "32768",
            "--seq", "256", "--batch", "8"] + args
else:
    args = ["--layers", "4", "--d-model", "256", "--vocab", "4096",
            "--seq", "128", "--batch", "4"] + args

sys.argv = ["train"] + args + ["--ckpt-dir", "/tmp/repro_train_ckpt",
                               "--tuner-db", "/tmp/repro_tuner_db"]
runpy.run_module("repro.launch.train", run_name="__main__")
