"""Streaming prefix-DTW matching stack.

The tentpole invariants:

* carrying the DP state across arriving chunks reproduces the one-shot
  batched solve EXACTLY, for any chunking, ragged and banded banks alike;
* prefix (open-end) distances are monotone in information — more samples
  never destroy evidence, so early pruning is sound and no prefix can
  certify an exact match for a reference the complete series rejects;
* once the series completes, the streamed score IS the offline
  ``similarity_bank`` score;
* a multi-job service tick is ONE device dispatch, however many jobs are
  in flight.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import mrsim
from repro.core import (OnlineMatcher, StreamingFilter, dtw, similarity_bank)
from repro.core.database import pack_series
from repro.core.filters import cheby1_design, lfilter
from repro.core.similarity import prefix_similarity_bank
from repro.serve.tuning import TuningService


def _random_chunks(rng, x):
    """Split x into random-size chunks (including size-1 and large)."""
    chunks = []
    lo = 0
    while lo < len(x):
        c = int(rng.integers(1, max(2, len(x) // 2)))
        chunks.append(x[lo: lo + c])
        lo += c
    return chunks


def _stream(x, bank, rng, band=None):
    st_ = dtw.dtw_bank_init(bank.series, bank.lengths, band=band,
                            query_len=len(x))
    for chunk in _random_chunks(rng, x):
        st_, _ = dtw.dtw_bank_extend(st_, chunk)
    return st_


# ---------------------------------------------------------------------------
# Property: any chunking == one-shot (ragged + banded)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_streaming_equals_oneshot_any_chunking(seed):
    rng = np.random.default_rng(seed)
    lengths = rng.integers(3, 40, size=int(rng.integers(2, 7)))
    series = [rng.normal(size=int(l)).astype(np.float32) for l in lengths]
    bank = pack_series(series)
    x = rng.normal(size=int(rng.integers(2, 48))).astype(np.float32)

    got = np.asarray(_stream(x, bank, rng).distances())
    want = np.asarray(dtw.dtw_distance_bank(x, bank.series, bank.lengths))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_streaming_equals_oneshot_banded_any_chunking(seed):
    rng = np.random.default_rng(seed ^ 0x5EED)
    lengths = rng.integers(3, 40, size=int(rng.integers(2, 7)))
    series = [rng.normal(size=int(l)).astype(np.float32) for l in lengths]
    bank = pack_series(series)
    # n and band keep the Sakoe-Chiba corridor connected (per-row center
    # jump < band) — with a disconnected corridor the distance is the
    # +inf-saturated sentinel, where the two formulations may saturate
    # differently and comparison is meaningless.
    x = rng.normal(size=int(rng.integers(16, 48))).astype(np.float32)
    band = int(rng.integers(6, 10))

    got = np.asarray(_stream(x, bank, rng, band=band).distances())
    want = np.asarray(dtw.dtw_distance_bank(x, bank.series, bank.lengths,
                                            band=band))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prefix_distances_monotone_in_information(seed):
    """Open-end prefix distances never decrease as samples arrive: every
    longer-prefix alignment extends a shorter one with non-negative cost.
    Corollary (tested below): no prefix can undercut the final distance,
    so a workload the complete series rejects can never be exact-matched
    from a prefix."""
    rng = np.random.default_rng(seed ^ 0xD15C0)
    series = [rng.normal(size=int(l)).astype(np.float32)
              for l in rng.integers(4, 30, size=4)]
    bank = pack_series(series)
    x = rng.normal(size=40).astype(np.float32)

    st_ = dtw.dtw_bank_init(bank.series, bank.lengths)
    prev = np.zeros((len(series),))
    history = []
    for chunk in _random_chunks(rng, x):
        st_, _ = dtw.dtw_bank_extend(st_, chunk)
        cur = np.asarray(st_.prefix_distances())
        assert (cur >= prev - 1e-4).all(), "prefix distance decreased"
        history.append(cur)
        prev = cur
    final = history[-1]
    for cur in history:          # no prefix undercuts the final evidence
        assert (cur <= final + 1e-4).all()


def test_prefix_exact_match_soundness():
    """A reference the full series rejects (positive final open-end
    distance) is never reported as an exact (zero-distance) match once any
    evidence against it has accumulated — monotonicity makes the early
    exact-match claim one-way."""
    y = np.linspace(0.0, 1.0, 24, dtype=np.float32)
    bank = pack_series([y])
    # query tracks y for 12 samples then diverges hard
    x = np.concatenate([y[:12], np.full(12, 5.0, np.float32)])

    st_ = dtw.dtw_bank_init(bank.series, bank.lengths)
    st_, _ = dtw.dtw_bank_extend(st_, x[:12])
    assert float(st_.prefix_distances()[0]) == pytest.approx(0.0, abs=1e-6)
    st_, _ = dtw.dtw_bank_extend(st_, x[12:])
    rejected_at = float(st_.prefix_distances()[0])
    assert rejected_at > 1.0
    # further samples can only pile on: streaming more of the divergent
    # tail never resurrects the exact match
    st_, _ = dtw.dtw_bank_extend(st_, np.full(6, 5.0, np.float32))
    assert float(st_.prefix_distances()[0]) >= rejected_at - 1e-4


# ---------------------------------------------------------------------------
# Rows / scoring layer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def wave_set():
    rng = np.random.default_rng(7)
    series = [np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 5 + i, l))
                      + 0.05 * rng.normal(size=l), 0, 1).astype(np.float32)
              for i, l in enumerate((50, 80, 65))]
    x = np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 6, 70))
                + 0.05 * rng.normal(size=70), 0, 1).astype(np.float32)
    return x, pack_series(series)


def test_collected_rows_match_matrix_bank(wave_set):
    x, bank = wave_set
    st_ = dtw.dtw_bank_init(bank.series, bank.lengths)
    rows = []
    for lo in range(0, len(x), 9):
        st_, r = dtw.dtw_bank_extend(st_, x[lo: lo + 9], collect_rows=True)
        rows.append(np.asarray(r))
    D = np.concatenate(rows).transpose(1, 0, 2)
    want = np.asarray(dtw.dtw_matrix_bank(x, bank.series, bank.lengths))
    np.testing.assert_allclose(D, want, rtol=1e-4, atol=1e-4)


def test_streamed_final_score_equals_offline(wave_set):
    x, bank = wave_set
    om = OnlineMatcher(bank)
    for lo in range(0, len(x), 13):
        om.extend(x[lo: lo + 13])
    np.testing.assert_allclose(om.final_scores(), similarity_bank(x, bank),
                               rtol=1e-4, atol=1e-4)


def test_streamed_final_score_equals_offline_banded(wave_set):
    x, bank = wave_set
    om = OnlineMatcher(bank, band=6, query_len=len(x))
    for lo in range(0, len(x), 7):
        om.extend(x[lo: lo + 7])
    np.testing.assert_allclose(om.final_scores(),
                               similarity_bank(x, bank, band=6),
                               rtol=1e-4, atol=1e-4)


def test_prefix_scores_need_collected_rows(wave_set):
    x, bank = wave_set
    om = OnlineMatcher(bank, collect_rows=False)
    om.extend(x[:16])
    with pytest.raises(ValueError, match="collect_rows"):
        om.prefix_scores()
    assert om.distances().shape == (len(bank),)


def test_prefix_similarity_rejects_row_mismatch(wave_set):
    x, bank = wave_set
    om = OnlineMatcher(bank)
    om.extend(x[:16])
    with pytest.raises(ValueError, match="rows"):
        prefix_similarity_bank(x[:10], bank, om._rows.view())


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_running_moments_match_two_pass_correlation(seed):
    """RunningMoments (single-pass, chunked) must agree with the offline
    two-pass correlation() it stands in for — pins the two implementations
    together so they can't drift apart."""
    from repro.core.similarity import RunningMoments, correlation

    rng = np.random.default_rng(seed ^ 0xC022)
    n = int(rng.integers(2, 200))
    x = rng.normal(size=n)
    y = 0.4 * x + rng.normal(size=n)
    rm = RunningMoments()
    lo = 0
    while lo < n:
        c = int(rng.integers(1, n + 1))
        rm.update(x[lo: lo + c], y[lo: lo + c])
        lo += c
    want = float(np.clip(correlation(x, y), -1.0, 1.0))
    assert rm.corr == pytest.approx(want, abs=1e-9)


def test_running_moments_degenerate_conventions():
    from repro.core.similarity import RunningMoments, correlation

    ones = np.ones(10)
    assert RunningMoments().update(ones, ones).corr == 1.0 \
        == correlation(ones, ones)
    assert RunningMoments().update(ones, 2 * ones).corr == 0.0 \
        == correlation(ones, 2 * ones)
    assert RunningMoments().corr == 0.0


def test_streaming_filter_chunking_invariant():
    rng = np.random.default_rng(3)
    x = rng.normal(size=200).astype(np.float32)
    b, a = cheby1_design(6, 1.0, 0.125)
    want = np.asarray(lfilter(b, a, x))
    for chunks in ((200,), (1, 199), (7, 64, 129), (50, 50, 50, 50)):
        sf = StreamingFilter()
        got = np.concatenate([sf(c) for c in np.split(x, np.cumsum(chunks))[:-1]])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_iter_cpu_series_concatenates_to_simulate():
    p = mrsim.paper_param_sets()[0]
    want = mrsim.simulate_cpu_series("terasort", p, run=2)
    got = np.concatenate(list(mrsim.iter_cpu_series("terasort", p, run=2,
                                                    chunk=7)))
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError):
        next(mrsim.iter_cpu_series("terasort", p, chunk=0))


# ---------------------------------------------------------------------------
# TuningService
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_bank():
    """Preprocessed references, as AutoTuner.profile stores them."""
    from repro.core.database import SeriesBank
    from repro.core.filters import preprocess_bank

    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in ("wordcount", "terasort"):
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


def test_service_lifecycle_and_one_dispatch_per_tick(paper_bank):
    svc = TuningService(paper_bank, band=16, threshold=0.85, denoise=True,
                        slots=4, min_fraction=0.15, stable_ticks=2)
    p = mrsim.paper_param_sets()[0]
    queries = {f"job{r}": mrsim.simulate_cpu_series("exim", p, run=r,
                                                    dt=0.25)
               for r in (1, 2, 3)}
    for jid, q in queries.items():
        svc.submit(jid, expected_len=len(q))
    assert svc.n_active == 3
    with pytest.raises(ValueError):
        svc.submit("job1", expected_len=10)

    n = max(len(q) for q in queries.values())
    for lo in range(0, n, 8):
        for jid, q in queries.items():
            svc.push(jid, q[lo: lo + 8])
        svc.tick()
    assert svc.dispatch_count <= svc.ticks          # ONE dispatch per tick

    for jid in queries:
        d = svc.finish(jid)
        assert d.final and d.matched == "wordcount"
        assert d.fraction_seen == 1.0
        assert set(d.scores) == {"wordcount", "terasort"}
    assert svc.n_active == 0
    # slots were freed: a fresh submit succeeds
    svc.submit("again", expected_len=32)


def test_service_slot_exhaustion(paper_bank):
    svc = TuningService(paper_bank, slots=1)
    svc.submit("a", expected_len=8)
    with pytest.raises(RuntimeError, match="slots busy"):
        svc.submit("b", expected_len=8)


def test_service_early_decision_abstains_below_min_fraction(paper_bank):
    """The confidence rule must hold fire before min_fraction even if the
    leader is already stable and above threshold."""
    svc = TuningService(paper_bank, band=16, threshold=0.5, margin=0.0,
                        stable_ticks=1, min_fraction=0.9, denoise=True)
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc.submit("q", expected_len=len(q))
    seen = 0
    for lo in range(0, len(q) // 2, 8):             # only half the job
        svc.push("q", q[lo: lo + 8])
        decisions = svc.tick()
        seen += 1
        assert decisions.get("q") is None, "decided below min_fraction"
    assert seen > 0


def test_service_emits_early_then_final(paper_bank):
    svc = TuningService(paper_bank, band=16, threshold=0.85, margin=0.02,
                        stable_ticks=3, min_fraction=0.15, denoise=True)
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc.submit("q", expected_len=len(q))
    early = None
    for lo in range(0, len(q), 8):
        svc.push("q", q[lo: lo + 8])
        d = svc.tick().get("q")
        if d is not None and early is None:
            early = d
    assert early is not None and not early.final
    assert early.matched == "wordcount"
    assert 0.0 < early.fraction_seen < 1.0
    final = svc.finish("q")
    assert final.final and final.matched == "wordcount"


def test_service_distance_only_mode_matches_offline(paper_bank):
    """collect_rows=False: no in-flight scoring, but finish() still agrees
    with the offline batch engine."""
    svc = TuningService(paper_bank, band=16, collect_rows=False)
    p = mrsim.paper_param_sets()[1]
    q = mrsim.simulate_cpu_series("wordcount", p, run=1, dt=0.25)
    svc.submit("q", expected_len=len(q))
    svc.push("q", q)
    assert svc.tick() == {"q": None}
    d = svc.finish("q")
    off = similarity_bank(q, paper_bank, band=16)
    best = {}
    for lbl, s in zip(paper_bank.labels, off):
        best[lbl] = max(best.get(lbl, -1.0), float(s))
    assert d.scores == pytest.approx(best, abs=1e-6)


def test_service_rejects_empty_bank():
    with pytest.raises(ValueError, match="empty"):
        TuningService(pack_series([]))


def test_service_banded_finish_self_corrects_wrong_expected_len(paper_bank):
    """expected_len is a runtime *prediction*; if the job ends at a
    different length, the streamed banded corridor was misplaced — the
    final verdict must fall back to the offline solve (band re-derived
    from the true length) instead of scoring through the stale corridor."""
    p = mrsim.paper_param_sets()[1]
    q = mrsim.simulate_cpu_series("wordcount", p, run=1, dt=0.25)
    svc = TuningService(paper_bank, band=16, collect_rows=True)
    svc.submit("q", expected_len=2 * len(q))        # prediction way off
    svc.push("q", q)
    svc.tick()
    d = svc.finish("q")
    off = similarity_bank(q, paper_bank, band=16)
    best = {}
    for lbl, s in zip(paper_bank.labels, off):
        best[lbl] = max(best.get(lbl, -1.0), float(s))
    assert d.scores == pytest.approx(best, abs=1e-6)
    assert d.matched == "wordcount"


def test_finish_does_not_drop_other_jobs_decisions(paper_bank):
    """finish() drains buffers with an internal tick; an early decision
    that tick emits for a DIFFERENT job must surface from the next
    tick() instead of vanishing."""
    p = mrsim.paper_param_sets()[0]
    qa = mrsim.simulate_cpu_series("terasort", p, run=1, dt=0.25)
    qb = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc = TuningService(paper_bank, band=16, threshold=0.5, margin=0.0,
                        stable_ticks=1, min_fraction=0.1, denoise=True)
    svc.submit("ja", expected_len=len(qa))
    svc.submit("jb", expected_len=len(qb))
    # jb gets enough samples that the (deliberately lax) rule decides on
    # the very tick that finish("ja") runs internally
    svc.push("ja", qa)
    svc.push("jb", qb[: len(qb) // 2])
    svc.finish("ja")
    assert svc._jobs["jb"].early is not None       # decided internally...
    later = svc.tick()                              # ...and not lost:
    assert later.get("jb") is svc._jobs["jb"].early


def test_finish_purges_undelivered_decision_of_finished_job(paper_bank):
    """A parked early decision must not outlive its job: finishing the job
    before the next tick() removes it, so a reused job_id can never
    receive a ghost decision from its predecessor."""
    p = mrsim.paper_param_sets()[0]
    qa = mrsim.simulate_cpu_series("terasort", p, run=1, dt=0.25)
    qb = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc = TuningService(paper_bank, band=16, threshold=0.5, margin=0.0,
                        stable_ticks=1, min_fraction=0.1, denoise=True)
    svc.submit("ja", expected_len=len(qa))
    svc.submit("jb", expected_len=len(qb))
    svc.push("ja", qa)
    svc.push("jb", qb[: len(qb) // 2])
    svc.finish("ja")                   # parks jb's early decision
    assert "jb" in svc._undelivered
    svc.finish("jb")                   # jb ends before any tick()
    assert svc.tick() == {}            # no ghost delivery
    svc.submit("jb", expected_len=len(qb))      # id reuse is clean
    assert svc.tick() == {}


# ---------------------------------------------------------------------------
# Device-resident tick (wavefront extend + fused on-device scoring)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_wavefront_tick_equals_bank_extend_many(seed):
    """The K-last wavefront tick (``dtw.bank_extend_tick``) must agree
    cell-for-cell with the row-formulation reference, across random
    ragged chunkings, ragged banks, banded and unbanded."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed ^ 0xD1A6)
    series = [rng.normal(size=int(l)).astype(np.float32)
              for l in rng.integers(4, 30, size=int(rng.integers(2, 6)))]
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C = int(rng.integers(1, 4)), 8
    band = int(rng.integers(6, 10)) if rng.integers(2) else None
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    rows_w = jnp.full((J, m, k), dtw._INF)
    ns_w = jnp.zeros((J,), jnp.int32)
    rows_h = jnp.full((J, k, m), dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray(rng.random((J, C)).astype(np.float32))
        rows_w, ns_w = dtw.bank_extend_tick(
            rows_w, ns_w, jnp.asarray(bank.series.T),
            jnp.asarray(bank.lengths), ch, nv, qlens, band=band)
        rows_h, ns_h, _ = dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, band, False)
    r1 = np.asarray(rows_w).transpose(0, 2, 1)
    r2 = np.asarray(rows_h)
    finite = r2 < 1e37
    assert (finite == (r1 < 1e37)).all()
    np.testing.assert_allclose(r1[finite], r2[finite], rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ns_w), np.asarray(ns_h))


@pytest.mark.parametrize("band", [None, 9])
def test_fused_device_scores_match_host_prefix_scoring(band):
    """The on-device warp-path-moment scores of the fused tick reproduce
    the host backtrack scorer (``prefix_similarity_bank`` over collected
    rows) at every tick — the tentpole claim that moving scoring
    on-device costs no fidelity."""
    import jax.numpy as jnp

    rng = np.random.default_rng(3 if band is None else band)
    series = []
    for i in range(5):
        l = int(rng.integers(16, 40))
        t = np.linspace(0, 1, l, dtype=np.float32)
        series.append(np.clip(
            0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + i) * t)
            + 0.05 * rng.normal(size=l), 0, 1).astype(np.float32))
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C, nticks = 2, 8, 4
    qlen = nticks * C
    qs = np.stack([np.clip(
        0.5 + 0.3 * np.sin(2 * np.pi * (2 + j) * np.linspace(0, 1, qlen))
        + 0.05 * rng.normal(size=qlen), 0, 1).astype(np.float32)
        for j in range(J)])
    rows = jnp.full((J, m, k), dtw._INF)
    moms = jnp.zeros((3, J, m, k))
    ns = jnp.zeros((J,), jnp.int32)
    sx = jnp.zeros((J,))
    sxx = jnp.zeros((J,))
    qlens = jnp.full((J,), qlen, jnp.int32)
    rows_h = jnp.full((J, k, m), dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    collected = []
    for t0 in range(nticks):
        ch = jnp.asarray(qs[:, t0 * C:(t0 + 1) * C])
        nv = jnp.full((J,), C, jnp.int32)
        rows, moms, ns, sx, sxx, scores = dtw.bank_extend_tick_scored(
            rows, moms, ns, sx, sxx, jnp.asarray(bank.series.T),
            jnp.asarray(bank.lengths), ch, nv, qlens, band=band)
        rows_h, ns_h, coll = dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, band, True)
        collected.append(np.asarray(coll))
        stack = np.concatenate(collected)
        dev = np.asarray(scores)
        for j in range(J):
            host = prefix_similarity_bank(qs[j, :(t0 + 1) * C], bank,
                                          stack[:, j])
            np.testing.assert_allclose(dev[j], host, atol=2e-3)


def test_service_margin_needs_two_workloads(paper_bank):
    """A single-workload bank has no runner-up, so the margin gate must
    not pass vacuously: the service abstains in flight (finish() still
    delivers the final verdict)."""
    from repro.core.database import SeriesBank

    rows = [i for i, lbl in enumerate(paper_bank.labels)
            if lbl == "wordcount"]
    solo = SeriesBank(paper_bank.series[rows], paper_bank.lengths[rows],
                      tuple(paper_bank.labels[i] for i in rows))
    # deliberately lax rule: threshold/margin/stability would all pass
    # trivially if the vacuous runner-up (-1.0) were allowed
    svc = TuningService(solo, band=16, threshold=0.3, margin=0.0,
                        stable_ticks=1, min_fraction=0.05, denoise=True)
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("wordcount", p, run=1, dt=0.25)
    svc.submit("q", expected_len=len(q))
    for lo in range(0, len(q), 8):
        svc.push("q", q[lo: lo + 8])
        assert svc.tick().get("q") is None, \
            "early decision from a single-workload bank"
    final = svc.finish("q")
    assert final.final and final.matched == "wordcount"


def test_service_scoring_tick_moves_no_rows(paper_bank):
    """The scoring tick's device->host traffic is the [S, K] score array:
    the job objects hold no DP-row history any more (finish() recomputes
    offline instead)."""
    svc = TuningService(paper_bank, band=16, denoise=True)
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc.submit("q", expected_len=len(q))
    svc.push("q", q[:32])
    svc.tick()
    job = svc._jobs["q"]
    assert not hasattr(job, "rows")
    assert job.last_sims is not None
    assert job.last_sims.shape == (len(paper_bank),)
    assert svc.dispatch_count == 1
    d = svc.finish("q")
    assert svc.offline_dispatch_count == 1 and svc.dispatch_count == 1
    assert set(d.scores) == {"wordcount", "terasort"}


@pytest.fixture(scope="module")
def golden_bank():
    """All three mrsim apps x paper param sets — the golden-trace bank
    the pruned-vs-unpruned decision property runs against."""
    from repro.core.database import SeriesBank
    from repro.core.filters import preprocess_bank

    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in mrsim.APPS:
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


@pytest.mark.parametrize("app", sorted(mrsim.APPS))
def test_pruned_tick_decisions_equal_unpruned_on_golden_traces(
        golden_bank, app):
    """Property: with the streaming wavelet prefilter pruning the bank,
    every in-flight decision (matched workload, correlation,
    decided_at_fraction) and the final verdict equal the unpruned
    service's, tick for tick, on the golden exim/wordcount/terasort
    traces — the prefilter's soundness-margin contract."""
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series(app, p, run=1, dt=0.25)
    runs = []
    for pf in (None, 4):
        svc = TuningService(golden_bank, band=16, threshold=0.85,
                            margin=0.02, stable_ticks=3, min_fraction=0.15,
                            denoise=True, prefilter_top=pf)
        svc.submit(app, expected_len=len(q))
        seq = []
        for lo in range(0, len(q), 8):
            svc.push(app, q[lo: lo + 8])
            d = svc.tick().get(app)
            seq.append(None if d is None else
                       (d.matched, d.corr, d.decided_at_fraction))
        final = svc.finish(app)
        assert svc.dispatch_count == svc.ticks, \
            "pruning must not change the one-dispatch-per-tick invariant"
        runs.append((seq, final))
    (seq_u, fin_u), (seq_p, fin_p) = runs
    assert seq_p == seq_u
    assert fin_p.matched == fin_u.matched
    assert fin_p.corr == pytest.approx(fin_u.corr, abs=1e-12)
    assert fin_p.decided_at_fraction == fin_u.decided_at_fraction


def _diverse_bank(rng, k, min_len=64):
    series = []
    for i in range(k):
        l = int(rng.integers(min_len, min_len + 40))
        t = np.linspace(0, 1, l, dtype=np.float32)
        s = (0.5 + 0.28 * np.sin(2 * np.pi * (1.5 + 0.3 * i) * t + 0.7 * i)
             + 0.06 * rng.normal(size=l).astype(np.float32))
        series.append(np.clip(s, 0, 1).astype(np.float32))
    return pack_series(series)


def test_prefilter_repack_accounting_and_dispatch_invariant():
    """Re-packs are counted separately and never inflate dispatch_count:
    dispatches == data-carrying ticks holds through prune-driven shrinks
    AND the re-grow when a fresh job re-widens the survivor union."""
    rng = np.random.default_rng(42)
    bank = _diverse_bank(rng, 24)
    qlen = 64
    svc = TuningService(bank, prefilter_top=2, prefilter_margin=0.0,
                        prefilter_min_fraction=0.1, slots=4)
    for j in range(2):
        svc.submit(f"job{j}", expected_len=qlen)
    qs = np.stack([np.clip(bank.row(7 * j)[:qlen]
                           + 0.04 * rng.normal(size=qlen), 0, 1)
                   .astype(np.float32) for j in range(2)])
    data_ticks = 0
    for lo in range(0, qlen, 8):
        for j in range(2):
            svc.push(f"job{j}", qs[j, lo: lo + 8])
        svc.tick()
        data_ticks += 1
    assert svc.dispatch_count == data_ticks == svc.ticks
    shrink_repacks = svc.repack_count
    assert shrink_repacks >= 1, "prune never re-packed the device state"
    assert len(svc._packed_idx) < len(bank)
    # an empty tick moves nothing: no dispatch, no re-pack
    svc.tick()
    assert svc.dispatch_count == data_ticks
    assert svc.repack_count == shrink_repacks
    # pruned-for-this-job references surface as -inf, never a leader
    for j in range(2):
        job = svc._jobs[f"job{j}"]
        assert job.allowed is not None and not job.allowed.all()
        assert np.isneginf(job.last_sims[~job.allowed]).all()
        assert np.isfinite(job.last_sims[int(np.argmax(job.last_sims))])
    for j in range(2):
        svc.finish(f"job{j}")
    # a fresh job needs the whole bank again: the next data tick re-grows
    # the pack (one more re-pack, still one dispatch per data tick)
    svc.submit("fresh", expected_len=qlen)
    svc.push("fresh", qs[0, :8])
    svc.tick()
    assert len(svc._packed_idx) == len(bank)
    assert svc.repack_count == shrink_repacks + 1
    assert svc.dispatch_count == data_ticks + 1


def test_service_decision_history_recorded(paper_bank):
    """A DB-backed service records finished decisions (with
    decided_at_fraction) into the ReferenceDB history."""
    from repro.core import ReferenceDB

    db = ReferenceDB()
    for i, lbl in enumerate(paper_bank.labels):
        db.add(lbl, {"i": i}, paper_bank.row(i))
    svc = TuningService(db, band=16, threshold=0.85, margin=0.02,
                        stable_ticks=3, min_fraction=0.15, denoise=True)
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc.submit("exim", expected_len=len(q))
    early = None
    for lo in range(0, len(q), 8):
        svc.push("exim", q[lo: lo + 8])
        d = svc.tick().get("exim")
        if d is not None and early is None:
            early = d
    final = svc.finish("exim")
    assert early is not None
    assert early.decided_at_fraction == pytest.approx(early.fraction_seen)
    assert final.decided_at_fraction == pytest.approx(
        early.decided_at_fraction)
    hist = db.decision_history(matched="wordcount")
    assert len(hist) == 1 and hist[0]["workload"] == "exim"
    fracs = db.decided_at_fractions("wordcount")
    assert fracs == [pytest.approx(early.decided_at_fraction)]
