"""Serving engine: greedy generation matches teacher-forced argmax."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, model
from repro.serve import ServeEngine

CFG = ModelConfig(name="tiny-serve", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", dtype="float32")


def test_greedy_generation_consistent_with_forward():
    params = model.init(jax.random.PRNGKey(0), CFG)
    engine = ServeEngine(params, CFG, max_len=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, CFG.vocab_size, size=(2, 8)).astype(np.int32)
    out = engine.generate(prompts, max_new=6)
    assert out.shape[:2] == (2, 6)

    # teacher-forced check: feeding prompt+generated reproduces the argmax
    seq = np.concatenate([prompts, out.reshape(2, 6)], axis=1)
    logits, _ = model.forward(params, jnp.asarray(seq), CFG)
    for t in range(6):
        pred = np.argmax(np.asarray(logits[:, 8 + t - 1]), -1)
        np.testing.assert_array_equal(pred, seq[:, 8 + t])


def test_multicodebook_generation():
    cfg = dataclasses.replace(CFG, num_codebooks=2)
    params = model.init(jax.random.PRNGKey(1), cfg)
    engine = ServeEngine(params, cfg, max_len=32)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(2, 8, 2)).astype(np.int32)
    out = engine.generate(prompts, max_new=4)
    assert out.shape == (2, 4, 2)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
