"""Matrix-free offline scoring (closed-end moment-carrying scorers).

Equivalence contract, mirroring the streaming-kernel suite's regimes:

* on DYADIC-GRID data every DTW cost, path sum and moment sum is exactly
  representable in f32, so the wavefront, the min-plus matrix path and
  the Pallas offline kernel make identical predecessor choices — device
  scores equal the host backtrack + correlation reference to float64-
  rounding tolerance (<= 1e-6), and the jnp wavefront equals the Pallas
  kernel BITWISE;
* on continuous-noise data, near-tie argmin flips move individual warp
  paths (~1e-3 score motion) — agreement is pinned at that tolerance.

Plus: batching invariance (J-batched == single bitwise), the Table-1
golden re-lock through the rewired engine, and the guard on the unsound
pure-wavelet prune mode.
"""
import json
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dtw, similarity, similarity_bank
from repro.core.database import pack_series


def _dyadic_series(rng, n, denom=8, hi=9):
    return (rng.integers(0, hi, n) / float(denom)).astype(np.float32)


def _dyadic_bank(rng, k, lo=12, hi=30):
    series = [_dyadic_series(rng, int(rng.integers(lo, hi)))
              for _ in range(k)]
    return series, pack_series(series)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_score_bank_equals_host_backtrack_on_dyadic(seed):
    """Property (ragged + banded): the closed-end moment scorer equals
    ``similarity_bank``'s host-backtrack matrix path on random
    dyadic-grid banks to float64-rounding tolerance."""
    rng = np.random.default_rng(seed)
    series, bank = _dyadic_bank(rng, int(rng.integers(3, 9)))
    x = _dyadic_series(rng, int(rng.integers(8, 26)))
    for band in (None, int(rng.integers(3, 8))):
        got = np.asarray(dtw.dtw_score_bank(
            x, bank.series, bank.lengths, band=band, use_kernel=False))
        want = similarity_bank(x, bank, band=band, matrix_path=True)
        np.testing.assert_allclose(got, want, atol=1e-6)
        # and similarity_bank's default engine IS this scorer
        np.testing.assert_array_equal(
            got, np.asarray(similarity_bank(x, bank, band=band),
                            np.float32))


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_score_bank_many_ragged_equals_per_query_solve(seed):
    """Property: J ragged queries scored in one batched dispatch equal
    each query's own single-dispatch solve BITWISE (per-cell arithmetic
    never sees the batch), and the host reference to 1e-6 on dyadic
    data."""
    rng = np.random.default_rng(seed)
    series, bank = _dyadic_bank(rng, int(rng.integers(3, 8)))
    j = int(rng.integers(2, 5))
    xlens = rng.integers(4, 24, size=j).astype(np.int32)
    xs = np.zeros((j, int(xlens.max())), np.float32)
    for i, l in enumerate(xlens):
        xs[i, :l] = _dyadic_series(rng, int(l))
    band = None if seed % 2 == 0 else 5
    got = np.asarray(dtw.dtw_score_bank_many(
        xs, bank.series, bank.lengths, xlens=xlens, band=band,
        use_kernel=False))
    for i in range(j):
        one = np.asarray(dtw.dtw_score_bank(
            xs[i, :xlens[i]], bank.series, bank.lengths, band=band,
            use_kernel=False))
        np.testing.assert_array_equal(got[i], one)
        want = similarity_bank(xs[i, :xlens[i]], bank, band=band,
                               matrix_path=True)
        np.testing.assert_allclose(got[i], want, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_score_pairs_equals_scalar_similarity_on_dyadic(seed):
    """Property: the pairs scorer (ragged both sides, banded) equals the
    scalar ``similarity`` pipeline on dyadic-grid pairs."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 6))
    qs = [_dyadic_series(rng, int(rng.integers(6, 24))) for _ in range(p)]
    rs = [_dyadic_series(rng, int(rng.integers(6, 24))) for _ in range(p)]
    qb, rb = pack_series(qs), pack_series(rs)
    for band in (None, 4):
        got = np.asarray(dtw.dtw_score_pairs(
            qb.series, rb.series, qb.lengths, rb.lengths, band=band))
        want = np.array([similarity(qs[i], rs[i], band=band)
                         for i in range(p)])
        np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128),
                                          (None, 4), (6, 4)])
def test_offline_kernel_bitwise_vs_jnp_wavefront(band, block_k):
    """The Pallas offline kernel (interpret mode) == the jnp wavefront
    scorer BITWISE — scores and endpoint distances — on dyadic-grid
    ragged banks and ragged queries, including a block_k that forces
    reference-tile padding."""
    rng = np.random.default_rng(7 if band is None else band + block_k)
    series, bank = _dyadic_bank(rng, 7)
    j = 3
    xlens = np.asarray([21, 9, 16], np.int32)
    xs = np.zeros((j, 24), np.float32)
    for i, l in enumerate(xlens):
        xs[i, :l] = _dyadic_series(rng, int(l))
    jn = dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 xlens=xlens, band=band, use_kernel=False,
                                 return_distances=True)
    from repro.kernels.dtw import score_bank_offline_kernel
    folds = [dtw.query_moments(xs[i, :xlens[i]]) for i in range(j)]
    kr = score_bank_offline_kernel(
        xs, xlens, bank.series, bank.lengths,
        np.asarray([f[0] for f in folds], np.float32),
        np.asarray([f[1] for f in folds], np.float32),
        band=band, block_k=block_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(jn[0]), np.asarray(kr[0]))
    np.testing.assert_array_equal(np.asarray(jn[1]), np.asarray(kr[1]))


def test_scorer_distances_equal_distance_bank_bitwise():
    """The scorer's endpoint distances are the SAME wavefront arithmetic
    as ``dtw_distance_bank`` — bitwise equal even on continuous data."""
    rng = np.random.default_rng(3)
    series = [rng.random(int(rng.integers(12, 40))).astype(np.float32)
              for _ in range(9)]
    bank = pack_series(series)
    x = rng.random(31).astype(np.float32)
    for band in (None, 6):
        _, dists = dtw.dtw_score_bank(x, bank.series, bank.lengths,
                                      band=band, use_kernel=False,
                                      return_distances=True)
        want = np.asarray(dtw.dtw_distance_bank(
            x, bank.series, bank.lengths, band=band))
        np.testing.assert_array_equal(np.asarray(dists), want)


def test_score_bank_smooth_data_tolerance():
    """On continuous-noise data the scorer tracks the host backtrack to
    warp-path-tie tolerance (same contract as the streaming kernel's
    host comparison)."""
    rng = np.random.default_rng(11)
    series = []
    for i in range(8):
        l = int(rng.integers(30, 70))
        t = np.linspace(0, 1, l, dtype=np.float32)
        series.append(np.clip(
            0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + i) * t)
            + 0.05 * rng.normal(size=l), 0, 1).astype(np.float32))
    bank = pack_series(series)
    x = np.clip(0.5 + 0.3 * np.sin(np.linspace(0, 9, 48)), 0, 1) \
        .astype(np.float32)
    got = np.asarray(dtw.dtw_score_bank(x, bank.series, bank.lengths,
                                        use_kernel=False))
    want = similarity_bank(x, bank, matrix_path=True)
    np.testing.assert_allclose(got, want, atol=5e-3)


def test_empty_and_degenerate_banks():
    assert dtw.dtw_score_bank_many(
        np.zeros((2, 8), np.float32), np.zeros((0, 8), np.float32),
        np.zeros((0,), np.int32)).shape == (2, 0)
    # constant query vs constant identical reference -> 1.0, constant
    # different reference -> 0.0 (RunningMoments' degenerate convention)
    x = np.full((12,), 0.25, np.float32)
    bank = pack_series([np.full((9,), 0.25, np.float32),
                        np.full((15,), 0.75, np.float32)])
    got = np.asarray(dtw.dtw_score_bank(x, bank.series, bank.lengths,
                                        use_kernel=False))
    np.testing.assert_allclose(got, [1.0, 0.0], atol=1e-6)


def test_table1_golden_relock_through_matrix_free_engine():
    """Golden re-lock: the rewired (matrix-free) batched engine
    reproduces the committed Table-1 similarity matrix within the golden
    tolerance — the offline rewiring moved no paper-facing number.  (The
    golden file itself is produced by the scalar pipeline, which is
    untouched; this pins the REWIRED path against it.)"""
    from repro import mrsim
    from repro.core import filters

    path = os.path.join(os.path.dirname(__file__), "golden",
                        "table1_similarity.json")
    with open(path) as f:
        golden = json.load(f)
    psets = mrsim.paper_param_sets()
    queries = [mrsim.simulate_cpu_series(golden["query_app"], p,
                                         run=golden["query_run"])
               for p in psets]
    band = golden["band"]
    for app, want in golden["similarity"].items():
        refs = pack_series([np.asarray(filters.preprocess(np.asarray(
            mrsim.simulate_cpu_series(app, p), np.float32)))
            for p in psets])
        got = np.stack([similarity_bank(
            np.asarray(filters.preprocess(np.asarray(q, np.float32))),
            refs, band=band) for q in queries], axis=1)   # [ref i, query j]
        np.testing.assert_allclose(got, np.asarray(want), atol=2e-3)


def test_score_plan_is_memoized_per_bank():
    """The finish-path double-pack fix: one SeriesBank builds its tiled
    device upload exactly once (whatever mix of similarity_bank /
    finish / match calls reuse it), and a DB-cached bank therefore
    shares one plan across verdicts.  A replace()d bank starts fresh."""
    import dataclasses

    from repro.core.database import ReferenceDB

    rng = np.random.default_rng(5)
    db = ReferenceDB()
    for i in range(5):
        db.add(f"w{i}", {"i": i}, rng.random(20 + i).astype(np.float32))
    bank = db.bank()
    plan = bank.score_plan()
    assert bank.score_plan() is plan                  # memoized
    assert db.bank().score_plan() is plan             # DB bank cache too
    assert plan.k == len(bank)
    # scoring through the plan == scoring without it
    x = rng.random(17).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(dtw.dtw_score_bank(x, bank.series, bank.lengths,
                                      plan=plan, use_kernel=False)),
        np.asarray(dtw.dtw_score_bank(x, bank.series, bank.lengths,
                                      use_kernel=False)))
    fresh = dataclasses.replace(bank)
    assert fresh._score_plan is None                  # no stale carry


def test_preprocessed_bank_is_memoized():
    """preprocess=True scoring must not rebuild/re-upload the bank per
    call: the filtered pack (and therefore its score plan) is memoized
    on the source SeriesBank."""
    rng = np.random.default_rng(9)
    bank = pack_series([rng.random(int(rng.integers(16, 40)))
                        .astype(np.float32) for _ in range(5)])
    pb = bank.preprocessed()
    assert bank.preprocessed() is pb
    plan = pb.score_plan()
    x = rng.random(20).astype(np.float32)
    a = similarity_bank(x, bank, preprocess=True, band=4)
    b = similarity_bank(x, bank, preprocess=True, band=4)
    np.testing.assert_array_equal(a, b)
    assert bank.preprocessed().score_plan() is plan   # no re-upload


def test_final_scores_banded_misprediction_without_rows():
    """collect_rows=False + banded stream whose query_len prediction was
    wrong: final_scores self-corrects via the matrix-free solve (corridor
    re-derived from the true length == offline similarity_bank) instead
    of crashing on the missing rows."""
    from repro.core import OnlineMatcher

    rng = np.random.default_rng(13)
    bank = pack_series([np.clip(rng.normal(0.5, 0.2, 40), 0, 1)
                        .astype(np.float32) for _ in range(4)])
    q = np.clip(rng.normal(0.5, 0.2, 30), 0, 1).astype(np.float32)
    om = OnlineMatcher(bank, band=6, query_len=50, collect_rows=False)
    om.extend(q)                          # stream ends early: n=30 != 50
    got = om.final_scores()
    want = similarity_bank(q, bank, band=6)
    np.testing.assert_array_equal(got, want)


def test_distance_only_prefilter_mode_is_guarded():
    """Satellite guard: a distance-only service (score_in_flight=False)
    with prefilter_top set would prune on the wavelet ranking ALONE —
    no in-flight DTW veto — which evicts warp-matching references.  The
    construction must refuse."""
    from repro.serve.tuning import TuningService

    rng = np.random.default_rng(0)
    bank = pack_series([rng.random(32).astype(np.float32)
                        for _ in range(4)])
    with pytest.raises(ValueError, match="score_in_flight"):
        TuningService(bank, score_in_flight=False, prefilter_top=2)


def test_pure_wavelet_pruning_would_evict_warp_match():
    """WHY the guard exists: on the paper's exim trace the warp-matching
    wordcount references rank so poorly in the rigid wavelet domain that
    a pure-wavelet top-P prune (no DTW veto) evicts every one of them —
    the reference family the full pipeline ultimately matches."""
    from repro import mrsim
    from repro.core import filters, wavelet
    from repro.core.database import SeriesBank
    from repro.serve.tuning import TuningService

    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in sorted(mrsim.APPS):
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    packed = pack_series(series, labels=labels)
    bank = SeriesBank(np.asarray(filters.preprocess_bank(
        packed.series, packed.lengths)), packed.lengths, packed.labels)

    svc = TuningService(bank, band=16, denoise=True, prefilter_top=2,
                        prefilter_min_fraction=0.1)
    p = psets[0]
    q = mrsim.simulate_cpu_series("exim", p, run=1, dt=0.25)
    svc.submit("exim", expected_len=len(q))
    half = len(q) // 2
    for lo in range(0, half, 8):
        svc.push("exim", q[lo: lo + 8])
        svc.tick()
    job = svc._jobs["exim"]
    # the vetoed (real) prune keeps at least one wordcount reference live
    assert job.allowed is not None
    labels_arr = np.asarray(bank.labels)
    assert job.allowed[labels_arr == "wordcount"].any()
    # ...but the PURE-WAVELET top-P ranking alone (what a distance-only
    # service would have pruned on) evicts every wordcount reference:
    wkeep = TuningService._top_p_with_margin(
        wavelet.coeff_similarity_bank(
            job.haar.compressed(svc.prefilter_coeffs),
            svc._ref_prefix_coeffs(job.haar.size, job.n)),
        np.ones(len(bank), bool), svc.prefilter_top,
        svc.prefilter_margin)
    assert not wkeep[labels_arr == "wordcount"].any(), \
        "wavelet ranking unexpectedly kept wordcount - guard test stale"
    # verdict sanity: the full pipeline does match wordcount
    for lo in range(half, len(q), 8):
        svc.push("exim", q[lo: lo + 8])
        svc.tick()
    assert svc.finish("exim").matched == "wordcount"
