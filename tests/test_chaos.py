"""Chaos injection: seeded faults must move counters, never decisions.

The fault plan injects dispatch failures, sample corruption and clock
skew into a serving run; the invariants pinned here are the robustness
contract of PR "crash-safe serving":

* injected transient dispatch failures are retried and the run's
  decisions are BITWISE equal to the fault-free run (with retry
  counters surfaced);
* failure bursts that exhaust the retry budget fall back to the jnp
  wavefront twin — ``degraded`` flagged, decisions still bitwise equal;
* with no fallback available the dispatch raises ``DispatchFailure``;
* corrupted (NaN/Inf) samples quarantine the poisoned JOB while every
  survivor's scores and decisions stay bitwise identical;
* skewed clocks never mass-evict healthy jobs (heartbeat monotonicity);
* the plan itself is deterministic per seed, with independent streams
  per fault class.

The fast CI job runs this module over a fixed seed matrix via the
``CHAOS_SEEDS`` env var (comma-separated ints).
"""
import os

import numpy as np
import pytest

from repro.core.database import pack_series
from repro.runtime.chaos import FaultPlan, InjectedDispatchError
from repro.runtime.retry import DispatchFailure, RetryPolicy, call_with_retry
from repro.serve.ingest import PoisonedSampleError
from repro.serve.tuning import TuningService

SEEDS = [int(s) for s in os.environ.get("CHAOS_SEEDS", "5,17").split(",")]


def _bank(k=4, seed=2):
    rng = np.random.default_rng(seed)
    series = [np.abs(np.cumsum(rng.normal(size=100)))
              .astype(np.float32) for _ in range(k)]
    return pack_series(series, labels=[f"w{i}" for i in range(k)])


def _drive(svc, poison=None):
    """Fixed schedule; poisons one chunk of j1 when ``poison`` is set.
    Returns the full decision trajectory with float-hex scores."""
    outs = []
    r = np.random.default_rng(3)
    streams = {f"j{i}": np.abs(np.cumsum(r.normal(size=48)))
               .astype(np.float32) for i in range(3)}
    for j in streams:
        svc.submit(j, 48)
    for t in range(6):
        for j, s in streams.items():
            if j in svc.quarantined:
                continue
            x = s[t * 8: (t + 1) * 8]
            if poison == (j, t):
                x = x.copy()
                x[3] = np.nan
                with pytest.raises(PoisonedSampleError):
                    svc.push(j, x)
                continue
            svc.push(j, x)
        outs.append(_keyd(svc.tick()))
    outs.append(_keyd(svc.finish_many(
        [j for j in streams if j not in svc.quarantined])))
    return outs


def _keyd(decisions):
    return sorted((j, None if d is None else
                   (d.matched, float(d.corr).hex(), d.final,
                    tuple((k, float(v).hex())
                          for k, v in sorted(d.scores.items()))))
                  for j, d in decisions.items())


def _policy(**kw):
    kw.setdefault("base_delay", 0.0)
    kw.setdefault("sleep", lambda s: None)   # no real sleeping in tests
    return RetryPolicy(**kw)


# ---------------------------------------------------------------------------
# retry / fallback wrapper
# ---------------------------------------------------------------------------

def test_retry_succeeds_after_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise InjectedDispatchError("boom")
        return 42

    out, report = call_with_retry(flaky, policy=_policy(max_retries=3),
                                  transient=(InjectedDispatchError,))
    assert out == 42
    assert report == {"retries": 2, "degraded": False}


def test_retry_exhaustion_uses_fallback_once():
    def always_fails():
        raise InjectedDispatchError("boom")

    out, report = call_with_retry(always_fails,
                                  policy=_policy(max_retries=2),
                                  transient=(InjectedDispatchError,),
                                  fallback=lambda: "degraded-result")
    assert out == "degraded-result"
    assert report == {"retries": 3, "degraded": True}


def test_retry_exhaustion_without_fallback_raises():
    def always_fails():
        raise InjectedDispatchError("boom")

    with pytest.raises(DispatchFailure):
        call_with_retry(always_fails, policy=_policy(max_retries=1),
                        transient=(InjectedDispatchError,))


def test_non_transient_errors_propagate_immediately():
    calls = {"n": 0}

    def typo():
        calls["n"] += 1
        raise TypeError("not a device fault")

    with pytest.raises(TypeError):
        call_with_retry(typo, policy=_policy(max_retries=5),
                        transient=(InjectedDispatchError,))
    assert calls["n"] == 1


def test_backoff_delays_grow_and_cap():
    p = RetryPolicy(max_retries=8, base_delay=0.1, max_delay=1.0,
                    jitter=0.0, sleep=lambda s: None)
    delays = [p.delay(a) for a in range(8)]
    assert delays[0] == pytest.approx(0.1)
    assert delays == sorted(delays)
    assert max(delays) <= 1.0


# ---------------------------------------------------------------------------
# fault plan determinism
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_per_seed():
    a = FaultPlan(seed=9, dispatch_fail_rate=0.3)
    b = FaultPlan(seed=9, dispatch_fail_rate=0.3)
    sched_a, sched_b = [], []
    for plan, sched in ((a, sched_a), (b, sched_b)):
        for _ in range(50):
            try:
                plan.on_dispatch()
                sched.append(0)
            except InjectedDispatchError:
                sched.append(1)
    assert sched_a == sched_b
    assert a.injected_failures == b.injected_failures > 0


def test_fault_plan_streams_are_independent():
    """Enabling corruption must not shift the dispatch-failure
    schedule: each fault class draws from its own seeded stream."""
    def dispatch_schedule(plan, n=40):
        out = []
        for _ in range(n):
            try:
                plan.on_dispatch()
                out.append(0)
            except InjectedDispatchError:
                out.append(1)
        return out

    a = FaultPlan(seed=9, dispatch_fail_rate=0.3)
    b = FaultPlan(seed=9, dispatch_fail_rate=0.3, corrupt_rate=1.0,
                  skew_rate=1.0)
    rng_noise = np.random.default_rng(0)
    sched_b = []
    for _ in range(40):
        b.corrupt(rng_noise.normal(size=4).astype(np.float32))
        b.skew(1.0)
        try:
            b.on_dispatch()
            sched_b.append(0)
        except InjectedDispatchError:
            sched_b.append(1)
    assert dispatch_schedule(a) == sched_b


def test_corrupt_injects_nonfinite_and_counts():
    plan = FaultPlan(seed=4, corrupt_rate=1.0)
    x = np.zeros(16, np.float32)
    y = plan.corrupt(x)
    assert np.all(np.isfinite(x)), "corrupt must not mutate its input"
    assert not np.all(np.isfinite(y))
    assert plan.corrupted_pushes == 1


def test_should_kill_fires_on_schedule():
    plan = FaultPlan(seed=0, kill_every=5)
    kills = [i for i in range(20) if plan.should_kill(i)]
    assert kills == [4, 9, 14, 19]
    assert not any(FaultPlan(seed=0).should_kill(i) for i in range(20))


# ---------------------------------------------------------------------------
# service-level invariants, over the CI seed matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_injected_failures_never_change_decisions(seed):
    bank = _bank()
    gold = _drive(TuningService(bank, slots=4))

    chaos = FaultPlan(seed=seed, dispatch_fail_rate=0.5)
    svc = TuningService(bank, slots=4, chaos=chaos,
                        retry_policy=_policy(max_retries=3))
    assert _drive(svc) == gold, "retried faults changed decisions"
    assert svc.retry_count == chaos.injected_failures > 0
    assert svc.degraded_dispatch_count == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_burst_exhausts_retries_falls_back_degraded(seed):
    bank = _bank()
    gold = _drive(TuningService(bank, slots=4))

    chaos = FaultPlan(seed=seed, dispatch_fail_rate=0.9,
                      dispatch_fail_burst=10)
    svc = TuningService(bank, slots=4, chaos=chaos,
                        retry_policy=_policy(max_retries=2))
    assert _drive(svc) == gold, "degraded fallback changed decisions"
    assert svc.degraded_dispatch_count > 0
    assert svc.last_tick_degraded in (True, False)  # surfaced per tick
    assert svc.retry_count >= 3 * svc.degraded_dispatch_count


@pytest.mark.parametrize("seed", SEEDS)
def test_quarantine_leaves_survivors_bit_identical(seed):
    bank = _bank()
    poison = ("j1", 2 + seed % 3)
    gold = _drive(TuningService(bank, slots=4), poison=poison)
    run2 = _drive(TuningService(bank, slots=4), poison=poison)
    assert gold == run2, "poisoned run must itself be deterministic"

    clean = _drive(TuningService(bank, slots=4))
    surv_clean = [[e for e in tick if e[0] != "j1"] for tick in clean]
    surv_poison = [[e for e in tick if e[0] != "j1"] for tick in gold]
    assert surv_clean == surv_poison, \
        "quarantining j1 perturbed the survivors"

    svc = TuningService(bank, slots=4)
    _drive(svc, poison=poison)
    assert svc.quarantined == {"j1": "non-finite sample (NaN/Inf)"}
    assert svc.quarantined_count == 1


def test_chaos_corruption_quarantines_via_push():
    """End-to-end: FaultPlan.corrupt wired through TuningService.push
    poisons a stream, the service quarantines instead of crashing."""
    bank = _bank()
    chaos = FaultPlan(seed=1, corrupt_rate=1.0)
    svc = TuningService(bank, slots=4, chaos=chaos)
    svc.submit("j0", 48)
    with pytest.raises(PoisonedSampleError):
        svc.push("j0", np.ones(8, np.float32))
    assert svc.quarantined == {"j0": "non-finite sample (NaN/Inf)"}
    # later pushes silently dropped
    svc.push("j0", np.ones(8, np.float32))
    assert svc.quarantine_dropped == 1


def test_backwards_clock_skew_never_mass_evicts():
    """A sweep clock that jumps BACKWARDS (NTP step, VM migration, the
    chaos plan's skew injection) must decide exactly what the honest
    sweep decided — the heartbeat high-water guard clamps it.  (A
    forward jump legitimately times jobs out, so only the backwards
    direction carries an invariant.)"""
    bank = _bank()
    svc = TuningService(bank, slots=4, heartbeat_timeout=10.0)
    svc.submit("j0", 48)
    svc.submit("j1", 48)
    rng = np.random.default_rng(0)
    for step in range(1, 21):
        t = float(step)
        for j in ("j0", "j1"):
            svc.push(j, np.abs(rng.normal(size=4)).astype(np.float32),
                     now=t)
        assert svc.sweep_stalled(t) == {}
        # chaos: the very next sweep arrives on a clock 100s in the past
        assert svc.sweep_stalled(t - 100.0) == {}, \
            "backwards sweep clock evicted heartbeating jobs"
    assert svc.n_active == 2


def test_backwards_beat_clock_cannot_rewind_liveness():
    """A push stamped with a backwards clock proves liveness; it must
    not rewind ``last_time`` so a later honest sweep times the job
    out on the strength of the skewed stamp."""
    bank = _bank()
    svc = TuningService(bank, slots=4, heartbeat_timeout=10.0)
    svc.submit("j0", 48)
    svc.push("j0", np.ones(4, np.float32), now=100.0)
    # skewed agent clock: stamps an ancient time on a fresh push
    svc.push("j0", np.ones(4, np.float32), now=3.0)
    assert svc.sweep_stalled(105.0) == {}, \
        "backwards beat rewound the heartbeat and got the job evicted"
    assert svc.n_active == 1
