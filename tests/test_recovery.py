"""Crash-safe serving: snapshot/restore + WAL replay == never crashed.

The recovery twin of the churn-invariance suite: a service snapshotted
and rehydrated at ANY point of a command schedule — or killed and
rebuilt from snapshot + journal tail — must continue the schedule with
decisions, scores and counters bit-identical to a service that ran it
uninterrupted.  Covers the exact and probabilistic decision rules, the
wavelet prefilter, denoised ingest, mid-repack snapshots (pending
fresh-slot resets), zero-job snapshots, torn journal tails and torn
snapshot steps, plus hypothesis-driven random interleavings of
push/tick/snapshot/crash/restore/evict/finish.
"""
import json
import os
import warnings

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.database import pack_series
from repro.runtime.chaos import truncate_file
from repro.serve.ingest import TraceLog
from repro.serve.recovery import (RecoverableTuningService, restore_service,
                                  snapshot_service)
from repro.serve.tuning import TuningService


def _bank(k=5, seed=0, base=90):
    rng = np.random.default_rng(seed)
    series = [np.abs(np.cumsum(rng.normal(size=base + 7 * i)))
              .astype(np.float32) for i in range(k)]
    return pack_series(series, labels=[f"w{i}" for i in range(k)])


def _streams(n=3, seed=42, length=80):
    r = np.random.default_rng(seed)
    return {f"j{i}": np.abs(np.cumsum(r.normal(size=length)))
            .astype(np.float32) for i in range(n)}


def _schedule(streams, chunks=10, chunk=8, variance=False, evict=None,
              finish_later=None):
    """Deterministic command list: submits, interleaved pushes + ticks,
    optional evict / deferred finish, then a batched finish."""
    cmds = [("submit", jid, chunks * chunk) for jid in streams]
    vr = np.random.default_rng(99)
    for t in range(chunks):
        for jid, s in streams.items():
            x = s[t * chunk: (t + 1) * chunk]
            v = (0.01 * np.abs(vr.normal(size=x.shape[0]))
                 .astype(np.float32)) if variance else None
            cmds.append(("push", jid, x, v))
        cmds.append(("tick",))
        if evict is not None and t == chunks // 2:
            cmds.append(("evict", evict))
        if finish_later is not None and t == chunks - 2:
            cmds.append(("finish_later", finish_later))
    live = [j for j in streams if j not in (evict, finish_later)]
    cmds.append(("finish", live))
    if finish_later is not None:
        cmds.append(("drain",))
    return cmds


def _run(svc, cmds, lo=0, hi=None):
    """Execute cmds[lo:hi]; returns the emitted decision trajectory with
    full-precision scores (float hex) so equality means bitwise."""
    outs = []
    hi = len(cmds) if hi is None else min(hi, len(cmds))
    gone = set()
    for i in range(lo, hi):
        c = cmds[i]
        if c[0] == "submit":
            svc.submit(c[1], c[2])
        elif c[0] == "push":
            if c[1] in gone:
                continue
            svc.push(c[1], c[2], variance=c[3], now=float(i))
        elif c[0] == "tick":
            outs.append((i, _keyd(svc.tick(now=float(i)))))
        elif c[0] == "evict":
            svc.evict(c[1])
            gone.add(c[1])
        elif c[0] == "finish_later":
            svc.finish_later(c[1])
            gone.add(c[1])
        elif c[0] == "finish":
            outs.append((i, _keyd(svc.finish_many(c[1]))))
        elif c[0] == "drain":
            outs.append((i, _keyd(svc.drain_finishes())))
    return outs


def _keyd(decisions):
    out = []
    for j, d in sorted(decisions.items()):
        if d is None:
            out.append((j, None))
        else:
            out.append((j, d.matched, float(d.corr).hex(), d.final,
                        d.fraction_seen,
                        None if d.probability is None
                        else float(d.probability).hex(),
                        tuple((k, float(v).hex())
                              for k, v in sorted(d.scores.items()))))
    return out


# ---------------------------------------------------------------------------
# snapshot/restore: bitwise continuation at every kind of cut point
# ---------------------------------------------------------------------------

def test_snapshot_restore_bitwise_exact_mode():
    bank = _bank()
    streams = _streams()
    cmds = _schedule(streams)
    gold = _run(TuningService(bank, slots=8), cmds)
    for cut in (0, 3, 9, 17, len(cmds) - 2):
        svc = TuningService(bank, slots=8)
        _run(svc, cmds, 0, cut)
        twin = restore_service(snapshot_service(svc), bank)
        a = _run(svc, cmds, cut)
        b = _run(twin, cmds, cut)
        assert a == b, f"restored service diverged (cut={cut})"
        assert a == gold[-len(a):], f"continuation != golden (cut={cut})"
        assert twin.ticks == svc.ticks
        assert twin.dispatch_count == svc.dispatch_count


def test_snapshot_restore_prob_prefilter_denoise():
    """All the stateful features at once: probabilistic rule (6-channel
    moments + vstats + variance queues), wavelet prefilter (haar state,
    allowed masks, packed-K state), causal denoise filter state, queues,
    heartbeats, eviction and the deferred-finish queue."""
    bank = _bank(k=6, seed=1)
    streams = _streams(n=4, seed=7, length=64)
    kw = dict(slots=8, min_probability=0.5, threshold=0.5, denoise=True,
              prefilter_top=3, prefilter_min_fraction=0.05,
              heartbeat_timeout=50.0, queue_limit=512,
              queue_policy="drop_oldest")
    cmds = _schedule(streams, chunks=8, variance=True, evict="j0",
                     finish_later="j1")
    gold = _run(TuningService(bank, **kw), cmds)
    for cut in (2, 11, 23, len(cmds) - 3):
        svc = TuningService(bank, **kw)
        _run(svc, cmds, 0, cut)
        twin = restore_service(snapshot_service(svc), bank)
        a = _run(svc, cmds, cut)
        b = _run(twin, cmds, cut)
        assert a == b, f"restored service diverged (cut={cut})"
        assert a == gold[-len(a):], f"continuation != golden (cut={cut})"


def test_snapshot_restore_approx_prob_mode():
    """Approx probability mode rides snapshots: the 4-channel moment
    slab and the ``prob_mode`` flag are persisted, the restored twin
    rebuilds an approx-mode service (same channel count, same config)
    and continues the schedule bitwise."""
    bank = _bank(k=6, seed=1)
    streams = _streams(n=4, seed=7, length=64)
    kw = dict(slots=8, min_probability=0.5, prob_mode="approx",
              threshold=0.5, denoise=True, queue_limit=512)
    cmds = _schedule(streams, chunks=8, variance=True, evict="j0",
                     finish_later="j1")
    gold = _run(TuningService(bank, **kw), cmds)
    for cut in (2, 11, 23, len(cmds) - 3):
        svc = TuningService(bank, **kw)
        _run(svc, cmds, 0, cut)
        twin = restore_service(snapshot_service(svc), bank)
        assert twin.prob_mode == "approx"
        assert twin._config["prob_mode"] == "approx"
        assert twin._moms.shape[0] == 4
        a = _run(svc, cmds, cut)
        b = _run(twin, cmds, cut)
        assert a == b, f"restored service diverged (cut={cut})"
        assert a == gold[-len(a):], f"continuation != golden (cut={cut})"


def test_snapshot_mid_repack_dirty_slots():
    """Snapshot taken AFTER a submit but BEFORE its lazy slot reset ran
    (the `_dirty` list is non-empty) must carry the pending reset."""
    bank = _bank()
    streams = _streams(n=2)
    svc = TuningService(bank, slots=8)
    svc.submit("j0", 80)
    svc.push("j0", streams["j0"][:8])
    svc.tick()
    svc.submit("j1", 80)            # slot dirty, no tick yet
    assert svc._dirty, "test setup: expected a pending lazy reset"
    twin = restore_service(snapshot_service(svc), bank)
    assert twin._dirty == svc._dirty
    for s in (svc, twin):
        s.push("j0", streams["j0"][8:16])
        s.push("j1", streams["j1"][:8])
    a, b = svc.tick(), twin.tick()
    assert _keyd(a) == _keyd(b)
    np.testing.assert_array_equal(svc._jobs["j1"].last_sims,
                                  twin._jobs["j1"].last_sims)


def test_restore_rejects_wrong_bank():
    svc = TuningService(_bank(), slots=4)
    tree = snapshot_service(svc)
    with pytest.raises(ValueError, match="different reference bank"):
        restore_service(tree, _bank(seed=123))


# ---------------------------------------------------------------------------
# the WAL wrapper: checkpoint + journal tail replay
# ---------------------------------------------------------------------------

def test_recover_snapshot_plus_journal_tail(tmp_path):
    bank = _bank()
    cmds = _schedule(_streams())
    gold = _run(TuningService(bank, slots=8), cmds)

    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8)
    _run(r1, cmds, 0, 9)
    r1.checkpoint()
    _run(r1, cmds, 9, 21)           # journaled past the snapshot
    del r1                          # "crash": nothing carried over

    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path))
    assert r2.replayed > 0, "tail records should have replayed"
    a = _run(r2, cmds, 21)
    assert a == gold[-len(a):]
    assert r2.ticks == 10


def test_recover_journal_only_cold_start(tmp_path):
    """No checkpoint was ever taken: the whole journal replays against a
    fresh service built from the recover() kwargs."""
    bank = _bank()
    cmds = _schedule(_streams())
    gold = _run(TuningService(bank, slots=8), cmds)
    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8)
    _run(r1, cmds, 0, 15)
    del r1
    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path),
                                          slots=8)
    assert r2.replayed == 15
    a = _run(r2, cmds, 15)
    assert a == gold[-len(a):]


def test_checkpoint_prunes_journal(tmp_path):
    bank = _bank()
    cmds = _schedule(_streams())
    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8,
                                  keep=1)
    _run(r1, cmds, 0, 20)
    n_before = len(r1.wal.segments())
    r1.checkpoint()
    assert len(r1.wal.segments()) < n_before or n_before == 0
    # pruning must not break recovery
    del r1
    gold = _run(TuningService(bank, slots=8), cmds)
    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path))
    a = _run(r2, cmds, 20)
    assert a == gold[-len(a):]


def test_recover_replays_quarantine_not_poison(tmp_path):
    """A poisoned push quarantines its job and is journaled as an
    explicit quarantine EVENT (the poison never enters the WAL); replay
    re-evicts and survivors continue bit-identically."""
    from repro.serve.ingest import PoisonedSampleError

    bank = _bank()
    streams = _streams()
    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8)
    for j in streams:
        r1.submit(j, 80)
    for t in range(3):
        for j, s in streams.items():
            r1.push(j, s[t * 8: (t + 1) * 8], now=float(t))
        r1.tick(now=float(t))
    bad = streams["j1"][24:32].copy()
    bad[2] = np.inf
    with pytest.raises(PoisonedSampleError):
        r1.push("j1", bad, now=3.0)
    assert r1.quarantined == {"j1": "non-finite sample (NaN/Inf)"}
    survivors_before = {j: svc_job.last_sims.copy()
                        for j, svc_job in r1.svc._jobs.items()}
    del r1

    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path))
    assert r2.quarantined == {"j1": "non-finite sample (NaN/Inf)"}
    assert "j1" not in r2.svc._jobs
    for j, sims in survivors_before.items():
        if j == "j1":
            continue
        np.testing.assert_array_equal(r2.svc._jobs[j].last_sims, sims)
    # a sick agent still pushing is dropped, not resurrected
    r2.push("j1", streams["j1"][24:32], now=4.0)
    assert r2.quarantine_dropped == 1 and "j1" not in r2.svc._jobs


def test_quarantine_sticks_across_checkpoint_and_recover(tmp_path):
    """Quarantine must survive the SNAPSHOT path too, not just WAL
    replay: a job quarantined before ``checkpoint()`` stays quarantined
    after ``recover()``, its sick agent's post-recovery pushes are
    swallowed and counted (``quarantine_dropped``, including swallows
    journaled before the crash), and the survivors finish with
    bitwise-identical verdicts to an uninterrupted run."""
    from repro.serve.ingest import PoisonedSampleError

    bank = _bank()
    streams = _streams()

    def drive(svc, poisoned):
        for j in streams:
            svc.submit(j, 80)
        for t in range(3):
            for j, s in streams.items():
                if poisoned and j == "j1" and t >= 1:
                    continue
                svc.push(j, s[t * 8: (t + 1) * 8], now=float(t))
            svc.tick(now=float(t))

    gold = TuningService(bank, slots=8)
    drive(gold, poisoned=True)
    gold_fin = _run(gold, [("finish", ["j0", "j2"])])

    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8)
    for j in streams:
        r1.submit(j, 80)
    for j, s in streams.items():
        r1.push(j, s[:8], now=0.0)
    r1.tick(now=0.0)
    bad = streams["j1"][8:16].copy()
    bad[4] = np.nan
    with pytest.raises(PoisonedSampleError):
        r1.push("j1", bad, now=1.0)
    r1.push("j1", streams["j1"][8:16], now=1.0)   # swallowed pre-crash
    assert r1.quarantine_dropped == 1
    for t in range(1, 3):
        for j, s in streams.items():
            if j == "j1":
                continue
            r1.push(j, s[t * 8: (t + 1) * 8], now=float(t))
        r1.tick(now=float(t))
    r1.checkpoint()
    del r1

    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path))
    assert r2.replayed == 0                       # snapshot was current
    assert r2.quarantined == {"j1": "non-finite sample (NaN/Inf)"}
    assert r2.quarantine_dropped == 1
    assert "j1" not in r2.svc._jobs
    # still-sick agent keeps pushing: swallowed + counted, never revived
    r2.push("j1", streams["j1"][16:24], now=3.0)
    assert r2.quarantine_dropped == 2 and "j1" not in r2.svc._jobs
    assert _run(r2, [("finish", ["j0", "j2"])]) == gold_fin


# ---------------------------------------------------------------------------
# torn files: truncated journal tails and incomplete snapshot steps
# ---------------------------------------------------------------------------

def test_tracelog_truncated_tail_is_skipped(tmp_path):
    """Chop bytes off a real flushed segment: the reopened log warns,
    counts it in ``corrupt_segments``, and replays everything before."""
    log = TraceLog(str(tmp_path), max_segment_bytes=1 << 14)
    rng = np.random.default_rng(0)
    for i in range(4):
        log.append("job0", rng.normal(size=32).astype(np.float32))
        log.flush()                 # one segment per record
    segs = log.segments()
    assert len(segs) == 4
    victim = os.path.join(str(tmp_path), segs[-1])
    truncate_file(victim, drop_bytes=max(1, os.path.getsize(victim) // 2))

    reopened = TraceLog(str(tmp_path), max_segment_bytes=1 << 14)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        recs = reopened.records()
    assert reopened.corrupt_segments == 1
    assert any("truncated or corrupt" in str(x.message) for x in w)
    assert [seq for seq, _, _ in recs] == [0, 1, 2]  # tail record lost
    assert reopened.read_job("job0").shape[0] == 3 * 32


def test_tracelog_reopen_resumes_sequence(tmp_path):
    log = TraceLog(str(tmp_path))
    log.append("a", np.ones(4, np.float32))
    log.append_event("tick", {"now": 1.0})
    log.flush()
    assert log.next_seq == 2
    reopened = TraceLog(str(tmp_path))
    assert reopened.next_seq == 2
    assert reopened.segments() == log.segments()
    seq = reopened.append_event("tick", {"now": 2.0})
    assert seq == 2                 # no clobbering of the old journal


def test_recover_with_torn_snapshot_falls_back(tmp_path):
    """A crash mid-save leaves a manifest-less step dir; recovery must
    restore the newest COMPLETE snapshot and replay a longer tail."""
    bank = _bank()
    cmds = _schedule(_streams())
    gold = _run(TuningService(bank, slots=8), cmds)
    r1 = RecoverableTuningService(bank, root=str(tmp_path), slots=8)
    _run(r1, cmds, 0, 9)
    r1.checkpoint(prune=False)
    _run(r1, cmds, 9, 15)
    # fake a crash mid-checkpoint: a step dir with arrays but no manifest
    torn = os.path.join(str(tmp_path), "ckpt", "step_000099")
    os.makedirs(torn)
    np.savez(os.path.join(torn, "arrays.npz"), junk=np.zeros(3))
    del r1
    r2 = RecoverableTuningService.recover(bank, root=str(tmp_path))
    a = _run(r2, cmds, 15)
    assert a == gold[-len(a):]


# ---------------------------------------------------------------------------
# hypothesis: random interleavings of push/tick/snapshot/crash/restore
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_random_interleaving_recovery_invariance(seed):
    """Random command tapes (uneven pushes, empty ticks, evictions,
    deferred finishes, zero-job stretches) crashed at a random point and
    recovered from snapshot+journal continue exactly like the
    uninterrupted run."""
    rng = np.random.default_rng(seed)
    bank = _bank(k=4, seed=3)
    n_jobs = int(rng.integers(1, 5))
    streams = _streams(n=n_jobs, seed=int(rng.integers(1 << 30)),
                       length=48)
    # random tape
    cmds = [("submit", j, 48) for j in streams]
    pos = {j: 0 for j in streams}
    for t in range(int(rng.integers(4, 12))):
        for j in streams:
            step = int(rng.integers(0, 9))
            if step and pos[j] < 48:
                cmds.append(("push", j, streams[j][pos[j]:pos[j] + step],
                             None))
                pos[j] = min(48, pos[j] + step)
        cmds.append(("tick",))
    if n_jobs > 1 and rng.random() < 0.5:
        cmds.append(("evict", f"j{n_jobs - 1}"))
        live = [j for j in streams if j != f"j{n_jobs - 1}"]
    else:
        live = list(streams)
    cmds.append(("finish", live))

    gold = _run(TuningService(bank, slots=8), cmds)

    import tempfile
    with tempfile.TemporaryDirectory() as root:
        r1 = RecoverableTuningService(bank, root=root, slots=8)
        cut = int(rng.integers(0, len(cmds)))
        ckpt_at = int(rng.integers(0, cut + 1))
        _run(r1, cmds, 0, ckpt_at)
        r1.checkpoint()
        _run(r1, cmds, ckpt_at, cut)
        del r1
        r2 = RecoverableTuningService.recover(bank, root=root, slots=8)
        a = _run(r2, cmds, cut)
        tail = gold[len(gold) - len(a):]
        assert a == tail, f"seed={seed} cut={cut} ckpt={ckpt_at}"
