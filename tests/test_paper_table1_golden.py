"""Golden-file lock on the paper Table-1 similarity matrix.

``benchmarks/bench_paper_table1.py`` asserts only the *structure* of the
reproduction (WordCount diagonal >= 0.9, WordCount > TeraSort); this test
pins the actual numbers, so a change anywhere in the matching stack
(filters, DTW, warping, correlation, simulator) that silently shifts the
paper-facing values fails loudly instead of drifting.

Regenerate deliberately after an intentional change with::

    PYTHONPATH=src python tests/test_paper_table1_golden.py

and review the diff of ``tests/golden/table1_similarity.json`` in the PR.
"""
import json
import os

import numpy as np

from repro import mrsim
from repro.core import similarity

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "table1_similarity.json")
#: The matching math is deterministic on a given jax/numpy version; the
#: tolerance only absorbs cross-platform libm/BLAS rounding.
TOL = 2e-3


def _compute(golden):
    psets = mrsim.paper_param_sets()
    assert [p.as_dict() for p in psets] == golden["param_sets"], \
        "paper_param_sets changed — Table 1 is no longer the paper's"
    queries = [mrsim.simulate_cpu_series(golden["query_app"], p,
                                         run=golden["query_run"])
               for p in psets]
    table = {}
    for app in golden["similarity"]:
        refs = [mrsim.simulate_cpu_series(app, p) for p in psets]
        table[app] = [[float(similarity(queries[j], refs[i],
                                        preprocess=True,
                                        band=golden["band"]))
                       for j in range(len(psets))]
                      for i in range(len(psets))]
    return table


def test_table1_similarity_matrix_matches_golden():
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    got = _compute(golden)
    for app, want in golden["similarity"].items():
        np.testing.assert_allclose(
            np.asarray(got[app]), np.asarray(want), atol=TOL,
            err_msg=f"Table-1 {app} matrix drifted from tests/golden/"
                    f"table1_similarity.json (regenerate deliberately if "
                    f"this change is intentional)")


def test_golden_matrix_preserves_paper_structure():
    """The stored numbers themselves must show the paper's finding: the
    Exim x WordCount diagonal clears the 0.9 threshold and dominates
    TeraSort — guards against regenerating a golden file that quietly
    lost the reproduction."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    wc = np.asarray(golden["similarity"]["wordcount"])
    ts = np.asarray(golden["similarity"]["terasort"])
    assert (np.diag(wc) >= 0.9).all()
    assert np.diag(wc).mean() > np.diag(ts).mean()


if __name__ == "__main__":          # regenerate the golden file
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    golden["similarity"] = {
        app: [[round(v, 6) for v in row] for row in M]
        for app, M in _compute(golden).items()}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"regenerated {GOLDEN_PATH}")
