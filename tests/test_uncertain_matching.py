"""Golden end-to-end tests for the uncertain-series (variance-carrying)
matching mode — the probabilistic verdict path of arXiv:1112.5505.

Three pinned behaviors:

* zero variance REDUCES bitwise to today's exact service: same scores,
  same decisions on the same ticks, probabilities exactly {0, 1} with
  ``prob == 1 <=> score >= threshold`` (golden mrsim traces);
* on heteroscedastic traces the probabilistic rule DOMINATES the point
  rule: no more wrong early decisions, and wherever the point rule
  decided correctly the probabilistic rule decided too, no later;
* degenerate inputs (constant trace) produce a 0.0 score, never NaN,
  and the service abstains — the PR-7 `_corr_from_moments` guard.
"""

import numpy as np
import pytest

from repro.core.database import pack_series
from repro.core.filters import preprocess
from repro.mrsim import (APPS, paper_param_sets, simulate_cpu_series,
                         simulate_cpu_series_uncertain)
from repro.serve.tuning import TuningService

PS = paper_param_sets()[0]


@pytest.fixture(scope="module")
def bank():
    return pack_series(
        [np.asarray(preprocess(simulate_cpu_series(a, PS, run=1)))
         for a in APPS],
        labels=list(APPS))


def _stream(svc, q, v=None, chunk=16, probe=False):
    """Push q through svc chunk by chunk; return (tick trace, first early
    decision, final verdict)."""
    svc.submit("j", expected_len=q.shape[0])
    trace, first = [], None
    for lo in range(0, q.shape[0], chunk):
        if v is None:
            svc.push("j", q[lo:lo + chunk])
        else:
            svc.push("j", q[lo:lo + chunk], variance=v[lo:lo + chunk])
        d = svc.tick()
        if probe:
            job = svc._jobs.get("j")
            if job is not None and job.last_sims is not None:
                trace.append((job.last_sims.copy(),
                              None if job.last_probs is None
                              else job.last_probs.copy(),
                              d.get("j")))
        elif first is None and d.get("j") is not None:
            first = d["j"]
    return trace, first, svc.finish("j")


@pytest.mark.parametrize("app", ["exim", "wordcount", "terasort"])
def test_zero_variance_service_reduces_bitwise(bank, app):
    """min_probability service fed zero variance == the exact service,
    tick for tick: identical score rows, identical decisions on identical
    ticks, and every probability exactly 1{score >= threshold}."""
    q = simulate_cpu_series(app, PS, run=2)
    ta, _, fa = _stream(
        TuningService(bank, band=16, threshold=0.8, denoise=False),
        q, probe=True)
    tb, _, fb = _stream(
        TuningService(bank, band=16, threshold=0.8, denoise=False,
                      min_probability=0.5),
        q, np.zeros_like(q), probe=True)

    assert len(ta) == len(tb) > 0
    for (sa, _, da), (sb, pb, db) in zip(ta, tb):
        np.testing.assert_array_equal(sa, sb)
        assert set(np.unique(pb)) <= {0.0, 1.0}
        np.testing.assert_array_equal(pb == 1.0, sb >= 0.8)
        assert (da is None) == (db is None)
        if da is not None:
            assert da.matched == db.matched and da.corr == db.corr
            assert da.decided_at_fraction == db.decided_at_fraction
            assert db.probability == 1.0
    assert fa.matched == fb.matched and fa.corr == fb.corr
    assert fb.probability in (0.0, 1.0)
    assert (fb.probability == 1.0) == (fa.corr >= 0.8)
    assert fa.probability is None  # point rule never reports one


def test_heteroscedastic_prob_rule_dominates_point_rule(bank):
    """Across golden heteroscedastic traces the probability-gated rule is
    never worse: no additional wrong early decisions, and every correct
    point-rule early decision is matched by a correct probabilistic one
    at the same fraction or earlier."""
    kw = dict(band=16, threshold=0.7, denoise=True, stable_ticks=2,
              min_fraction=0.1, margin=0.01)
    pt_wrong = pr_wrong = decided_pairs = 0
    for app in APPS:
        for run in (3, 4, 5):
            q, v = simulate_cpu_series_uncertain(app, PS, run=run,
                                                 noise=0.12)
            _, pe, _ = _stream(TuningService(bank, **kw), q)
            _, re, _ = _stream(
                TuningService(bank, min_probability=0.6, **kw), q, v)
            if pe is not None and pe.matched != app:
                pt_wrong += 1
            if re is not None and re.matched != app:
                pr_wrong += 1
            if pe is not None and pe.matched == app:
                # correct point decision -> prob rule also decides it,
                # correctly, no later (disattenuation recovers the
                # noise-attenuated correlation).
                assert re is not None and re.matched == app
                assert re.decided_at_fraction <= pe.decided_at_fraction
                assert re.probability >= 0.6
                decided_pairs += 1
    assert pr_wrong <= pt_wrong
    assert decided_pairs >= 1  # the property was actually exercised


def test_flat_posterior_abstains_where_point_rule_commits(bank):
    """Claimed measurement variance so large the posterior can't clear a
    strict gate: the point rule still commits on raw correlation, the
    probabilistic final verdict abstains (matched=None) with a finite
    sub-gate probability — never NaN."""
    q, _ = simulate_cpu_series_uncertain("terasort", PS, run=3, noise=0.12)
    kw = dict(band=16, threshold=0.7, denoise=True, stable_ticks=2,
              min_fraction=0.1, margin=0.01)
    _, _, fpt = _stream(TuningService(bank, **kw), q)
    assert fpt.matched == "terasort" and fpt.corr >= 0.7
    big = np.full_like(q, 0.5)
    _, _, fpr = _stream(TuningService(bank, min_probability=0.95, **kw),
                        q, big)
    assert fpr.matched is None
    assert fpr.probability is not None and np.isfinite(fpr.probability)
    assert 0.0 <= fpr.probability < 0.95


def test_constant_trace_scores_zero_and_abstains(bank):
    """Degenerate (zero-variance-in-x) query: the guarded score tail
    returns 0.0 instead of NaN on both the exact and the probabilistic
    paths, and neither service commits to a match."""
    qc = np.full(200, 0.5, np.float32)
    _, e_pt, f_pt = _stream(
        TuningService(bank, band=16, threshold=0.7, denoise=False), qc)
    assert e_pt is None and f_pt.matched is None
    assert f_pt.corr == 0.0 and np.isfinite(f_pt.corr)
    _, e_pr, f_pr = _stream(
        TuningService(bank, band=16, threshold=0.7, denoise=False,
                      min_probability=0.5),
        qc, np.zeros_like(qc))
    assert e_pr is None and f_pr.matched is None
    assert f_pr.corr == 0.0
    assert f_pr.probability == 0.0  # flat posterior at a 0.0 score


def test_host_correlation_degenerate_conventions():
    """Satellite-2 host half: `similarity.correlation` and
    `RunningMoments.corr` never emit NaN on constant inputs — identical
    constant pair -> 1.0, anything else degenerate -> 0.0."""
    from repro.core.similarity import RunningMoments, correlation

    c = np.full(32, 0.7, np.float32)
    r = np.linspace(0.0, 1.0, 32).astype(np.float32)
    assert correlation(c, c) == 1.0
    assert correlation(c, r) == 0.0
    assert correlation(r, c) == 0.0
    assert correlation(c, np.full(32, 0.2, np.float32)) == 0.0

    assert RunningMoments().update(c, c).corr == 1.0
    assert RunningMoments().update(c, r).corr == 0.0
    assert RunningMoments().update(r, c).corr == 0.0
    assert np.isfinite(RunningMoments().update(c, c + 0.1).corr)


# ---------------------------------------------------------------------------
# Approximate probability tail (prob_mode="approx")
# ---------------------------------------------------------------------------

def test_prob_mode_validation(bank):
    with pytest.raises(ValueError):
        TuningService(bank, min_probability=0.5, prob_mode="bogus")
    with pytest.raises(ValueError):
        TuningService(bank, prob_mode="approx")  # needs min_probability


@pytest.mark.parametrize("app", ["exim", "wordcount", "terasort"])
def test_approx_zero_variance_service_reduces_bitwise(bank, app):
    """The PR-7 degenerate-clamp guards extended to the approx tail: an
    approx-mode service fed zero variance == the EXACT prob service,
    tick for tick — identical score rows, identical {0, 1}
    probabilities, identical decisions on identical ticks — and both
    reduce to the point rule."""
    q = simulate_cpu_series(app, PS, run=2)
    kw = dict(band=16, threshold=0.8, denoise=False, min_probability=0.5)
    te, _, fe = _stream(TuningService(bank, **kw), q, np.zeros_like(q),
                        probe=True)
    ta, _, fa = _stream(TuningService(bank, prob_mode="approx", **kw),
                        q, np.zeros_like(q), probe=True)

    assert len(te) == len(ta) > 0
    for (se, pe, de), (sa, pa, da) in zip(te, ta):
        np.testing.assert_array_equal(sa, se)
        np.testing.assert_array_equal(pa, pe)
        assert set(np.unique(pa)) <= {0.0, 1.0}
        np.testing.assert_array_equal(pa == 1.0, sa >= 0.8)
        assert (da is None) == (de is None)
        if da is not None:
            assert da.matched == de.matched and da.corr == de.corr
            assert da.probability == de.probability
    assert fa.matched == fe.matched and fa.corr == fe.corr
    assert fa.probability == fe.probability


def test_approx_calibration_band_and_gating_agreement(bank):
    """The headline calibration contract on golden heteroscedastic
    traces: in-flight approx probabilities sit within a tolerance band
    of the exact tail (|dp| <= 0.2; short prefixes dominate the band —
    the svyy/svxy reconstruction is noisiest at small n, and the error
    is conservative: approx under-states confidence, it never inflates
    it enough to commit where exact would not), the ``P >=
    min_probability``
    gating decision agrees wherever the exact probability clears the
    band, the approx service makes NO additional wrong early decisions,
    and final verdicts are BITWISE the exact service's (finish always
    recomputes through the exact six-channel tail)."""
    BAND = 0.2
    GATE = 0.6
    kw = dict(band=16, threshold=0.7, denoise=True, stable_ticks=2,
              min_fraction=0.1, margin=0.01, min_probability=GATE)
    wrong_exact = wrong_approx = ticks_checked = 0
    for app in APPS:
        for run in (3, 4):
            q, v = simulate_cpu_series_uncertain(app, PS, run=run,
                                                 noise=0.12)
            te, ee, fe = _stream(TuningService(bank, **kw), q, v,
                                 probe=True)
            ta, ea, fa = _stream(
                TuningService(bank, prob_mode="approx", **kw), q, v,
                probe=True)
            assert len(te) == len(ta) > 0
            for (se, pe, de), (sa, pa, da) in zip(te, ta):
                # scores ride channels 0:3 — bitwise mode-independent
                np.testing.assert_array_equal(sa, se)
                dp = np.abs(pa - pe)
                assert dp.max() <= BAND
                # calibration band implies gate agreement outside it
                clear = np.abs(pe - GATE) > BAND
                np.testing.assert_array_equal((pa >= GATE)[clear],
                                              (pe >= GATE)[clear])
                ticks_checked += 1
            ee = next((t[2] for t in te if t[2] is not None), None)
            ea = next((t[2] for t in ta if t[2] is not None), None)
            if ee is not None and ee.matched != app:
                wrong_exact += 1
            if ea is not None and ea.matched != app:
                wrong_approx += 1
            # finals: bitwise the exact service's verdict
            assert fa.matched == fe.matched
            assert fa.corr == fe.corr
            assert fa.probability == fe.probability
    assert wrong_approx <= wrong_exact
    assert ticks_checked > 0


def test_approx_constant_trace_scores_zero_and_abstains(bank):
    """Degenerate (zero-variance-in-x) query through the approx tail:
    score 0.0 — never NaN — probability exactly 0.0, no commitment."""
    qc = np.full(200, 0.5, np.float32)
    _, e_a, f_a = _stream(
        TuningService(bank, band=16, threshold=0.7, denoise=False,
                      min_probability=0.5, prob_mode="approx"),
        qc, np.zeros_like(qc))
    assert e_a is None and f_a.matched is None
    assert f_a.corr == 0.0 and np.isfinite(f_a.corr)
    assert f_a.probability == 0.0
    # heteroscedastic noise on a constant trace: still finite, still 0.0
    _, e_n, f_n = _stream(
        TuningService(bank, band=16, threshold=0.7, denoise=False,
                      min_probability=0.5, prob_mode="approx"),
        qc, np.full_like(qc, 0.01))
    assert f_n.matched is None and np.isfinite(f_n.corr)
    assert f_n.corr == 0.0
