"""HLO cost model: trip-count-aware flops/bytes/collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlocost import parse_module


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_matmul_flops_exact():
    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), ()
        c, _ = jax.lax.scan(body, x, ws)
        return c
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 512, 512), jnp.float32)
    cost = parse_module(_compile(f, x, ws).as_text())
    assert cost.flops == pytest.approx(7 * 2 * 256 * 512 * 512, rel=0.01)


def test_nested_scan_flops():
    def g(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(jnp.dot(c2, w)), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, ws)
        return c
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    cost = parse_module(_compile(g, x, ws).as_text())
    assert cost.flops == pytest.approx(5 * 3 * 2 * 64 * 128 * 128, rel=0.02)


def test_bytes_reasonable_for_matmul():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    cost = parse_module(_compile(f, a, a).as_text())
    io_bytes = 3 * 512 * 512 * 4
    assert io_bytes * 0.5 <= cost.bytes <= io_bytes * 4


def test_tags_attributed():
    @jax.named_scope("flash_tile")
    def inner(a):
        return jnp.exp(a) * 2

    def f(a):
        return inner(a).sum()
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = parse_module(_compile(f, a).as_text())
    assert cost.tag_flops.get("flash_tile", 0) > 0
