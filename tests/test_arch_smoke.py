"""Per-assigned-architecture smoke tests: reduced config, one forward +
one train step on CPU, asserting shapes and finiteness."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import model as model_lib
from repro.sharding.rules import ExecConfig
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def _batch(cfg, B=2, S=32, with_extras=True, seed=0):
    rng = np.random.default_rng(seed)
    shape = (B, S) if cfg.num_codebooks == 1 else (B, S, cfg.num_codebooks)
    toks = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if with_extras and cfg.frontend == "vision":
        batch["extra_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S))
        batch["positions"] = jnp.asarray(pos.astype(np.int32))
    return batch


@pytest.mark.parametrize("arch", cfglib.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfglib.smoke_config(arch)
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    logits, aux = model_lib.forward(params, batch["tokens"], cfg,
                                    positions=batch.get("positions"),
                                    extra_embeds=batch.get("extra_embeds"))
    B, S = batch["tokens"].shape[:2]
    nb = max(cfg.num_codebooks, 1)
    assert logits.shape == (B, S, nb * cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = make_train_step(cfg, ExecConfig(), AdamWConfig(lr=1e-3))
    opt = adamw_init(params, AdamWConfig())
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ["minitron-4b", "deepseek-v2-236b",
                                  "zamba2-7b", "xlstm-1p3b",
                                  "musicgen-large"])
def test_smoke_prefill_decode_consistency(arch):
    cfg = cfglib.smoke_config(arch)
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    logits_full, _ = model_lib.forward(params, batch["tokens"], cfg)
    cache = model_lib.make_cache(cfg, B, S + 4, concrete=True)
    last, cache = model_lib.prefill(params, batch["tokens"], cache, cfg)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)
    nxt = jnp.argmax(last[:, :cfg.vocab_size], -1).astype(jnp.int32)
    if cfg.num_codebooks > 1:
        nxt = jnp.argmax(last.reshape(B, cfg.num_codebooks, -1), -1
                         ).astype(jnp.int32)
    lg, _ = model_lib.decode_step(params, nxt, cache, jnp.int32(S), cfg)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", cfglib.ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned dimensions."""
    cfg = cfglib.get(arch)
    expected = {
        "xlstm-1p3b": (48, 2048, 4, 50304),
        "minitron-4b": (32, 3072, 24, 256000),
        "starcoder2-15b": (40, 6144, 48, 49152),
        "phi3-mini-3p8b": (32, 3072, 32, 32064),
        "granite-20b": (52, 6144, 48, 49152),
        "musicgen-large": (48, 2048, 32, 2048),
        "deepseek-v2-236b": (60, 5120, 128, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 163840),
        "qwen2-vl-2b": (28, 1536, 12, 151936),
        "zamba2-7b": (81, 3584, 32, 32000),
    }[cfglib.canonical(arch)]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
            cfg.vocab_size) == expected
