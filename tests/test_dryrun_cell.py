"""One real dry-run cell end-to-end (subprocess: 512 fake devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_granite_decode_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-20b", "--shape", "decode_32k", "--force"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    path = os.path.join(ROOT, "experiments", "dryrun",
                        "granite-20b__decode_32k__16x16.json")
    rec = json.load(open(path))
    rf = rec["roofline"]
    assert rf["chips"] == 256
    assert all(v >= 0 for v in rf["terms_seconds"].values())
    # granite is MQA -> its 32k x 128 cache fits; MHA archs (musicgen,
    # phi3) sit at ~17 GB bf16 and need cache quantization (known issue)
    assert rec["memory_analysis"]["temp_size_in_bytes"] < 16e9  # fits HBM
    assert rf["per_chip"]["flops"] > 0
