"""Import ``given``/``settings``/``st`` from hypothesis when available,
else fall back to a deterministic mini property runner.

Tier-1 must collect and pass on machines without hypothesis installed
(CI installs it — see .github/workflows/ci.yml — so the real shrinking
engine still runs there).  The fallback drives each ``@given`` test with a
fixed, seeded set of examples per strategy: both bounds, the midpoint, and
a few seeded draws — no shrinking, but the same properties get exercised.

Only the strategies tier-1 actually uses are implemented (``st.integers``);
extend as tests grow.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import itertools

    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, lo: int, hi: int) -> None:
            self.lo, self.hi = int(lo), int(hi)

        def examples(self, rng, n_random: int):
            vals = [self.lo, self.hi, (self.lo + self.hi) // 2]
            vals += [int(v) for v in
                     rng.integers(self.lo, self.hi + 1, size=n_random)]
            return vals

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    st = _Strategies()

    def settings(**_kw):  # max_examples/deadline are hypothesis-only
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                rng = _np.random.default_rng(0xC0FFEE)
                cols = [s.examples(rng, 5) for s in strategies]
                for row in itertools.islice(zip(*(itertools.cycle(c)
                                                  for c in cols)),
                                            max(len(c) for c in cols)):
                    f(*row)

            # deliberately NOT functools.wraps: the wrapper must present a
            # zero-arg signature or pytest treats the example params as
            # fixtures.
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco
