"""MoE: routing invariants, capacity behaviour, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.moe import moe_init, moe_apply, _capacity

CFG = ModelConfig(name="moe-t", num_layers=1, d_model=32, num_heads=2,
                  num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=8,
                  top_k=2, d_ff_expert=16, param_dtype="float32",
                  dtype="float32")


def test_moe_output_shape_and_finite():
    p = moe_init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_apply(p, x, CFG)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_capacity_formula():
    assert _capacity(1024, CFG) == int(np.ceil(1024 * 2 * 1.25 / 8))


def test_moe_capacity_drops_tokens_when_tight():
    import dataclasses
    cfg_tight = dataclasses.replace(CFG, capacity_factor=0.05)
    p = moe_init(jax.random.PRNGKey(0), cfg_tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    out_tight, _ = moe_apply(p, x, cfg_tight)
    out_full, _ = moe_apply(p, x, CFG)
    # tight capacity zeroes some token outputs
    tight_norms = np.linalg.norm(np.asarray(out_tight)[0], axis=-1)
    full_norms = np.linalg.norm(np.asarray(out_full)[0], axis=-1)
    assert (tight_norms < 1e-6).sum() > (full_norms < 1e-6).sum()


def test_moe_shared_expert_always_active():
    import dataclasses
    cfg = dataclasses.replace(CFG, num_shared_experts=1, capacity_factor=0.01)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    out, _ = moe_apply(p, x, cfg)
    # even with ~all routed tokens dropped, shared expert output is nonzero
    assert np.linalg.norm(np.asarray(out)) > 1e-3


def test_moe_aux_balanced_router_near_one():
    """Uniform router -> aux loss ~= 1 (balanced)."""
    p = moe_init(jax.random.PRNGKey(0), CFG)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 32))
    _, aux = moe_apply(p, x, CFG)
    assert 0.8 < float(aux) < 1.3
