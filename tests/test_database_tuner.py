"""ReferenceDB + AutoTuner (profiling/matching phases + config transfer)."""
import numpy as np
import pytest

from repro.core import ReferenceDB, AutoTuner
from repro import mrsim


def _series(app, j=0, run=0):
    return mrsim.simulate_cpu_series(app, mrsim.paper_param_sets()[j], run=run)


def test_db_roundtrip(tmp_path):
    db = ReferenceDB()
    db.add("wc", {"M": 11, "R": 6}, _series("wordcount"), note="x")
    db.add("ts", {"M": 11, "R": 6}, _series("terasort"))
    db.set_best_config("wc", {"microbatch": 2}, score=1.5)
    db.save(str(tmp_path / "db"))
    db2 = ReferenceDB.load(str(tmp_path / "db"))
    assert len(db2) == 2
    assert db2.workloads() == ["wc", "ts"]
    assert db2.best_config("wc") == {"microbatch": 2}
    np.testing.assert_allclose(db2.entries[0].series, db.entries[0].series)


def test_lookup_by_params():
    db = ReferenceDB()
    db.add("wc", {"M": 11}, _series("wordcount"))
    assert db.lookup("wc", {"M": 11}) is not None
    assert db.lookup("wc", {"M": 12}) is None


def test_tuner_transfers_config_to_similar_workload():
    db = ReferenceDB()
    tuner = AutoTuner(db, band=8)
    tuner.profile("wordcount", {"j": 0}, _series("wordcount"))
    tuner.profile("terasort", {"j": 0}, _series("terasort"))
    db.set_best_config("wordcount", {"remat": "dots", "microbatch": 4}, 2.0)
    db.set_best_config("terasort", {"remat": "full"}, 1.0)

    decision = tuner.match("exim", _series("exim", run=1))
    assert decision.matched == "wordcount"
    assert decision.corr >= 0.9
    assert decision.config == {"remat": "dots", "microbatch": 4}


def test_tuner_falls_back_below_threshold():
    db = ReferenceDB()
    tuner = AutoTuner(db, threshold=0.999999, band=4)
    tuner.profile("a", {}, _series("terasort"))
    db.set_best_config("a", {"x": 1}, 1.0)
    calls = []
    decision = tuner.tune("b", _series("wordcount", run=3),
                          fallback=lambda: calls.append(1) or {"y": 2})
    assert calls == [1]
    assert decision.config == {"y": 2}
    assert db.best_config("b") == {"y": 2}


def test_tuner_wavelet_prefilter():
    db = ReferenceDB()
    tuner = AutoTuner(db, band=8, wavelet_prefilter=1)
    tuner.profile("wordcount", {}, _series("wordcount"))
    tuner.profile("terasort", {}, _series("terasort"))
    db.set_best_config("wordcount", {"z": 3}, 1.0)
    decision = tuner.match("exim", _series("exim", run=1))
    assert decision.used_wavelet_prefilter
    assert decision.matched == "wordcount"
