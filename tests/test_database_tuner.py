"""ReferenceDB + AutoTuner (profiling/matching phases + config transfer)."""
import os

import numpy as np
import pytest

from repro.core import ReferenceDB, AutoTuner
from repro import mrsim

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


def _series(app, j=0, run=0):
    return mrsim.simulate_cpu_series(app, mrsim.paper_param_sets()[j], run=run)


def test_db_roundtrip(tmp_path):
    db = ReferenceDB()
    db.add("wc", {"M": 11, "R": 6}, _series("wordcount"), note="x")
    db.add("ts", {"M": 11, "R": 6}, _series("terasort"))
    db.set_best_config("wc", {"microbatch": 2}, score=1.5)
    db.save(str(tmp_path / "db"))
    db2 = ReferenceDB.load(str(tmp_path / "db"))
    assert len(db2) == 2
    assert db2.workloads() == ["wc", "ts"]
    assert db2.best_config("wc") == {"microbatch": 2}
    np.testing.assert_allclose(db2.entries[0].series, db.entries[0].series)


def test_lookup_by_params():
    db = ReferenceDB()
    db.add("wc", {"M": 11}, _series("wordcount"))
    assert db.lookup("wc", {"M": 11}) is not None
    assert db.lookup("wc", {"M": 12}) is None


def test_tuner_transfers_config_to_similar_workload():
    db = ReferenceDB()
    tuner = AutoTuner(db, band=8)
    tuner.profile("wordcount", {"j": 0}, _series("wordcount"))
    tuner.profile("terasort", {"j": 0}, _series("terasort"))
    db.set_best_config("wordcount", {"remat": "dots", "microbatch": 4}, 2.0)
    db.set_best_config("terasort", {"remat": "full"}, 1.0)

    decision = tuner.match("exim", _series("exim", run=1))
    assert decision.matched == "wordcount"
    assert decision.corr >= 0.9
    assert decision.config == {"remat": "dots", "microbatch": 4}


def test_tuner_falls_back_below_threshold():
    db = ReferenceDB()
    tuner = AutoTuner(db, threshold=0.999999, band=4)
    tuner.profile("a", {}, _series("terasort"))
    db.set_best_config("a", {"x": 1}, 1.0)
    calls = []
    decision = tuner.tune("b", _series("wordcount", run=3),
                          fallback=lambda: calls.append(1) or {"y": 2})
    assert calls == [1]
    assert decision.config == {"y": 2}
    assert db.best_config("b") == {"y": 2}


def test_tuner_wavelet_prefilter():
    db = ReferenceDB()
    tuner = AutoTuner(db, band=8, wavelet_prefilter=1)
    tuner.profile("wordcount", {}, _series("wordcount"))
    tuner.profile("terasort", {}, _series("terasort"))
    db.set_best_config("wordcount", {"z": 3}, 1.0)
    decision = tuner.match("exim", _series("exim", run=1))
    assert decision.used_wavelet_prefilter
    assert decision.matched == "wordcount"


# ---------------------------------------------------------------------------
# Architecture-signature discrimination (the kimi-k2 -> deepseek-v2 match)
# ---------------------------------------------------------------------------

def _arch_tuner(sigs, band):
    db = ReferenceDB()
    tuner = AutoTuner(db, band=band, threshold=0.85)
    for name, sig in sigs.items():
        if name != "kimi-k2-1t-a32b":
            tuner.profile(name, {}, sig)
            db.set_best_config(name, {"arch": name}, 1.0)
    return tuner


def test_kimi_matches_deepseek_not_phi3_golden_signatures():
    """Regression for the signature-discrimination defect: with the band at
    one layer period (32 = 2048 samples / 64 layers) the MLA+MoE pair
    (kimi-k2 -> deepseek-v2) must win; at two layer periods DTW could warp
    phi3's dense waves over kimi's pattern (phi3 0.8994 vs deepseek
    0.8963).  Runs on golden jaxpr-trace signatures so the matching stack
    is pinned independently of model-code drift; bench_autotune asserts
    the same ordering on live traces.
    """
    sigs = dict(np.load(os.path.join(GOLDEN, "arch_signatures.npz")))
    tuner = _arch_tuner(sigs, band=32)
    decision = tuner.match("kimi-k2-1t-a32b", sigs["kimi-k2-1t-a32b"])
    assert decision.matched == "deepseek-v2-236b", decision.scores
    assert decision.corr >= 0.85
    assert decision.scores["phi3-mini-3p8b"] < decision.corr - 0.1, \
        decision.scores
    assert decision.config == {"arch": "deepseek-v2-236b"}


@pytest.mark.slow
def test_kimi_matches_deepseek_live_traces():
    """Same ordering on freshly traced signatures (catches drift in the
    signature features themselves, not just the matcher)."""
    import jax
    import jax.numpy as jnp

    from repro import configs as cfglib
    from repro.core.signatures import signature_of
    from repro.models import model as model_lib

    def sig(arch):
        cfg = cfglib.get(arch)
        params = jax.eval_shape(lambda k: model_lib.init(k, cfg),
                                jax.random.PRNGKey(0))
        shape = (4, 512) if cfg.num_codebooks == 1 else \
            (4, 512, cfg.num_codebooks)
        batch = {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
                 "labels": jax.ShapeDtypeStruct(shape, jnp.int32)}
        return signature_of(lambda p, b: model_lib.loss_fn(p, b, cfg)[0],
                            params, batch, samples=2048)

    sigs = {a: sig(a) for a in ("deepseek-v2-236b", "phi3-mini-3p8b",
                                "kimi-k2-1t-a32b")}
    tuner = _arch_tuner(sigs, band=32)
    decision = tuner.match("kimi-k2-1t-a32b", sigs["kimi-k2-1t-a32b"])
    assert decision.matched == "deepseek-v2-236b", decision.scores
    assert decision.scores["phi3-mini-3p8b"] < decision.corr


# ---------------------------------------------------------------------------
# ReferenceDB.bank cache behavior
# ---------------------------------------------------------------------------

def test_bank_cache_add_invalidates_stale_pack():
    """add() after a cached bank() must invalidate EVERY cached selection —
    a stale [K, M] pack would silently drop the new entry from matching."""
    rng = np.random.default_rng(11)
    db = ReferenceDB()
    db.add("a", {}, rng.normal(size=24))
    db.add("b", {}, rng.normal(size=30))
    full = db.bank()
    only_a = db.bank(workloads=["a"])
    assert db.bank() is full and db.bank(workloads=["a"]) is only_a

    db.add("a", {}, rng.normal(size=18))        # second entry for "a"
    fresh_full = db.bank()
    fresh_a = db.bank(workloads=["a"])
    assert fresh_full is not full and len(fresh_full) == 3
    assert fresh_a is not only_a and len(fresh_a) == 2
    # the fresh pack really contains the new series, not a stale copy
    np.testing.assert_array_equal(fresh_a.row(1), db.entries[2].series)


def test_bank_cache_lru_evicts_oldest_selection():
    rng = np.random.default_rng(12)
    db = ReferenceDB()
    names = [f"w{i}" for i in range(ReferenceDB.BANK_CACHE_MAX + 1)]
    for name in names:
        db.add(name, {}, rng.normal(size=16))

    banks = {name: db.bank(workloads=[name]) for name in names[:-1]}
    # touch the oldest so it becomes most-recent...
    assert db.bank(workloads=[names[0]]) is banks[names[0]]
    # ...then push one more distinct selection over the cap:
    db.bank(workloads=[names[-1]])
    assert len(db._bank_cache) == ReferenceDB.BANK_CACHE_MAX
    # LRU evicted names[1] (the least recently used), NOT the re-touched
    # names[0]:
    assert db.bank(workloads=[names[0]]) is banks[names[0]]
    assert db.bank(workloads=[names[1]]) is not banks[names[1]]


def test_decision_history_roundtrip(tmp_path):
    """Decision records (the margin/stable_ticks/min_fraction calibration
    data) persist with the DB and survive a save/load cycle; old saves
    without a decisions section still load."""
    from repro.core.tuner import TuneDecision

    db = ReferenceDB()
    db.add("wc", {"M": 11}, _series("wordcount"))
    db.record_decision(TuneDecision(
        workload="job-1", matched="wc", corr=0.97, config=None,
        scores={"wc": 0.97, "ts": 0.41}, fraction_seen=1.0, final=True,
        decided_at_fraction=0.44))
    db.record_decision({"workload": "job-2", "matched": "ts", "corr": 0.91,
                        "scores": {}, "decided_at_fraction": 0.6,
                        "final": True})
    p = tmp_path / "db"
    db.save(str(p))
    db2 = ReferenceDB.load(str(p))
    assert len(db2.decision_history()) == 2
    assert db2.decided_at_fractions("wc") == [pytest.approx(0.44)]
    assert db2.decided_at_fractions("ts") == [pytest.approx(0.6)]
    rec = db2.decision_history(matched="wc")[0]
    round_trip = TuneDecision.from_record(rec)
    assert round_trip.matched == "wc"
    assert round_trip.decided_at_fraction == pytest.approx(0.44)
    assert round_trip.scores["ts"] == pytest.approx(0.41)
