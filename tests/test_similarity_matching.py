"""Similarity + matching phase (paper §3.1.3, Fig. 4-b, Table 1)."""
import numpy as np
import pytest

from repro.core import similarity, match_application, correlation
from repro import mrsim


def test_self_similarity_is_one():
    x = np.random.default_rng(0).normal(size=64).astype(np.float32)
    assert similarity(x, x) == pytest.approx(1.0, abs=1e-5)


def test_correlation_requires_equal_length():
    with pytest.raises(ValueError):
        correlation(np.zeros(4), np.zeros(5))


def test_paper_table1_structure():
    """Exim matches WordCount (same text-parse family), not TeraSort."""
    psets = mrsim.paper_param_sets()
    refs = {app: [mrsim.simulate_cpu_series(app, p) for p in psets]
            for app in ("wordcount", "terasort")}
    qs = [mrsim.simulate_cpu_series("exim", p, run=1) for p in psets]
    res = match_application(qs, refs, band=8)
    assert res.best == "wordcount"
    assert res.wins["wordcount"] > res.wins["terasort"]
    # diagonal scores beat the paper's 0.9 threshold
    assert all(s >= 0.9 for s in res.scores["wordcount"])


def test_match_application_rejects_below_threshold():
    rng = np.random.default_rng(1)
    qs = [rng.normal(size=100).astype(np.float32)]
    refs = {"other": [rng.normal(size=100).astype(np.float32) * 0 + 
                      np.linspace(0, 1, 100).astype(np.float32)]}
    res = match_application(qs, refs, threshold=0.999, band=4)
    assert res.best is None or res.wins[res.best] == 0 or res.best == "other"
