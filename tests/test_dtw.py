"""DTW (paper Eq. 1-2): jnp min-plus scan vs brute force + properties."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dtw
from repro.kernels.dtw.ref import dtw_matrix_ref


@given(st.integers(0, 2**31 - 1), st.integers(2, 24), st.integers(2, 31))
@settings(max_examples=25, deadline=None)
def test_dtw_matrix_matches_bruteforce(seed, n, m):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=m).astype(np.float32)
    D = np.asarray(dtw.dtw_matrix(x, y))
    Dr = dtw_matrix_ref(x, y)
    np.testing.assert_allclose(D, Dr, rtol=1e-4, atol=1e-4)


def test_identity_distance_zero():
    x = np.random.default_rng(0).normal(size=50).astype(np.float32)
    assert float(dtw.dtw_distance(x, x)) < 1e-4


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_distance_symmetry(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=17).astype(np.float32)
    y = rng.normal(size=23).astype(np.float32)
    assert abs(float(dtw.dtw_distance(x, y))
               - float(dtw.dtw_distance(y, x))) < 1e-3


def test_banded_equals_full_for_wide_band():
    rng = np.random.default_rng(3)
    x = rng.normal(size=20).astype(np.float32)
    y = rng.normal(size=25).astype(np.float32)
    Df = np.asarray(dtw.dtw_matrix(x, y))
    Db = np.asarray(dtw.dtw_matrix_banded(x, y, band=30))
    np.testing.assert_allclose(Df, Db, rtol=1e-4, atol=1e-4)


def test_backtrack_path_valid():
    rng = np.random.default_rng(4)
    x = rng.normal(size=30).astype(np.float32)
    y = rng.normal(size=40).astype(np.float32)
    D = np.asarray(dtw.dtw_matrix(x, y))
    path = dtw.backtrack(D)
    assert tuple(path[0]) == (0, 0)
    assert tuple(path[-1]) == (29, 39)
    steps = np.diff(path, axis=0)
    assert ((steps >= 0) & (steps <= 1)).all()
    assert (steps.sum(axis=1) >= 1).all()


def test_warp_to_length_and_monotonicity():
    rng = np.random.default_rng(5)
    x = rng.normal(size=30).astype(np.float32)
    y = rng.normal(size=12).astype(np.float32)
    yp, dist = dtw.dtw_warp(x, y)
    assert yp.shape == (30,)
    assert set(np.unique(yp)).issubset(set(np.unique(y)))
    assert dist >= 0
