"""PR-7 matching-stack bugfix sweep regression tests.

Pins the three pre-existing defects fixed alongside the uncertain-series
tentpole:

* ``BoundedBuffer`` sample-conservation accounting under ``drop_oldest``
  multi-chunk sheds (``total_in`` used to count the post-shed size when a
  single chunk alone overflowed the limit);
* ``OnlineMatcher.final_scores`` re-running the full DP on device even
  when the streamed rows were already collected (the PR-5
  ``stream_offline_equiv`` throughput regression) — now a host backtrack
  of the collected rows, equal to the offline verdict;
* degenerate-variance NaNs in the host correlation tail (covered from
  the service side in ``test_uncertain_matching``).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serve.ingest import BackpressureError, BoundedBuffer


class _Tape:
    """Replays one seeded push/drain interleaving against a BoundedBuffer
    and tracks the drained-sample total for the conservation check."""

    def __init__(self, seed: int, limit, policy: str) -> None:
        self.rng = np.random.default_rng(seed)
        self.buf = BoundedBuffer(limit, policy)
        self.drained = 0

    def step(self) -> None:
        if self.rng.random() < 0.7:
            # chunk sizes straddle the limit so single pushes can shed
            # multiple buffered chunks, or alone overflow the limit.
            n = int(self.rng.integers(1, 24))
            try:
                self.buf.append(self.rng.random(n).astype(np.float32))
            except BackpressureError:
                pass                       # rejected pushes enqueue nothing
        else:
            out = self.buf.drain()
            if out is not None:
                self.drained += out.shape[0]

    def check(self) -> None:
        assert self.buf.total_in == (self.drained + len(self.buf)
                                     + self.buf.dropped)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_bounded_buffer_conservation_drop_oldest(seed):
    """Conservation invariant ``pushed == drained + buffered + dropped``
    holds at EVERY step of random push/drain interleavings under
    drop_oldest, including multi-chunk sheds and chunks that alone
    overflow the limit (limit=10 < max chunk size 23)."""
    tape = _Tape(seed, limit=10, policy="drop_oldest")
    for _ in range(200):
        tape.step()
        tape.check()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_bounded_buffer_conservation_reject(seed):
    """Same invariant under reject: a refused push enqueues (and counts)
    nothing, so the identical chunk can be retried."""
    tape = _Tape(seed, limit=16, policy="reject")
    for _ in range(200):
        tape.step()
        tape.check()
    assert tape.buf.dropped == 0


def test_bounded_buffer_chunk_alone_overflow_counts_full_push():
    """A single 25-sample push into a limit-10 buffer keeps the newest 10
    and counts all 25 accepted — 15 dropped, not silently uncounted."""
    buf = BoundedBuffer(10, "drop_oldest")
    buf.append(np.arange(25, dtype=np.float32))
    assert buf.total_in == 25
    assert buf.dropped == 15
    assert len(buf) == 10
    out = buf.drain()
    np.testing.assert_array_equal(out, np.arange(15, 25, dtype=np.float32))
    assert buf.total_in == out.shape[0] + buf.dropped


@pytest.mark.parametrize("band", [None, 8])
@pytest.mark.parametrize("collect_rows", [True, False])
def test_final_scores_equals_offline_bank(band, collect_rows):
    """`OnlineMatcher.final_scores` == the offline ``similarity_bank``
    verdict on the full query whether it backtracks collected rows (the
    fixed fast path) or re-solves matrix-free (collect_rows=False)."""
    from repro.core.database import pack_series
    from repro.core.similarity import similarity_bank
    from repro.core.tuner import OnlineMatcher

    rng = np.random.default_rng(7)
    refs = [rng.random(int(rng.integers(20, 40))).astype(np.float32)
            for _ in range(6)]
    bank = pack_series(refs)
    q = rng.random(30).astype(np.float32)

    m = OnlineMatcher(bank, band=band, collect_rows=collect_rows,
                      query_len=q.shape[0] if band is not None else None)
    for lo in range(0, q.shape[0], 7):
        m.extend(q[lo:lo + 7])
    got = m.final_scores()
    want = similarity_bank(q, bank, preprocess=False, band=band)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_final_scores_rows_path_matches_rows_free_path():
    """Both final_scores paths agree with each other on the same stream
    (the rows backtrack is not a different verdict, just a cheaper one)."""
    from repro.core.database import pack_series
    from repro.core.tuner import OnlineMatcher

    rng = np.random.default_rng(11)
    bank = pack_series([rng.random(int(rng.integers(20, 40)))
                        .astype(np.float32) for _ in range(5)])
    q = rng.random(26).astype(np.float32)
    outs = []
    for collect in (True, False):
        m = OnlineMatcher(bank, collect_rows=collect)
        for lo in range(0, q.shape[0], 5):
            m.extend(q[lo:lo + 5])
        outs.append(m.final_scores())
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
