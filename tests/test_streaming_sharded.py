"""Sharded streaming tick (8 forced host devices in a subprocess so the
main test process keeps its single real device).

The bank-sharded TuningService must be *observationally identical* to the
unsharded one: every per-(job, reference) score agrees to 1e-6 (the tick
math is per-reference, so partitioning K changes nothing), the emitted
early decisions match tick-for-tick, ragged + banded banks both work, and
a tick stays ONE dispatch however many devices the bank spans.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    import numpy as np
    from repro.core.database import pack_series
    from repro.serve.tuning import TuningService

    rng = np.random.default_rng(0)

    def make_bank(k=11, lo=18, hi=40):
        # deliberately NOT a multiple of 8 devices: exercises bank padding
        series = []
        for i in range(k):
            l = int(rng.integers(lo, hi))
            t = np.linspace(0, 1, l, dtype=np.float32)
            s = 0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + 0.7 * i) * t) \\
                + 0.04 * rng.normal(size=l)
            series.append(np.clip(s, 0, 1).astype(np.float32))
        labels = [f"w{i % 4}" for i in range(k)]
        return pack_series(series, labels=labels)

    def drive(svc, queries):
        # per-job chunk sizes differ and drift tick-to-tick, so every
        # sharded tick sees ragged nvalid (including jobs that push
        # nothing) and exercises the padded-sample passthrough.
        decisions = []
        sims = []
        pos = {jid: 0 for jid in queries}
        sizes = {jid: (7, 3, 9, 0, 5)[i % 5:] + (7, 3, 9, 0, 5)[:i % 5]
                 for i, jid in enumerate(queries)}
        t = 0
        while any(pos[jid] < len(q) for jid, q in queries.items()):
            for jid, q in queries.items():
                step = sizes[jid][t % 5]
                svc.push(jid, q[pos[jid]: pos[jid] + step])
                pos[jid] = min(pos[jid] + step, len(q))
            t += 1
            out = svc.tick()
            decisions.append({jid: (d.matched, round(d.corr, 5))
                              for jid, d in out.items() if d is not None})
            sims.append({jid: svc._jobs[jid].last_sims.copy()
                         for jid in queries if svc._jobs[jid].last_sims
                         is not None})
        finals = {jid: svc.finish(jid) for jid in queries}
        return decisions, sims, finals

    mesh = jax.make_mesh((8,), ("bank",))
    for band in (None, 6):
        bank = make_bank()
        qlen = 42
        queries = {}
        for j in range(3):
            t = np.linspace(0, 1, qlen, dtype=np.float32)
            q = 0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + 0.7 * j) * t) \\
                + 0.04 * rng.normal(size=qlen)
            queries[f"job{j}"] = np.clip(q, 0, 1).astype(np.float32)

        kw = dict(band=band, threshold=0.5, margin=0.01, stable_ticks=2,
                  min_fraction=0.2, slots=4)
        ref = TuningService(bank, **kw)
        shd = TuningService(bank, mesh=mesh, **kw)
        for jid, q in queries.items():
            ref.submit(jid, expected_len=len(q))
            shd.submit(jid, expected_len=len(q))
        dec_r, sims_r, fin_r = drive(ref, queries)
        dec_s, sims_s, fin_s = drive(shd, queries)

        # sharded == unsharded: scores to 1e-6, decisions identical
        for tick_r, tick_s in zip(sims_r, sims_s):
            assert tick_r.keys() == tick_s.keys()
            for jid in tick_r:
                err = float(np.abs(tick_r[jid] - tick_s[jid]).max())
                assert err < 1e-6, (band, jid, err)
        assert dec_r == dec_s, (band, dec_r, dec_s)
        for jid in queries:
            assert fin_r[jid].matched == fin_s[jid].matched
            assert abs(fin_r[jid].corr - fin_s[jid].corr) < 1e-9

        # dispatch-per-tick invariant holds under sharding
        assert shd.dispatch_count == shd.ticks, \\
            (shd.dispatch_count, shd.ticks)
        print(f"SHARDED_TICK_OK band={band} "
              f"dispatches={shd.dispatch_count} ticks={shd.ticks}")

    # wavelet-prefilter pruning composes with the sharded tick: the
    # re-packed (bucket-padded, device-count-multiple) survivor bank
    # shards like the full one, and sharded == unsharded holds for the
    # pruned service too (masked scores compare as: same -inf pattern,
    # finite entries to 1e-6).
    bank = make_bank()
    queries = {}
    for j in range(3):
        t = np.linspace(0, 1, 42, dtype=np.float32)
        q = 0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + 0.7 * j) * t) \\
            + 0.04 * rng.normal(size=42)
        queries[f"job{j}"] = np.clip(q, 0, 1).astype(np.float32)
    kw = dict(threshold=0.5, margin=0.01, stable_ticks=2, min_fraction=0.2,
              slots=4, prefilter_top=2, prefilter_margin=0.02)
    ref = TuningService(bank, **kw)
    shd = TuningService(bank, mesh=mesh, **kw)
    for jid, q in queries.items():
        ref.submit(jid, expected_len=len(q))
        shd.submit(jid, expected_len=len(q))
    dec_r, sims_r, fin_r = drive(ref, queries)
    dec_s, sims_s, fin_s = drive(shd, queries)
    for tick_r, tick_s in zip(sims_r, sims_s):
        for jid in tick_r:
            a, b = tick_r[jid], tick_s[jid]
            fa, fb = np.isfinite(a), np.isfinite(b)
            assert (fa == fb).all(), ("prefilter mask diverged", jid)
            err = float(np.abs(a[fa] - b[fb]).max())
            assert err < 1e-6, ("pruned", jid, err)
    assert dec_r == dec_s, ("pruned", dec_r, dec_s)
    for jid in queries:
        assert fin_r[jid].matched == fin_s[jid].matched
    assert shd.dispatch_count == shd.ticks
    assert shd.repack_count == ref.repack_count
    print(f"SHARDED_PRUNED_OK repacks={shd.repack_count} "
          f"survivors={len(shd._packed_idx)}/{len(bank)}")

    # elastic rescale mid-flight: an ElasticController decision (two of
    # the eight hosts flagged as stragglers -> data axis snaps to the
    # pow2 floor 4) drives TuningService.rescale onto a 4-device mesh.
    # The re-homed service keeps ticking bit-compatibly with the
    # unsharded reference: rescale moves state, never numbers.
    from repro.runtime.fault import ElasticController

    bank = make_bank()
    queries = {}
    for j in range(3):
        t = np.linspace(0, 1, 42, dtype=np.float32)
        q = 0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + 0.7 * j) * t) \\
            + 0.04 * rng.normal(size=42)
        queries[f"job{j}"] = np.clip(q, 0, 1).astype(np.float32)
    kw = dict(band=6, threshold=0.5, margin=0.01, stable_ticks=2,
              min_fraction=0.2, slots=4)
    ref = TuningService(bank, **kw)
    shd = TuningService(bank, mesh=mesh, **kw)
    for jid, q in queries.items():
        ref.submit(jid, expected_len=len(q))
        shd.submit(jid, expected_len=len(q))

    ctl = ElasticController(model_parallel=1)
    pos = {jid: 0 for jid in queries}
    t = 0
    while any(pos[jid] < len(q) for jid, q in queries.items()):
        if t == 3:      # hosts 6, 7 degrade mid-run
            d = ctl.decide(current_data_parallel=8, alive=list(range(8)),
                           stragglers=[6, 7])
            assert d.should_rescale and d.new_data_parallel == 4, d
            shd.rescale(jax.make_mesh(
                (d.new_data_parallel,), ("bank",),
                devices=jax.devices()[:d.new_data_parallel]))
        for jid, q in queries.items():
            ref.push(jid, q[pos[jid]: pos[jid] + 7])
            shd.push(jid, q[pos[jid]: pos[jid] + 7])
            pos[jid] = min(pos[jid] + 7, len(q))
        t += 1
        ref.tick()
        shd.tick()
        for jid in queries:
            a = ref._jobs[jid].last_sims
            b = shd._jobs[jid].last_sims
            err = float(np.abs(a - b).max())
            assert err < 1e-6, ("rescale", t, jid, err)
    fin_r = ref.finish_many(list(queries))
    fin_s = shd.finish_many(list(queries))
    for jid in queries:
        assert fin_r[jid].matched == fin_s[jid].matched
        assert abs(fin_r[jid].corr - fin_s[jid].corr) < 1e-9
    assert shd.rescale_count == 1 and shd.mesh.devices.size == 4
    assert shd.dispatch_count == shd.ticks
    print(f"SHARDED_RESCALE_OK ndev={shd.mesh.devices.size} "
          f"rescales={shd.rescale_count}")
""")


def test_sharded_tick_equals_unsharded():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("SHARDED_TICK_OK") == 2, r.stdout + r.stderr
    assert "SHARDED_PRUNED_OK" in r.stdout, r.stdout + r.stderr
    assert "SHARDED_RESCALE_OK" in r.stdout, r.stdout + r.stderr
