"""Kill-and-recover: SIGKILL a serving process mid-stream, recover from
snapshot + WAL replay, and pin the restored decisions BITWISE against an
uninterrupted golden run.

Three subprocess modes share one deterministic command tape (submits,
pushes, ticks, finishes — each journaling exactly one WAL record, so the
resume position after a crash is simply ``wal.next_seq``):

* ``golden``  — runs the full tape on a plain ``TuningService`` and
  prints every decision (float-hex scores) keyed by command index;
* ``serve``   — runs the tape on a ``RecoverableTuningService``,
  checkpoints mid-run, and SIGKILLs *itself* at the chaos plan's seeded
  kill point (``FaultPlan.should_kill``) — a real crash, no cleanup;
* ``recover`` — ``RecoverableTuningService.recover`` (snapshot + journal
  tail replay), resumes the tape at ``wal.next_seq`` and prints the
  remaining decisions.

The parent asserts the recovered run's decisions equal the golden run's
at every shared command index — including the sharded variant where the
service crashes on an 8-device (forced host) mesh and recovers onto 4
devices: scores are per-reference quantities, so the column math never
crosses the shard boundary and recovery is device-count independent.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    MESH = os.environ.get("CR_MESH", "none")
    if MESH != "none":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import signal
    import sys
    sys.path.insert(0, "src")
    import jax
    import numpy as np
    from repro.core.database import pack_series
    from repro.runtime.chaos import FaultPlan
    from repro.serve.recovery import RecoverableTuningService
    from repro.serve.tuning import TuningService

    MODE = os.environ["CR_MODE"]            # golden | serve | recover
    ROOT = os.environ["CR_ROOT"]
    KILL_EVERY = int(os.environ.get("CR_KILL_EVERY", "0")) or None
    CKPT_AT = int(os.environ.get("CR_CKPT_AT", "11"))

    rng = np.random.default_rng(7)
    series = [np.abs(np.cumsum(rng.normal(size=int(l))))
              .astype(np.float32)
              for l in rng.integers(40, 90, size=6)]
    bank = pack_series(series, labels=[f"w{i}" for i in range(6)])
    streams = {f"j{i}": np.abs(np.cumsum(rng.normal(size=64)))
               .astype(np.float32) for i in range(3)}

    # the command tape: every entry journals EXACTLY one WAL record, so
    # a crashed run's resume position is wal.next_seq.
    cmds = [("submit", j) for j in streams]
    for t in range(8):
        cmds += [("push", j, t) for j in streams]
        cmds += [("tick", float(t))]
    cmds += [("finish", sorted(streams))]

    def keyd(decisions):
        out = []
        for j, d in sorted(decisions.items()):
            if d is None:
                out.append([j, None])
            else:
                out.append([j, d.matched, float(d.corr).hex(), d.final,
                            sorted([k, float(v).hex()]
                                   for k, v in d.scores.items())])
        return out

    def run_cmd(svc, cmd):
        kind = cmd[0]
        if kind == "submit":
            svc.submit(cmd[1], 64)
        elif kind == "push":
            j, t = cmd[1], cmd[2]
            svc.push(j, streams[j][t * 8:(t + 1) * 8], now=float(t))
        elif kind == "tick":
            return keyd(svc.tick(now=cmd[1]))
        elif kind == "finish":
            return keyd(svc.finish_many(cmd[1]))
        return None

    def make_mesh():
        if MESH == "none":
            return None
        n = int(MESH)
        return jax.make_mesh((n,), ("bank",), devices=jax.devices()[:n])

    KW = dict(threshold=0.5, margin=0.01, stable_ticks=2,
              min_fraction=0.2, slots=4)

    if MODE == "golden":
        svc = TuningService(bank, mesh=make_mesh(), **KW)
        out = {}
        for i, cmd in enumerate(cmds):
            d = run_cmd(svc, cmd)
            if d is not None:
                out[str(i)] = d
        print("GOLDEN " + json.dumps(out), flush=True)

    elif MODE == "serve":
        svc = RecoverableTuningService(bank, root=ROOT, mesh=make_mesh(),
                                       **KW)
        plan = FaultPlan(seed=0, kill_every=KILL_EVERY)
        for i, cmd in enumerate(cmds):
            run_cmd(svc, cmd)
            print(f"ACK {i}", flush=True)
            if i == CKPT_AT:
                svc.checkpoint()
                print(f"CKPT {i}", flush=True)
            if plan.should_kill(i):
                os.kill(os.getpid(), signal.SIGKILL)   # a REAL crash
        print("SERVE_DONE", flush=True)

    elif MODE == "recover":
        svc = RecoverableTuningService.recover(bank, root=ROOT,
                                               mesh=make_mesh(), **KW)
        resume = svc.wal.next_seq
        print(f"RESUMED_AT {resume} REPLAYED {svc.replayed}", flush=True)
        out = {}
        for i in range(resume, len(cmds)):
            d = run_cmd(svc, cmds[i])
            if d is not None:
                out[str(i)] = d
        print("RECOVERED " + json.dumps(out), flush=True)
""")

N_CMDS = 3 + 8 * 4 + 1     # keep in sync with the tape in SCRIPT


def _run(tmp_path, mode, mesh, root, **env_extra):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.update({"CR_MODE": mode, "CR_MESH": mesh, "CR_ROOT": str(root)},
               **{k: str(v) for k, v in env_extra.items()})
    return subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900)


def _kill_and_recover(tmp_path, crash_mesh, recover_mesh):
    root = tmp_path / "svc"

    g = _run(tmp_path, "golden", recover_mesh, root)
    assert g.returncode == 0, g.stdout + g.stderr
    golden = json.loads(g.stdout.split("GOLDEN ", 1)[1].splitlines()[0])

    s = _run(tmp_path, "serve", crash_mesh, root,
             CR_KILL_EVERY=20, CR_CKPT_AT=11)
    assert s.returncode == -signal.SIGKILL, \
        f"serve process should die by SIGKILL: {s.returncode}\n" \
        + s.stdout + s.stderr
    assert "SERVE_DONE" not in s.stdout, "crash must land mid-tape"
    assert "CKPT 11" in s.stdout, s.stdout + s.stderr
    assert "ACK 19" in s.stdout and "ACK 20" not in s.stdout, s.stdout

    r = _run(tmp_path, "recover", recover_mesh, root)
    assert r.returncode == 0, r.stdout + r.stderr
    head = r.stdout.split("RESUMED_AT ", 1)[1].split()
    resume, replayed = int(head[0]), int(head[2])
    assert resume == 20, (resume, r.stdout)       # crash after cmd 19
    assert replayed == 20 - 1 - 11, (replayed, r.stdout)  # tail past ckpt
    recovered = json.loads(
        r.stdout.split("RECOVERED ", 1)[1].splitlines()[0])

    # every decision the recovered run emits is BITWISE the golden one
    assert recovered, "recovered run emitted no decisions"
    for i, dec in recovered.items():
        assert int(i) >= resume
        assert dec == golden[i], (i, dec, golden[i])
    # the tape's final verdicts are always post-crash: covered above
    assert str(N_CMDS - 1) in recovered


def test_kill_and_recover_unsharded(tmp_path):
    _kill_and_recover(tmp_path, crash_mesh="none", recover_mesh="none")


def test_kill_and_recover_onto_fewer_devices(tmp_path):
    """Crash on an 8-device (forced host) mesh, recover onto 4 devices;
    golden runs on the 4-device mesh.  Decisions must still be bitwise
    identical — recovery composes with elastic rescale."""
    _kill_and_recover(tmp_path, crash_mesh="8", recover_mesh="4")
