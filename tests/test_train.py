"""Training semantics: loss decreases, microbatch equivalence, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataPipeline, SyntheticCorpus
from repro.models import ModelConfig, model
from repro.sharding.rules import ExecConfig
from repro.train.optim import (AdamWConfig, adamw_init, cosine_schedule,
                               global_norm)
from repro.train.step import make_train_step

CFG = ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=256,
                  param_dtype="float32", dtype="float32")


def test_loss_decreases():
    params = model.init(jax.random.PRNGKey(0), CFG)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(CFG, ExecConfig(), opt_cfg))
    pipe = DataPipeline(SyntheticCorpus(CFG.vocab_size), 32, 4)
    losses = []
    for s in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatch_grad_equivalence():
    """microbatch=2 gives (numerically close) same update as microbatch=1."""
    params = model.init(jax.random.PRNGKey(1), CFG)
    opt_cfg = AdamWConfig(lr=1e-3)
    pipe = DataPipeline(SyntheticCorpus(CFG.vocab_size), 32, 4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    outs = []
    for mb in (1, 2):
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(CFG, ExecConfig(microbatch=mb),
                                       opt_cfg))
        p2, _, m = step(params, opt, batch)
        outs.append((p2, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        outs[0][0], outs[1][0])


def test_grad_compression_close():
    params = model.init(jax.random.PRNGKey(2), CFG)
    opt_cfg = AdamWConfig(lr=1e-3)
    pipe = DataPipeline(SyntheticCorpus(CFG.vocab_size), 32, 4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    ps = []
    for gc in ("none", "bf16"):
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(
            CFG, ExecConfig(microbatch=2, grad_compress=gc), opt_cfg))
        p2, _, _ = step(params, opt, batch)
        ps.append(p2)
    # bf16 compression is approximate but close
    diffs = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a, np.float32) - np.asarray(b, np.float32)))), *ps)
    assert max(jax.tree.leaves(diffs)) < 1e-2


def test_cosine_schedule_shape():
    s = np.array([float(cosine_schedule(jnp.int32(i), peak_lr=1.0,
                                        warmup=10, total=100))
                  for i in (0, 5, 10, 55, 100)])
    assert s[0] == 0.0
    assert s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0)
    assert 0.1 < s[3] < 1.0
    assert s[4] == pytest.approx(0.1, rel=1e-3)


def test_remat_matches_no_remat():
    import dataclasses
    pipe = DataPipeline(SyntheticCorpus(CFG.vocab_size), 32, 4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    params = model.init(jax.random.PRNGKey(3), CFG)
    opt_cfg = AdamWConfig(lr=1e-3)
    outs = []
    for remat in ("none", "full"):
        cfg = dataclasses.replace(CFG, remat=remat)
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, ExecConfig(), opt_cfg))
        p2, _, m = step(params, opt, batch)
        outs.append(float(m["loss"]))
    assert outs[0] == pytest.approx(outs[1], rel=1e-5)
